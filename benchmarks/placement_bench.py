"""Paper Sec-5 evaluation: Figures 9 (initial deployment), 10 (compaction),
11 (reconfiguration), on 8-GPU and 80-GPU clusters, 100 random test cases.

Approaches (paper Sec 5.1) — all routed through core.engine.PlacementEngine:
  first_fit      — GPUs/workloads by id, indexes from 0
  load_balanced  — GPUs by joint slice utilization ascending, indexes from 0
  rule_based     — Sec-4.2 heuristic (ours)
  mip            — WPM with existing placements fixed (ours)
  joint_mip      — WPM jointly re-placing existing workloads (ours; Fig 9 only)
  patterns       — beyond-paper pattern-enumeration exact solver (reconfig only)

Every approach is scored with the Table-3 metrics averaged over test cases,
then normalized against the highest value per metric (as the paper plots).

Usage:
  python -m benchmarks.placement_bench --case initial --gpus 8 --cases 100
  python -m benchmarks.placement_bench --trace --gpus 8 --tpu-pods 2 \\
      --horizon 200 --policies first_fit load_balanced rule_based
  python -m benchmarks.placement_bench --fleet-scale 256 1024

``--trace`` switches to the online mode: a seeded arrival/departure/burst
trace over a mixed A100 + TPU-pod fleet, periodic compaction with an
optional migration budget, reporting time-averaged GPUs-used and wastage.

``--autoscale`` switches to the demand-driven mode: seeded request traffic
(phase-shifted diurnal chat models + a flash-crowd embedding model) drives
the traffic/perf/autoscaler subsystem over an A100 fleet; rows are
controller x rate-scale x commit-mode, columns SLO attainment / GPUs-used /
disruption-minutes.  ``static`` rows are the peak-provisioned baseline the
closed loop must beat.

``--calibrated CALIBRATION.json`` (with ``--autoscale``) re-runs the grid
on a measured ``PerfModel`` loaded from the kernel calibration artifact
(``benchmarks/calibrate.py``): rows gain ``@cal`` variants and the report
a ``calibration_delta`` section — how far the hand-written rate table was
from measured kernel rates, in attainment and GPUs-used.

``--faults`` switches to the chaos mode: the demand scenario (with the
embedding model demoted to the best-effort brownout tier) is replayed
clean and again under a seeded ``FaultInjector`` schedule (GPU failures
spread mid-trace + node drains at 70% horizon) per commit mode.  The
report (``BENCH_failures.json``, schema ``failures_bench/v1``) carries
per-run fault/recovery columns, a ``retention`` section (faulted/clean
SLO attainment, recovery-time-to-full-capacity, capacity-lost
GPU-seconds), the injected schedule, and the ``fault_byte_identity``
flag — a wired-but-empty injector must reproduce the clean trace.

``--fleet-scale`` benchmarks the vectorized placement fabric
(core/fabric.py) against the scalar path on large fleets: per size, one
deploy of a ~60%-load test case through first_fit and rule_based with the
fabric off vs on (placements are identical — the speedup is free), plus the
fabric-native frag_aware policy, plus a short online trace per policy.

Every run also emits a machine-readable ``BENCH_placement.json`` (disable
with ``--json ''``) so the repo's perf trajectory is tracked across PRs.
The JSON is strict (non-finite floats serialize as ``null``, never ``NaN``).

``--telemetry`` opts the run into the ``repro.obs`` subsystem: engine verbs
are span-traced, planner-latency p50/p95/p99 per verb land in the JSON
report (``planner_latency`` section), and the run writes a JSONL span/event
dump plus a Prometheus text exposition next to the report (render the JSONL
with ``python -m repro.obs.report``).

Human-readable tables go through the std ``logging`` module on stderr
(``--verbose`` adds debug/timing chatter), so stdout stays clean for
machine consumers.
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import math
import sys
import time
from typing import Dict, Optional, Sequence, Tuple

from repro import obs
from repro.core import metrics
from repro.core.autoscaler import SLO, Autoscaler, AutoscalerConfig
from repro.core.engine import PlacementEngine
from repro.core.events import (
    DemandSimulator,
    ModelServiceSpec,
    OnlineSimulator,
    build_fleet,
    generate_trace,
)
from repro.core.faults import FaultInjector, FaultSpec
from repro.core.perfmodel import PerfModel
from repro.core.profiles import A100_80GB
from repro.core.simulator import TestCase, generate_test_case
from repro.core.tpu_profiles import TPU_V5E_POD
from repro.core.traffic import DiurnalRate, FlashCrowd, ModelTraffic, generate_requests

#: human-readable output channel (tables, timings) — stderr via logging, so
#: stdout never interleaves human text with telemetry/JSON consumers.
log = logging.getLogger("repro.bench")

APPROACHES = {
    "initial": ("first_fit", "load_balanced", "rule_based", "frag_aware",
                "mip", "joint_mip"),
    "compaction": ("first_fit", "load_balanced", "rule_based", "frag_aware",
                   "mip"),
    "reconfiguration": ("first_fit", "load_balanced", "rule_based",
                        "frag_aware", "mip", "patterns"),
}

_METRICS = (
    "n_gpus", "memory_wastage", "compute_wastage", "availability",
    "migration_size", "pending_model_size", "sequential_migrations",
    "memory_utilization", "compute_utilization", "fragmentation",
)


def _run(case: str, tc: TestCase, approach: str, time_limit: float):
    """One test case through the unified engine; returns (state, pending, secs)."""
    st = tc.initial.clone()
    eng = PlacementEngine(approach, time_limit=time_limit)
    if case == "initial":
        res = eng.deploy(st, tc.new_workloads)
    elif case == "compaction":
        res = eng.compact(st)
    elif case == "reconfiguration":
        res = eng.reconfigure(st)
    else:
        raise ValueError(case)
    return st, res.pending, res.seconds


def run_case(
    case: str,
    n_gpus: int,
    n_cases: int,
    time_limit: float,
    mip_cases: Optional[int] = None,
    approaches: Optional[Sequence[str]] = None,
) -> Dict[str, Dict[str, float]]:
    """Returns {approach: {metric: mean}} plus solve-time and seq-migration."""
    approaches = approaches or APPROACHES[case]
    sums: Dict[str, Dict[str, float]] = {a: {m: 0.0 for m in _METRICS} for a in approaches}
    counts: Dict[str, int] = {a: 0 for a in approaches}
    for a in approaches:
        sums[a]["solve_seconds"] = 0.0
        n = n_cases
        if mip_cases is not None and a in ("mip", "joint_mip", "patterns"):
            n = min(n, mip_cases)
        for seed in range(n):
            tc = generate_test_case(seed, n_gpus=n_gpus)
            # compaction/reconfiguration act on existing workloads only —
            # pending is null for them by construction (paper Sec 5.2.2)
            all_wl = list(tc.initial.workloads.values())
            if case == "initial":
                all_wl += list(tc.new_workloads)
            final, pending, secs = _run(case, tc, a, time_limit)
            final.validate()
            m = metrics.evaluate(final, tc.initial, all_wl)
            for k in _METRICS:
                sums[a][k] += float(getattr(m, k))
            sums[a]["solve_seconds"] += secs
            counts[a] += 1
    return {
        a: {k: v / max(counts[a], 1) for k, v in sums[a].items()} for a in approaches
    }


def normalize(table: Dict[str, Dict[str, float]]) -> Dict[str, Dict[str, float]]:
    """Paper-style: each metric normalized against its max across approaches."""
    out: Dict[str, Dict[str, float]] = {a: {} for a in table}
    keys = next(iter(table.values())).keys()
    for k in keys:
        mx = max(abs(table[a][k]) for a in table) or 1.0
        for a in table:
            out[a][k] = table[a][k] / mx
    return out


def print_table(case: str, n_gpus: int, table: Dict[str, Dict[str, float]]) -> None:
    norm = normalize(table)
    keys = list(next(iter(table.values())).keys())
    log.info(f"\n== {case} @ {n_gpus} GPUs (mean over cases; normalized in []) ==")
    header = "approach".ljust(15) + "".join(k[:14].rjust(16) for k in keys)
    log.info(header)
    for a, row in table.items():
        line = a.ljust(15)
        for k in keys:
            line += f"{row[k]:9.3f}[{norm[a][k]:4.2f}]".rjust(16)
        log.info(line)


# ---------------------------------------------------------------------------
# online trace mode (--trace)
# ---------------------------------------------------------------------------
#: TraceStats field -> short column label (migration-cost columns included)
_TRACE_COLS = {
    "time_avg_gpus_used": "avg_gpus",
    "time_avg_compute_waste": "avg_cwaste",
    "time_avg_mem_occupancy": "avg_mem_occ",
    "peak_gpus_used": "peak_gpus",
    "n_placed": "placed",
    "n_rejected": "rejected",
    "n_migrations": "migrations",
    "n_compactions": "compactions",
    "n_plans_rejected": "plans_rej",
    "n_deferred": "deferred",  # compactions + reconfigures inside a window
    "gib_moved": "gib_moved",
    "disruption_minutes": "disrupt_min",
    "migration_window_seconds": "migr_win_s",
    "engine_seconds": "engine_s",
}


def run_trace(
    policies: Sequence[str],
    n_a100: int,
    n_tpu_pods: int,
    seed: int,
    horizon: float,
    arrival_rate: float,
    mean_lifetime: float,
    compact_every: Optional[float],
    migration_budget: Optional[int],
    time_limit: float,
    commit_modes: Sequence[str] = ("always",),
    reconfigure_every: Optional[float] = None,
) -> Dict[str, Dict[str, float]]:
    """Each policy x commit mode over the same seeded trace.

    Rows are keyed ``policy`` when one commit mode is given, else
    ``policy@mode`` — the side-by-side view behind the control plane's
    headline: net-positive cuts disruption-minutes at equal GPUs-used.
    """
    spec = [(A100_80GB, n_a100)]
    if n_tpu_pods:
        spec.append((TPU_V5E_POD, n_tpu_pods))
    out: Dict[str, Dict[str, float]] = {}
    for policy in policies:
        for commit in commit_modes:
            fleet = build_fleet(spec)
            trace = generate_trace(
                seed, fleet, horizon=horizon, arrival_rate=arrival_rate,
                mean_lifetime=mean_lifetime,
            )
            sim = OnlineSimulator(
                fleet,
                PlacementEngine(policy, time_limit=time_limit, commit=commit),
                compact_every=compact_every,
                migration_budget=migration_budget,
                reconfigure_every=reconfigure_every,
            )
            stats = sim.run(trace)
            fleet.validate()
            d = stats.as_dict()
            d["gib_moved"] = stats.bytes_moved / 2**30
            d["n_deferred"] = (
                stats.n_compactions_deferred + stats.n_reconfigures_deferred
            )
            key = policy if len(commit_modes) == 1 else f"{policy}@{commit}"
            out[key] = {k: float(d[k]) for k in _TRACE_COLS}
    return out


def print_trace_table(table: Dict[str, Dict[str, float]], header: str) -> None:
    log.info(f"\n== online trace: {header} ==")
    cols = list(next(iter(table.values())).keys())
    width = max(24, max(len(a) for a in table) + 2)
    log.info("policy".ljust(width) + "".join(_TRACE_COLS[c].rjust(13) for c in cols))
    for a, row in table.items():
        log.info(a.ljust(width) + "".join(f"{row[c]:13.3f}" for c in cols))


# ---------------------------------------------------------------------------
# autoscale mode (--autoscale): demand-driven traffic + replica controller
# ---------------------------------------------------------------------------
#: default demand scenario: three phase-shifted diurnal chat models plus one
#: flash-crowd embedding model, on A100 MIG profiles.  ``rate_scale``
#: multiplies every base rate; the diurnal period is the trace horizon (one
#: simulated "day" per run).  (profile, ladder, traffic args) per model.
_SCENARIO = (
    ("chat-l", 5, (), dict(base_rps=100.0, amplitude=0.7, phase=0.0), 512, 128),
    ("chat-m", 9, (), dict(base_rps=75.0, amplitude=0.8, phase=0.5), 512, 96),
    ("bot-s", 15, (15, 19), dict(base_rps=40.0, amplitude=0.6, phase=0.25), 256, 32),
    ("embed", 19, (), None, 128, 4),  # FlashCrowd (mid-trace spike)
)

_AUTOSCALE_COLS = {
    "slo_attainment": "slo_attain",
    "ttft_p95": "ttft_p95",
    "time_avg_gpus_used": "avg_gpus",
    "peak_gpus_used": "peak_gpus",
    "time_avg_queue_depth": "avg_queue",
    "n_requests": "requests",
    "n_unserved": "unserved",
    "n_scale_ups": "ups",
    "n_scale_downs": "downs",
    "n_resizes": "resizes",
    "n_deploy_rejected": "deploy_rej",
    "n_plans_rejected": "plans_rej",
    "disruption_minutes": "disrupt_min",
    "gib_moved": "gib_moved",
    "engine_seconds": "engine_s",
}


def _scenario_specs(rate_scale: float, horizon: float, slo: SLO):
    """(ModelServiceSpec list, ModelTraffic list, peak rps per model)."""
    specs, traffic, peaks = [], [], {}
    for model, pid, ladder, diurnal, mean_p, mean_d in _SCENARIO:
        if diurnal is not None:
            pat = DiurnalRate(
                base_rps=diurnal["base_rps"] * rate_scale,
                amplitude=diurnal["amplitude"],
                period=horizon,
                phase=diurnal["phase"] * horizon,
            )
        else:
            pat = FlashCrowd(
                base_rps=20.0 * rate_scale,
                flash_at=horizon * 0.4,
                flash_duration=horizon * 0.15,
                multiplier=4.0,
            )
        specs.append(ModelServiceSpec(
            model=model, profile_id=pid, profile_ladder=ladder, slo=slo,
        ))
        traffic.append(ModelTraffic(
            model=model, pattern=pat,
            mean_prompt_len=mean_p, mean_decode_len=mean_d,
        ))
        peaks[model] = pat.peak_rate
    return specs, traffic, peaks


def _static_replicas(spec: ModelServiceSpec, traffic: ModelTraffic,
                     peak_rps: float, perf: PerfModel, rho: float) -> int:
    """Peak-provisioned static sizing (the no-autoscaler baseline)."""
    cap = perf.capacity_rps(
        A100_80GB, spec.profile_id,
        traffic.mean_prompt_len, traffic.mean_decode_len,
    )
    return max(1, math.ceil(peak_rps / (rho * cap)))


def run_autoscale(
    policy: str,
    n_gpus: int,
    seed: int,
    horizon: float,
    rate_scales: Sequence[float],
    controllers: Sequence[str],
    commit_modes: Sequence[str],
    compact_every: Optional[float],
    autoscale_every: float,
    perf: Optional[PerfModel] = None,
) -> Dict[str, Dict[str, float]]:
    """Rate-sweep x controller x commit grid over the demand scenario.

    ``static`` rows provision every model for its PEAK rate up front and
    never scale — the over-provisioning baseline the closed loop must beat
    on time-averaged GPUs at equal-or-better SLO attainment.

    ``perf`` swaps the service-rate model the whole loop plans with — pass
    ``PerfModel.from_calibration(...)`` to run on measured kernel rates
    instead of the built-in table (the ``--calibrated`` mode).
    """
    slo = SLO(ttft_seconds=2.0, tpot_seconds=0.1, attainment_target=0.95)
    perf = perf or PerfModel()
    out: Dict[str, Dict[str, float]] = {}
    for rate in rate_scales:
        specs, tspecs, peaks = _scenario_specs(rate, horizon, slo)
        traffic = generate_requests(tspecs, seed, horizon)
        for controller in controllers:
            for commit in commit_modes:
                fleet = build_fleet([(A100_80GB, n_gpus)])
                if controller == "static":
                    scaler = None
                    rho = AutoscalerConfig().target_utilization
                    run_specs = [
                        dataclasses.replace(
                            spec,
                            initial_replicas=_static_replicas(
                                spec, ts, peaks[spec.model], perf, rho
                            ),
                        )
                        for spec, ts in zip(specs, tspecs)
                    ]
                else:
                    cfg = AutoscalerConfig(mode=controller)
                    scaler = Autoscaler(cfg)
                    # Warm start at the t=0 sizing: the service was already
                    # running; what's under test is demand *tracking*.
                    run_specs = [
                        dataclasses.replace(
                            spec,
                            initial_replicas=_static_replicas(
                                spec, ts, ts.pattern.rate(0.0), perf,
                                cfg.target_utilization,
                            ),
                        )
                        for spec, ts in zip(specs, tspecs)
                    ]
                sim = DemandSimulator(
                    fleet,
                    PlacementEngine(policy, commit=commit),
                    run_specs,
                    autoscaler=scaler,
                    perf=perf,
                    autoscale_every=autoscale_every,
                    compact_every=compact_every,
                )
                stats = sim.run(traffic)
                fleet.validate()
                d = stats.as_dict()
                d["gib_moved"] = stats.bytes_moved / 2**30
                key = f"{controller}@r{rate:g}@{commit}"
                out[key] = {k: float(d[k]) for k in _AUTOSCALE_COLS}
    return out


#: columns compared between the calibrated and table PerfModel runs.
_DELTA_COLS = ("slo_attainment", "time_avg_gpus_used", "peak_gpus_used",
               "ttft_p95", "n_unserved")


def calibration_delta(
    table_rows: Dict[str, Dict[str, float]],
    cal_rows: Dict[str, Dict[str, float]],
) -> Dict[str, Dict[str, float]]:
    """Calibrated-minus-table deltas per grid row: how much the planning
    answer moves when measured kernel rates replace the hand-written
    table — the headline of the ``--calibrated`` mode."""
    out: Dict[str, Dict[str, float]] = {}
    for key, cal in cal_rows.items():
        tab = table_rows.get(key)
        if tab is None:
            continue
        out[key] = {c: cal[c] - tab[c] for c in _DELTA_COLS}
    return out


def print_calibration_delta(delta: Dict[str, Dict[str, float]]) -> None:
    log.info("\n== calibrated - table deltas (measured kernel rates vs "
             "built-in planning numbers) ==")
    width = max(30, max((len(a) for a in delta), default=0) + 2)
    log.info("controller".ljust(width)
             + "".join(c[:12].rjust(13) for c in _DELTA_COLS))
    for a, row in delta.items():
        log.info(a.ljust(width)
                 + "".join(f"{row[c]:+13.3f}" for c in _DELTA_COLS))


def print_autoscale_table(table: Dict[str, Dict[str, float]], header: str) -> None:
    log.info(f"\n== autoscale: {header} ==")
    cols = list(next(iter(table.values())).keys())
    width = max(30, max(len(a) for a in table) + 2)
    log.info("controller".ljust(width)
             + "".join(_AUTOSCALE_COLS[c][:11].rjust(12) for c in cols))
    for a, row in table.items():
        log.info(a.ljust(width) + "".join(f"{row[c]:12.3f}" for c in cols))


# ---------------------------------------------------------------------------
# faults mode (--faults): seeded chaos over the demand scenario
# ---------------------------------------------------------------------------
#: TraceStats columns surfaced per fault-grid row (clean vs faulted runs).
_FAULT_COLS = {
    "slo_attainment": "slo_attain",
    "ttft_p95": "ttft_p95",
    "time_avg_gpus_used": "avg_gpus",
    "n_requests": "requests",
    "n_unserved": "unserved",
    "n_requeued_requests": "requeued",
    "n_shed_requests": "shed",
    "n_gpu_failures": "gpu_fail",
    "n_node_drains": "drains",
    "n_fault_evictions": "evicted",
    "n_fault_recovered": "recovered",
    "n_recovery_pending": "rec_pend",
    "recovery_seconds_total": "rec_s_tot",
    "recovery_seconds_max": "rec_s_max",
    "capacity_lost_gpu_seconds": "cap_lost_s",
    "brownout_seconds": "brownout_s",
    "n_emergency_commits": "emergency",
    "disruption_minutes": "disrupt_min",
    "engine_seconds": "engine_s",
}


def _fault_specs(
    n_gpu_failures: int,
    n_drains: int,
    horizon: float,
    mttr: float,
    drain_duration: float,
) -> Tuple[FaultSpec, ...]:
    """Deterministic chaos schedule: GPU failures spread over the middle of
    the trace (so recovery is observable before the horizon) plus node
    drains at 70%.  Targets are drawn by the injector's seeded substreams."""
    specs = []
    if n_gpu_failures > 0:
        lo, hi = 0.2, 0.6
        ats = tuple(
            horizon * (lo + (hi - lo) * i / max(n_gpu_failures - 1, 1))
            for i in range(n_gpu_failures)
        )
        specs.append(FaultSpec(
            kind="gpu_failure", at=ats, duration=mttr, name="bench-gpu",
        ))
    if n_drains > 0:
        specs.append(FaultSpec(
            kind="node_drain", at=(horizon * 0.7,), count=n_drains,
            duration=drain_duration, name="bench-drain",
        ))
    return tuple(specs)


def _stats_signature(stats) -> Dict[str, float]:
    """Full TraceStats dict minus the one wall-clock field — the object the
    injector-off byte-identity contract is checked against."""
    d = stats.as_dict()
    d.pop("engine_seconds", None)
    return d


def run_faults(
    policy: str,
    n_gpus: int,
    seed: int,
    horizon: float,
    rate_scale: float,
    commit_modes: Sequence[str],
    compact_every: Optional[float],
    autoscale_every: float,
    n_gpu_failures: int,
    n_drains: int,
    fault_seed: int,
    mttr: float,
    drain_duration: float,
):
    """Clean vs faulted demand runs per commit mode over the standard
    scenario (``embed`` demoted to the best-effort brownout tier).

    Returns ``(rows, retention, byte_identity, fault_events)``:

    * rows — ``{commit}@clean`` / ``{commit}@faults`` -> ``_FAULT_COLS``;
    * retention — per commit mode, faulted/clean SLO attainment plus the
      recovery-time and capacity-lost headline numbers;
    * byte_identity — True iff a wired-but-empty ``FaultInjector(())``
      reproduces the clean trace exactly (minus wall-clock timing);
    * fault_events — the injected schedule, for reproducibility.
    """
    slo = SLO(ttft_seconds=2.0, tpot_seconds=0.1, attainment_target=0.95)
    perf = PerfModel()
    specs, tspecs, _ = _scenario_specs(rate_scale, horizon, slo)
    specs = [
        dataclasses.replace(s, best_effort=(s.model == "embed")) for s in specs
    ]
    traffic = generate_requests(tspecs, seed, horizon)
    chaos = _fault_specs(n_gpu_failures, n_drains, horizon, mttr, drain_duration)

    def _one(commit: str, faults: Optional[FaultInjector]):
        fleet = build_fleet([(A100_80GB, n_gpus)])
        cfg = AutoscalerConfig(mode="slo")
        run_specs = [
            dataclasses.replace(
                spec,
                initial_replicas=_static_replicas(
                    spec, ts, ts.pattern.rate(0.0), perf,
                    cfg.target_utilization,
                ),
            )
            for spec, ts in zip(specs, tspecs)
        ]
        sim = DemandSimulator(
            fleet,
            PlacementEngine(policy, commit=commit),
            run_specs,
            autoscaler=Autoscaler(cfg),
            perf=perf,
            autoscale_every=autoscale_every,
            compact_every=compact_every,
            faults=faults,
        )
        stats = sim.run(traffic)
        fleet.validate()
        return stats

    rows: Dict[str, Dict[str, float]] = {}
    retention: Dict[str, Dict[str, float]] = {}
    byte_identity: Optional[bool] = None
    for commit in commit_modes:
        clean = _one(commit, None)
        if byte_identity is None:
            # a wired-but-silent injector must not perturb the trace
            byte_identity = (
                _stats_signature(_one(commit, FaultInjector(())))
                == _stats_signature(clean)
            )
        faulted = _one(commit, FaultInjector(chaos, seed=fault_seed))
        for label, st in (("clean", clean), ("faults", faulted)):
            d = st.as_dict()
            rows[f"{commit}@{label}"] = {k: float(d[k]) for k in _FAULT_COLS}
        c, f = clean.slo_attainment, faulted.slo_attainment
        retention[commit] = {
            "clean_attainment": c,
            "faulted_attainment": f,
            "slo_retention": f / c if c > 0 else float("nan"),
            "recovery_seconds_max": faulted.recovery_seconds_max,
            "recovery_seconds_total": faulted.recovery_seconds_total,
            "capacity_lost_gpu_seconds": faulted.capacity_lost_gpu_seconds,
            "n_recovery_pending": float(faulted.n_recovery_pending),
            "n_requeued_requests": float(faulted.n_requeued_requests),
            "n_shed_requests": float(faulted.n_shed_requests),
        }
    events = [
        dataclasses.asdict(fe)
        for fe in FaultInjector(chaos, seed=fault_seed).schedule(
            build_fleet([(A100_80GB, n_gpus)]), horizon
        )
    ]
    return rows, retention, byte_identity, events


def print_fault_table(table: Dict[str, Dict[str, float]], header: str) -> None:
    log.info(f"\n== faults: {header} ==")
    cols = list(next(iter(table.values())).keys())
    width = max(26, max(len(a) for a in table) + 2)
    log.info("commit@run".ljust(width)
             + "".join(_FAULT_COLS[c][:11].rjust(12) for c in cols))
    for a, row in table.items():
        log.info(a.ljust(width) + "".join(f"{row[c]:12.3f}" for c in cols))


def print_fault_retention(retention: Dict[str, Dict[str, float]],
                          byte_identity: bool) -> None:
    log.info("\n== fault recovery headline (faulted vs clean) ==")
    for commit, r in retention.items():
        log.info(
            f"{commit}: SLO retention {r['slo_retention']:.3f} "
            f"({r['faulted_attainment']:.3f} / {r['clean_attainment']:.3f}), "
            f"recovery max {r['recovery_seconds_max']:.1f}s, "
            f"capacity lost {r['capacity_lost_gpu_seconds']:.1f} GPU-s, "
            f"requeued {r['n_requeued_requests']:.0f}, "
            f"shed {r['n_shed_requests']:.0f}"
        )
    log.info(f"injector-off byte identity: {byte_identity}")


# ---------------------------------------------------------------------------
# fleet-scale mode (--fleet-scale): scalar path vs vectorized fabric
# ---------------------------------------------------------------------------
#: metrics surfaced in the fleet-scale comparison (the acceptance metrics:
#: GPUs used + wastage + fragmentation + pending).
_SCALE_METRICS = (
    "n_gpus", "compute_wastage", "memory_wastage", "fragmentation", "n_pending",
)


def _deploy_once(tc: TestCase, policy: str, fabric: str) -> Dict[str, float]:
    st = tc.initial.clone()
    eng = PlacementEngine(policy, fabric=fabric)
    res = eng.deploy(st, tc.new_workloads)
    st.validate()
    all_wl = list(tc.initial.workloads.values()) + list(tc.new_workloads)
    m = metrics.evaluate(st, tc.initial, all_wl)
    out = {k: float(getattr(m, k)) for k in _SCALE_METRICS}
    out["seconds"] = res.seconds
    return out


def run_fleet_scale(
    n_gpus: int, seed: int, horizon: float
) -> Dict[str, Dict[str, float]]:
    """One fleet size: deploys (scalar vs fabric) + a short online trace.

    The fabric deploy is run twice and the warm timing reported (the first
    call pays one-off jit compilation for the fleet shape; ``cold_seconds``
    is kept in the JSON for honesty).
    """
    tc = generate_test_case(seed, n_gpus=n_gpus)
    rows: Dict[str, Dict[str, float]] = {}
    for policy in ("first_fit", "rule_based"):
        scalar = _deploy_once(tc, policy, fabric="off")
        cold = _deploy_once(tc, policy, fabric="on")
        warm = _deploy_once(tc, policy, fabric="on")
        assert all(
            warm[k] == scalar[k] for k in _SCALE_METRICS
        ), f"fabric parity broken for {policy} @ {n_gpus}"
        row = dict(warm)
        row["scalar_seconds"] = scalar["seconds"]
        row["cold_seconds"] = cold["seconds"]
        row["speedup"] = scalar["seconds"] / max(warm["seconds"], 1e-9)
        rows[policy] = row
    frag = _deploy_once(tc, "frag_aware", fabric="on")
    frag["scalar_seconds"] = float("nan")
    frag["cold_seconds"] = frag["seconds"]
    frag["speedup"] = float("nan")
    rows["frag_aware"] = frag

    # Short online trace over the same fleet size (arrival rate scaled so
    # steady-state load covers roughly half the fleet); compaction off — this
    # measures deploy latency and GPUs-used/wastage per policy at scale.
    for policy in ("first_fit", "rule_based", "frag_aware"):
        fleet = build_fleet([(A100_80GB, n_gpus)])
        trace = generate_trace(
            seed, fleet, horizon=horizon, arrival_rate=max(1.0, n_gpus / 8.0),
            mean_lifetime=horizon * 0.6,
        )
        stats = OnlineSimulator(fleet, PlacementEngine(policy)).run(trace)
        fleet.validate()
        rows[policy]["trace_avg_gpus"] = stats.time_avg_gpus_used
        rows[policy]["trace_avg_cwaste"] = stats.time_avg_compute_waste
        rows[policy]["trace_engine_seconds"] = stats.engine_seconds
    return rows


def print_fleet_scale(n_gpus: int, rows: Dict[str, Dict[str, float]]) -> None:
    log.info(f"\n== fleet-scale @ {n_gpus} GPUs (deploy; fabric vs scalar) ==")
    cols = (
        "scalar_seconds", "seconds", "speedup", "n_gpus", "compute_wastage",
        "memory_wastage", "fragmentation", "n_pending",
        "trace_avg_gpus", "trace_avg_cwaste", "trace_engine_seconds",
    )
    short = {
        "scalar_seconds": "scalar_s", "seconds": "fabric_s",
        "compute_wastage": "cwaste", "memory_wastage": "mwaste",
        "fragmentation": "frag", "trace_avg_gpus": "tr_gpus",
        "trace_avg_cwaste": "tr_cwaste", "trace_engine_seconds": "tr_eng_s",
    }
    log.info("policy".ljust(12) + "".join(short.get(c, c)[:10].rjust(11) for c in cols))
    for a, row in rows.items():
        log.info(a.ljust(12) + "".join(f"{row.get(c, float('nan')):11.3f}" for c in cols))


def write_json(path: str, report: Dict, schema: str = "placement_bench/v1") -> None:
    """Write via the shared strict-JSON report writer (``obs.write_report``):
    sections merge into an existing report of the same schema family (so a
    ``--trace`` run and an ``--autoscale`` run can share one file) and
    non-finite floats serialize as ``null``, never ``NaN``.  ``--faults``
    runs write a ``failures_bench/v1`` report instead."""
    if obs.write_report(path, report, schema):
        log.info(f"wrote {path}")


# ---------------------------------------------------------------------------
# telemetry plumbing (--telemetry)
# ---------------------------------------------------------------------------
def planner_latency_section(tel: obs.Telemetry) -> Dict[str, Dict[str, float]]:
    """Per-verb planner-latency percentiles from the live registry:
    {"verb@policy": {count, p50_s, p95_s, p99_s, total_s}}."""
    out: Dict[str, Dict[str, float]] = {}
    for inst in tel.metrics.families().get("planner_latency_seconds", []):
        labels = dict(inst.labels)
        key = f"{labels.get('verb', '?')}@{labels.get('policy', '?')}"
        pct = inst.percentiles((50, 95, 99))
        out[key] = {
            "count": float(inst.count),
            "total_s": inst.sum,
            "p50_s": pct["p50"],
            "p95_s": pct["p95"],
            "p99_s": pct["p99"],
        }
    return out


def dump_telemetry(tel: obs.Telemetry, prefix: str) -> None:
    """Write the run's spans/events as JSONL and the registry as Prometheus
    text exposition, under ``{prefix}_spans.jsonl`` / ``{prefix}_metrics.prom``."""
    spans_path = f"{prefix}_spans.jsonl"
    prom_path = f"{prefix}_metrics.prom"
    n = obs.write_jsonl(tel.tracer.records(), spans_path)
    with open(prom_path, "w") as f:
        f.write(obs.prometheus_text(tel.metrics))
    log.info(f"wrote {spans_path} ({n} records) and {prom_path}")
    log.info(f"render with: python -m repro.obs.report {spans_path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--case", default="all",
                    choices=["initial", "compaction", "reconfiguration", "all"])
    ap.add_argument("--gpus", type=int, nargs="+", default=[8, 80])
    ap.add_argument("--cases", type=int, default=100)
    ap.add_argument("--mip-cases", type=int, default=None,
                    help="cap test cases for MIP approaches (big clusters)")
    ap.add_argument("--time-limit", type=float, default=30.0)
    # online trace mode
    ap.add_argument("--trace", action="store_true",
                    help="online arrival/departure trace over a mixed fleet")
    ap.add_argument("--policies", nargs="+",
                    default=["first_fit", "load_balanced", "rule_based"])
    ap.add_argument("--tpu-pods", type=int, default=2,
                    help="TPU v5e pods to add next to the --gpus A100s")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--horizon", type=float, default=200.0)
    ap.add_argument("--arrival-rate", type=float, default=1.0)
    ap.add_argument("--mean-lifetime", type=float, default=40.0)
    ap.add_argument("--compact-every", type=float, default=25.0)
    ap.add_argument("--migration-budget", type=int, default=None)
    ap.add_argument("--commit", nargs="+", default=["always"],
                    choices=["always", "net-positive", "budgeted"],
                    help="CommitPolicy mode(s); several = side-by-side rows "
                    "per policy (plan/score/commit control plane)")
    ap.add_argument("--reconfigure-every", type=float, default=None,
                    help="periodic maintenance repack (Sec 2.3.3) in the "
                    "online trace; the verb the CommitPolicy keeps honest")
    # autoscale mode
    ap.add_argument("--autoscale", action="store_true",
                    help="demand-driven mode: request traffic + replica "
                    "controller closing the loop into the engine")
    ap.add_argument("--rate-scale", type=float, nargs="+", default=[1.0],
                    help="multipliers on the demand scenario's base rates "
                    "(several = arrival-rate sweep)")
    ap.add_argument("--controller", nargs="+", default=["slo", "static"],
                    choices=["slo", "target-utilization", "static"],
                    help="autoscaler mode(s); 'static' = peak-provisioned "
                    "fixed replicas (the over-provisioning baseline)")
    ap.add_argument("--autoscale-every", type=float, default=5.0,
                    help="control-tick period (simulated seconds)")
    ap.add_argument("--calibrated", default=None, metavar="CALIBRATION.json",
                    help="run the autoscale grid a second time on a "
                    "measured PerfModel loaded from this calibration "
                    "artifact (benchmarks/calibrate.py output); rows gain "
                    "an @cal variant and the report a calibration_delta "
                    "section (calibrated-minus-table attainment/GPUs)")
    # faults mode
    ap.add_argument("--faults", action="store_true",
                    help="seeded chaos mode: clean-vs-faulted demand runs "
                    "per commit mode; emits BENCH_failures.json "
                    "(failures_bench/v1) with SLO retention, "
                    "recovery-time-to-full-capacity, and "
                    "capacity-lost-GPU-seconds")
    ap.add_argument("--gpu-failures", type=int, default=3,
                    help="GPU hard failures injected mid-trace")
    ap.add_argument("--node-drains", type=int, default=1,
                    help="simultaneous node drains injected at 70%% horizon")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the injector's target-selection streams")
    ap.add_argument("--fault-mttr", type=float, default=None,
                    help="repair time per GPU failure (default 15%% horizon)")
    ap.add_argument("--drain-duration", type=float, default=None,
                    help="drain length (default 20%% horizon)")
    # fleet-scale mode
    ap.add_argument("--fleet-scale", type=int, nargs="+", default=None,
                    metavar="N", help="fleet sizes for the fabric-vs-scalar "
                    "comparison (e.g. 256 1024 4096)")
    ap.add_argument("--fleet-horizon", type=float, default=20.0,
                    help="trace horizon per fleet-scale size")
    ap.add_argument("--json", default="BENCH_placement.json",
                    help="machine-readable output path ('' disables)")
    # observability
    ap.add_argument("--telemetry", action="store_true",
                    help="enable repro.obs: span-trace engine verbs, add "
                    "planner-latency p50/p95/p99 to the JSON report, and "
                    "dump spans (JSONL) + metrics (Prometheus text)")
    ap.add_argument("--telemetry-prefix", default="TELEMETRY",
                    help="output prefix for the spans/metrics dumps")
    ap.add_argument("--verbose", "-v", action="store_true",
                    help="debug logging (timings, progress) on stderr")
    args = ap.parse_args()

    logging.basicConfig(
        stream=sys.stderr,
        level=logging.DEBUG if args.verbose else logging.INFO,
        format="%(message)s",
    )

    tel: Optional[obs.Telemetry] = None
    if args.telemetry:
        tel = obs.enable()

    report: Dict = {"args": {k: v for k, v in vars(args).items() if k != "json"}}
    # contended-host guard: timings next to a stale pytest/bench are suspect
    report["host"] = obs.host_snapshot()

    def _finish(rep: Dict, schema: str = "placement_bench/v1") -> None:
        if tel is not None:
            rep["planner_latency"] = planner_latency_section(tel)
            dump_telemetry(tel, args.telemetry_prefix)
        write_json(args.json, rep, schema)

    if args.faults:
        n_a100 = args.gpus[0]
        if args.json == ap.get_default("json"):
            args.json = "BENCH_failures.json"  # own artifact, own schema
        mttr = (args.fault_mttr if args.fault_mttr is not None
                else args.horizon * 0.15)
        drain_dur = (args.drain_duration if args.drain_duration is not None
                     else args.horizon * 0.2)
        t0 = time.time()
        rows, retention, identity, events = run_faults(
            args.policies[0], n_a100, args.seed, args.horizon,
            args.rate_scale[0], args.commit,
            args.compact_every if args.compact_every > 0 else None,
            args.autoscale_every,
            args.gpu_failures, args.node_drains, args.fault_seed,
            mttr, drain_dur,
        )
        print_fault_table(
            rows,
            f"{n_a100}x A100, horizon {args.horizon}, "
            f"{args.gpu_failures} GPU failures + {args.node_drains} drain(s)",
        )
        print_fault_retention(retention, identity)
        log.debug(f"   ({time.time() - t0:.0f}s)")
        report["faults"] = {
            "rows": rows,
            "retention": retention,
            "fault_byte_identity": identity,
            "fault_events": events,
        }
        _finish(report, schema="failures_bench/v1")
        return

    if args.fleet_scale:
        report["fleet_scale"] = {}
        for n in args.fleet_scale:
            t0 = time.time()
            rows = run_fleet_scale(n, args.seed, args.fleet_horizon)
            print_fleet_scale(n, rows)
            log.debug(f"   ({time.time() - t0:.0f}s)")
            report["fleet_scale"][str(n)] = rows
        _finish(report)
        return

    if args.autoscale:
        n_a100 = args.gpus[0]
        t0 = time.time()
        grid_args = (
            args.policies[0], n_a100, args.seed, args.horizon,
            args.rate_scale, args.controller, args.commit,
            args.compact_every if args.compact_every > 0 else None,
            args.autoscale_every,
        )
        table = run_autoscale(*grid_args)
        print_autoscale_table(
            table,
            f"{n_a100}x A100, horizon {args.horizon}, "
            f"policy {args.policies[0]}",
        )
        if args.calibrated:
            perf_cal = PerfModel.from_calibration(args.calibrated)
            whole = perf_cal.device_throughput(A100_80GB)
            log.info(
                f"\ncalibrated PerfModel from {args.calibrated}: "
                f"prefill {whole.prefill_tokens_per_s:.0f} tok/s, decode "
                f"{whole.decode_tokens_per_s:.0f} tok/s, "
                f"e={perf_cal.parallel_efficiency:.3f}"
            )
            cal_table = run_autoscale(*grid_args, perf=perf_cal)
            print_autoscale_table(
                cal_table, f"CALIBRATED rates, {n_a100}x A100"
            )
            delta = calibration_delta(table, cal_table)
            print_calibration_delta(delta)
            table = dict(table)
            table.update({f"{k}@cal": v for k, v in cal_table.items()})
            report["calibration_delta"] = delta
            report["calibration_source"] = args.calibrated
        log.debug(f"   ({time.time() - t0:.0f}s)")
        report["autoscale"] = table
        _finish(report)
        return

    if args.trace:
        n_a100 = args.gpus[0]
        t0 = time.time()
        table = run_trace(
            args.policies, n_a100, args.tpu_pods, args.seed, args.horizon,
            args.arrival_rate, args.mean_lifetime,
            args.compact_every if args.compact_every > 0 else None,
            args.migration_budget, args.time_limit,
            commit_modes=args.commit,
            reconfigure_every=args.reconfigure_every,
        )
        print_trace_table(
            table,
            f"{n_a100}x A100 + {args.tpu_pods}x TPU pod, horizon {args.horizon}",
        )
        log.debug(f"   ({time.time() - t0:.0f}s)")
        report["trace"] = table
        _finish(report)
        return

    cases = (
        ["initial", "compaction", "reconfiguration"]
        if args.case == "all" else [args.case]
    )
    report["snapshot"] = {}
    for case in cases:
        for g in args.gpus:
            t0 = time.time()
            table = run_case(case, g, args.cases, args.time_limit, args.mip_cases)
            print_table(case, g, table)
            log.debug(f"   ({time.time() - t0:.0f}s)")
            report["snapshot"][f"{case}@{g}"] = table
    _finish(report)


if __name__ == "__main__":
    main()
