"""Paper Sec-5 evaluation: Figures 9 (initial deployment), 10 (compaction),
11 (reconfiguration), on 8-GPU and 80-GPU clusters, 100 random test cases.

Approaches (paper Sec 5.1):
  first_fit      — GPUs/workloads by id, indexes from 0
  load_balanced  — GPUs by joint slice utilization ascending, indexes from 0
  rule_based     — Sec-4.2 heuristic (ours)
  mip            — WPM with existing placements fixed (ours)
  joint_mip      — WPM jointly re-placing existing workloads (ours; Fig 9 only)
  patterns       — beyond-paper pattern-enumeration exact solver (reconfig only)

Every approach is scored with the Table-3 metrics averaged over test cases,
then normalized against the highest value per metric (as the paper plots).

Usage: python -m benchmarks.placement_bench --case initial --gpus 8 --cases 100
"""
from __future__ import annotations

import argparse
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import baselines, heuristic, metrics
from repro.core.migration import plan_migration
from repro.core.patterns import reconfigure_patterns
from repro.core.simulator import TestCase, generate_test_case
from repro.core.state import ClusterState, GPUState, Workload
from repro.core.wpm_mip import solve_wpm

# ---------------------------------------------------------------------------
# baseline compaction / reconfiguration replays (paper Sec 5.2.2/5.2.3)
# ---------------------------------------------------------------------------
def _spot_first_fit(state: ClusterState, w: Workload, candidates) -> Optional[Tuple[str, int]]:
    for gid in sorted(candidates):
        idx = baselines._try_place(state.gpus[gid], w, numeric_order=True)
        if idx is not None:
            return gid, idx
    return None


def _spot_load_balanced(state, w, candidates) -> Optional[Tuple[str, int]]:
    ordered = sorted(
        candidates, key=lambda gid: (state.gpus[gid].joint_slice_utilization(), gid)
    )
    for gid in ordered:
        idx = baselines._try_place(state.gpus[gid], w, numeric_order=True)
        if idx is not None:
            return gid, idx
    return None


_SPOTS: Dict[str, Callable] = {
    "first_fit": _spot_first_fit,
    "load_balanced": _spot_load_balanced,
}


def baseline_compaction(state: ClusterState, policy: str) -> None:
    """Compaction replay with a baseline placement rule: vacate the least
    utilized GPU into other allocated GPUs, placing per ``policy``."""
    spot = _SPOTS[policy]
    progress = True
    while progress:
        progress = False
        used = sorted(
            state.used_gpus(), key=lambda g: (g.joint_slice_utilization(), g.gid)
        )
        for gpu in used:
            others = [g.gid for g in state.used_gpus() if g.gid != gpu.gid]
            trial = state.clone()
            moves = []
            ok = True
            for pl in list(trial.gpus[gpu.gid].placements):
                w = trial.workloads[pl.wid]
                trial.gpus[gpu.gid].remove(pl.wid)
                s = spot(trial, w, others)
                if s is None:
                    ok = False
                    break
                trial.place(w.wid, *s)
                moves.append((w.wid, *s))
            # one-shot property: destinations must be free in the real state
            if ok:
                for wid, dst, idx in moves:
                    prof = state.gpus[dst].device.profile(
                        state.workloads[wid].profile_id
                    )
                    if not state.gpus[dst].can_place_at(prof, idx):
                        ok = False
                        break
            if ok:
                for wid, dst, idx in moves:
                    state.gpus[gpu.gid].remove(wid)
                    state.place(wid, dst, idx)
                progress = True
                break


def baseline_reconfiguration(state: ClusterState, policy: str) -> List[Workload]:
    """Reconfiguration replay: re-place ALL workloads from scratch with the
    baseline rule (arrival order, indexes from 0 — paper Sec 5.2.3)."""
    device = next(iter(state.gpus.values())).device
    workloads = state.placed_workloads()
    fresh = ClusterState(
        gpus={gid: GPUState(gid, device) for gid in state.gpus},
        workloads={w.wid: w for w in workloads},
    )
    fn = baselines.first_fit if policy == "first_fit" else baselines.load_balanced
    pending = fn(fresh, workloads)
    state.gpus = fresh.gpus
    state.workloads = fresh.workloads
    return pending


# ---------------------------------------------------------------------------
# per-use-case runners: (test case) -> final state (+ pending, solve time)
# ---------------------------------------------------------------------------
def _run_initial(tc: TestCase, approach: str, time_limit: float):
    st = tc.initial.clone()
    t0 = time.time()
    if approach == "first_fit":
        pending = baselines.first_fit(st, tc.new_workloads)
    elif approach == "load_balanced":
        pending = baselines.load_balanced(st, tc.new_workloads)
    elif approach == "rule_based":
        pending = heuristic.initial_deployment(st, tc.new_workloads)
    elif approach == "mip":
        res = solve_wpm(st, tc.new_workloads, movable=False, allow_reconfig=False,
                        time_limit=time_limit)
        st, pending = res.state, res.pending
    elif approach == "joint_mip":
        res = solve_wpm(st, tc.new_workloads, movable=True, allow_reconfig=True,
                        time_limit=time_limit)
        st, pending = res.state, res.pending
    else:
        raise ValueError(approach)
    return st, pending, time.time() - t0


def _run_compaction(tc: TestCase, approach: str, time_limit: float):
    st = tc.initial.clone()
    t0 = time.time()
    if approach in _SPOTS:
        baseline_compaction(st, approach)
    elif approach == "rule_based":
        heuristic.compaction(st)
    elif approach == "mip":
        res = solve_wpm(st, (), movable=True, allow_reconfig=True,
                        time_limit=time_limit)
        st = res.state
    else:
        raise ValueError(approach)
    return st, [], time.time() - t0


def _run_reconfiguration(tc: TestCase, approach: str, time_limit: float):
    st = tc.initial.clone()
    t0 = time.time()
    if approach in _SPOTS:
        pending = baseline_reconfiguration(st, approach)
    elif approach == "rule_based":
        pending = heuristic.reconfiguration(st)
    elif approach == "mip":
        res = solve_wpm(st, (), movable=True, allow_reconfig=True,
                        time_limit=time_limit)
        st, pending = res.state, res.pending
    elif approach == "patterns":
        res = reconfigure_patterns(st, time_limit=time_limit)
        st, pending = res.state, []
    else:
        raise ValueError(approach)
    return st, pending, time.time() - t0


_RUNNERS = {
    "initial": _run_initial,
    "compaction": _run_compaction,
    "reconfiguration": _run_reconfiguration,
}

APPROACHES = {
    "initial": ("first_fit", "load_balanced", "rule_based", "mip", "joint_mip"),
    "compaction": ("first_fit", "load_balanced", "rule_based", "mip"),
    "reconfiguration": ("first_fit", "load_balanced", "rule_based", "mip", "patterns"),
}

_METRICS = (
    "n_gpus", "memory_wastage", "compute_wastage", "availability",
    "migration_size", "pending_model_size", "sequential_migrations",
    "memory_utilization", "compute_utilization",
)


def run_case(
    case: str,
    n_gpus: int,
    n_cases: int,
    time_limit: float,
    mip_cases: Optional[int] = None,
    approaches: Optional[Sequence[str]] = None,
) -> Dict[str, Dict[str, float]]:
    """Returns {approach: {metric: mean}} plus solve-time and seq-migration."""
    approaches = approaches or APPROACHES[case]
    runner = _RUNNERS[case]
    sums: Dict[str, Dict[str, float]] = {a: {m: 0.0 for m in _METRICS} for a in approaches}
    counts: Dict[str, int] = {a: 0 for a in approaches}
    for a in approaches:
        sums[a]["solve_seconds"] = 0.0
        n = n_cases
        if mip_cases is not None and a in ("mip", "joint_mip", "patterns"):
            n = min(n, mip_cases)
        for seed in range(n):
            tc = generate_test_case(seed, n_gpus=n_gpus)
            # compaction/reconfiguration act on existing workloads only —
            # pending is null for them by construction (paper Sec 5.2.2)
            all_wl = list(tc.initial.workloads.values())
            if case == "initial":
                all_wl += list(tc.new_workloads)
            final, pending, secs = runner(tc, a, time_limit)
            final.validate()
            m = metrics.evaluate(final, tc.initial, all_wl)
            for k in _METRICS:
                sums[a][k] += float(getattr(m, k))
            sums[a]["solve_seconds"] += secs
            counts[a] += 1
    return {
        a: {k: v / max(counts[a], 1) for k, v in sums[a].items()} for a in approaches
    }


def normalize(table: Dict[str, Dict[str, float]]) -> Dict[str, Dict[str, float]]:
    """Paper-style: each metric normalized against its max across approaches."""
    out: Dict[str, Dict[str, float]] = {a: {} for a in table}
    keys = next(iter(table.values())).keys()
    for k in keys:
        mx = max(abs(table[a][k]) for a in table) or 1.0
        for a in table:
            out[a][k] = table[a][k] / mx
    return out


def print_table(case: str, n_gpus: int, table: Dict[str, Dict[str, float]]) -> None:
    norm = normalize(table)
    keys = list(next(iter(table.values())).keys())
    print(f"\n== {case} @ {n_gpus} GPUs (mean over cases; normalized in []) ==")
    header = "approach".ljust(15) + "".join(k[:14].rjust(16) for k in keys)
    print(header)
    for a, row in table.items():
        line = a.ljust(15)
        for k in keys:
            line += f"{row[k]:9.3f}[{norm[a][k]:4.2f}]".rjust(16)
        print(line)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--case", default="all",
                    choices=["initial", "compaction", "reconfiguration", "all"])
    ap.add_argument("--gpus", type=int, nargs="+", default=[8, 80])
    ap.add_argument("--cases", type=int, default=100)
    ap.add_argument("--mip-cases", type=int, default=None,
                    help="cap test cases for MIP approaches (big clusters)")
    ap.add_argument("--time-limit", type=float, default=30.0)
    args = ap.parse_args()
    cases = (
        ["initial", "compaction", "reconfiguration"]
        if args.case == "all" else [args.case]
    )
    for case in cases:
        for g in args.gpus:
            t0 = time.time()
            table = run_case(case, g, args.cases, args.time_limit, args.mip_cases)
            print_table(case, g, table)
            print(f"   ({time.time() - t0:.0f}s)")


if __name__ == "__main__":
    main()
