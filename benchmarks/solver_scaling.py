"""Beyond-paper: solver scaling study.

How do the four approaches scale with cluster size?  The paper reports only
8 vs 80 GPUs; here we sweep sizes and record wall time, objective quality
(#GPUs used), and MILP size — the computational-overhead argument of Sec 4.2
made quantitative.

Usage: python -m benchmarks.solver_scaling --sizes 8 16 32 80 --seeds 3
"""
from __future__ import annotations

import argparse
import time

from repro.core import heuristic, metrics
from repro.core.simulator import generate_test_case
from repro.core.wpm_mip import solve_wpm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+", default=[8, 16, 32, 80])
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--time-limit", type=float, default=30.0)
    args = ap.parse_args()

    print("size,approach,seconds,n_gpus,vars,cons,status")
    for size in args.sizes:
        for seed in range(args.seeds):
            tc = generate_test_case(seed, n_gpus=size)

            st = tc.initial.clone()
            t0 = time.time()
            heuristic.initial_deployment(st, tc.new_workloads)
            hsec = time.time() - t0
            hm = metrics.evaluate(st, tc.initial)
            print(f"{size},rule_based,{hsec:.3f},{hm.n_gpus},0,0,exact")

            t0 = time.time()
            res = solve_wpm(
                tc.initial.clone(), tc.new_workloads, movable=False,
                allow_reconfig=False, time_limit=args.time_limit,
            )
            mm = metrics.evaluate(res.state, tc.initial)
            print(
                f"{size},mip,{time.time() - t0:.3f},{mm.n_gpus},"
                f"{res.n_variables},{res.n_constraints},{res.status}"
            )


if __name__ == "__main__":
    main()
