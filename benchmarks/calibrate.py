"""Calibration driver: profile the kernels, write ``CALIBRATION.json``.

Runs the kernel calibration profiler (:mod:`repro.obs.profile`) over the
requested device models and problem-size preset, then writes the
schema-validated artifact that closes the measure -> model -> plan loop:

    python -m benchmarks.calibrate                         # small preset
    python -m benchmarks.calibrate --preset tiny           # CI smoke
    python -m benchmarks.calibrate --device A100-80GB H100-96GB \\
        --preset full --out CALIBRATION.json

Feed the artifact back into the planning stack:

    python -m benchmarks.placement_bench --autoscale \\
        --calibrated CALIBRATION.json          # measured-vs-table deltas

or load it directly: ``PerfModel.from_calibration("CALIBRATION.json")``.

``--telemetry`` additionally dumps the per-rep ``kernel_wall_seconds``
histograms (Prometheus text) recorded during the sweep.  The report always
carries a host-contention snapshot (``host.contended``) — treat timings
from a contended run as suspect (the driver warns loudly).
"""
from __future__ import annotations

import argparse
import logging
import sys

from repro import obs
from repro.core.profiles import A100_80GB, H100_96GB
from repro.core.tpu_profiles import TPU_V5E_POD
from repro.obs import profile

log = logging.getLogger("repro.bench.calibrate")

DEVICES = {d.name: d for d in (A100_80GB, H100_96GB, TPU_V5E_POD)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--device", nargs="+", default=["A100-80GB"],
                    choices=sorted(DEVICES), help="device models to calibrate")
    ap.add_argument("--preset", default="small",
                    choices=sorted(profile.PRESETS),
                    help="problem-size preset (tiny = CI smoke)")
    ap.add_argument("--reps", type=int, default=None,
                    help="timed reps per measurement (default: preset's)")
    ap.add_argument("--warmup", type=int, default=None,
                    help="discarded warm-up calls (default: preset's)")
    ap.add_argument("--impl", default=None, choices=["jnp", "pallas", "ref"],
                    help="kernel implementation (default: current, i.e. jnp)")
    ap.add_argument("--no-emulate", action="store_true",
                    help="do NOT apply slice fractions analytically — use "
                    "when running inside a real MIG GPU instance")
    ap.add_argument("--out", default="CALIBRATION.json",
                    help="artifact path ('' = stdout summary only)")
    ap.add_argument("--telemetry", action="store_true",
                    help="dump kernel_wall_seconds histograms "
                    "(Prometheus text) next to the artifact")
    ap.add_argument("--verbose", "-v", action="store_true")
    args = ap.parse_args(argv)

    logging.basicConfig(
        stream=sys.stderr,
        level=logging.DEBUG if args.verbose else logging.INFO,
        format="%(message)s",
    )

    tel = obs.enable() if args.telemetry else None
    report = profile.run_calibration(
        devices=[DEVICES[n] for n in args.device],
        preset=args.preset,
        reps=args.reps,
        warmup=args.warmup,
        emulate=not args.no_emulate,
        impl=args.impl,
    )

    for name, entry in report["devices"].items():
        whole = entry["whole_device"]
        log.info(
            "%-20s prefill %10.0f tok/s   decode %8.0f tok/s   "
            "fitted parallel_efficiency %.3f",
            name, whole["prefill_tokens_per_s"], whole["decode_tokens_per_s"],
            entry["parallel_efficiency"],
        )
        for pid, prof in entry["profiles"].items():
            log.info("  %-12s (id %2s)  prefill %10.0f  decode %8.0f",
                     prof["name"], pid, prof["prefill_tokens_per_s"],
                     prof["decode_tokens_per_s"])
    if report["host"]["contended"]:
        log.warning("host was contended during the sweep — artifact carries "
                    "contended=true; re-run on a quiet machine before "
                    "committing these numbers")

    if obs.write_report(args.out, report, profile.CALIBRATION_SCHEMA):
        log.info("wrote %s", args.out)
        log.info("load with: PerfModel.from_calibration(%r)", args.out)
    if tel is not None:
        prom = (args.out or "CALIBRATION") + ".prom"
        with open(prom, "w") as f:
            f.write(obs.prometheus_text(tel.metrics))
        log.info("wrote %s", prom)
        obs.disable()
    return 0


if __name__ == "__main__":
    sys.exit(main())
