"""Kernel micro-bench: per-call wall time of the jnp execution path on CPU
plus analytic FLOPs (the TPU-relevant number is the FLOPs/bytes profile; the
CPU microseconds only sanity-check that the memory-efficient paths run).

Usage: python -m benchmarks.kernel_bench
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops


def _timeit(fn, *args, n=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6  # us


def main() -> None:
    key = jax.random.key(0)
    print("kernel,shape,us_per_call,gflops_analytic")

    # flash attention (prefill): B=1, S=2048, Hq=8, Hkv=2, D=64
    b, s, hq, hkv, d = 1, 2048, 8, 2, 64
    q = jax.random.normal(key, (b, s, hq, d), jnp.float32)
    k = jax.random.normal(key, (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(key, (b, s, hkv, d), jnp.float32)
    fa = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, causal=True))
    us = _timeit(fa, q, k, v)
    gf = 4 * b * s * s * hq * d / 2 / 1e9  # causal halves the score matmul
    print(f"flash_attention,B{b}xS{s}xH{hq}/{hkv}xD{d},{us:.0f},{gf:.2f}")

    # decode attention: B=32, Smax=8192
    b, smax = 32, 8192
    q = jax.random.normal(key, (b, 1, hq, d), jnp.float32)
    k = jax.random.normal(key, (b, smax, hkv, d), jnp.float32)
    v = jax.random.normal(key, (b, smax, hkv, d), jnp.float32)
    lens = jnp.full((b,), smax // 2, jnp.int32)
    da = jax.jit(lambda q, k, v, l: ops.decode_attention(q, k, v, l))
    us = _timeit(da, q, k, v, lens)
    gf = 4 * b * smax * hq * d / 1e9
    print(f"decode_attention,B{b}xS{smax}ragged,{us:.0f},{gf:.2f}")

    # SSD scan: B=2, S=1024, H=4, P=32, N=16
    b, s, h, p, n = 2, 1024, 4, 32, 16
    x = jax.random.normal(key, (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(key, (b, s, h), jnp.float32))
    A = -jnp.ones((h,), jnp.float32)
    B_ = jax.random.normal(key, (b, s, n), jnp.float32)
    C = jax.random.normal(key, (b, s, n), jnp.float32)
    sc = jax.jit(lambda *a: ops.ssd_scan(*a, chunk=256))
    us = _timeit(sc, x, dt, A, B_, C)
    gf = (2 * b * s * h * p * n * 2) / 1e9
    print(f"ssd_scan,B{b}xS{s}xH{h}xP{p}xN{n},{us:.0f},{gf:.2f}")


if __name__ == "__main__":
    main()
