"""Kernel micro-bench: per-call wall time of the jnp execution path on CPU
plus analytic FLOPs (the TPU-relevant number is the FLOPs/bytes profile; the
CPU microseconds only sanity-check that the memory-efficient paths run).

Shapes come from the calibration profiler's presets
(:data:`repro.obs.profile.PRESETS`) at whole-device size, so this bench and
``benchmarks/calibrate.py`` measure the same workloads.  Besides the
human-readable CSV on stdout, every run emits a machine-readable
``BENCH_kernels.json`` (same strict-JSON writer as ``placement_bench``)
with p50/p95 per kernel — the rows the ``validate_bench.py --baseline``
regression gate compares across commits.  A host-contention snapshot is
recorded (``host.contended``): timings taken next to a stale ``pytest`` or
a concurrent bench are flagged rather than silently trusted.

Usage: python -m benchmarks.kernel_bench [--preset full] [--json PATH]
"""
from __future__ import annotations

import argparse
import logging
import sys
import time
from typing import Dict, List

from repro import obs
from repro.obs import profile

log = logging.getLogger("repro.bench.kernels")

#: schema tag of BENCH_kernels.json (validate_bench checks it).
KERNEL_BENCH_SCHEMA = "kernel_bench/v1"


def _timeit(fn, *args, n: int = 5, warmup: int = 1) -> List[float]:
    """Per-call wall times in seconds: ``warmup`` discarded calls (compile +
    caches), then ``n`` individually-timed synchronized calls.

    ``jax.block_until_ready`` handles tuple-returning ops (it synchronizes
    arbitrary pytrees), so each iteration invokes ``fn`` exactly once.
    """
    import jax

    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn(*args))
    walls = []
    for _ in range(max(n, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        walls.append(time.perf_counter() - t0)
    return walls


def run(preset: str = "full", reps: int = None, warmup: int = None
        ) -> Dict[str, Dict[str, float]]:
    """Run the preset's whole-device workloads; returns the ``kernels``
    section rows keyed ``kernel@shape``."""
    cfg = profile.PRESETS[preset]
    reps = int(cfg["reps"] if reps is None else reps)
    warmup = int(cfg["warmup"] if warmup is None else warmup)
    rows: Dict[str, Dict[str, float]] = {}
    print("kernel,shape,us_per_call,gflops_analytic")
    for wl in profile.whole_device_specs(preset):
        fn, args = wl.make()
        walls = sorted(_timeit(fn, *args, n=reps, warmup=warmup))
        timing = profile.KernelTiming(tuple(walls))
        p50 = timing.p50
        rows[f"{wl.kernel}@{wl.shape}"] = {
            "p50_us": p50 * 1e6,
            "p95_us": timing.p95 * 1e6,
            "min_us": walls[0] * 1e6,
            "mean_us": sum(walls) / len(walls) * 1e6,
            "reps": reps,
            "gflops_analytic": wl.flops / 1e9,
            "achieved_gflops_per_s": wl.flops / p50 / 1e9,
            "achieved_gbytes_per_s": wl.bytes / p50 / 1e9,
            "tokens_per_s": wl.tokens / p50,
        }
        print(f"{wl.kernel},{wl.shape},{p50 * 1e6:.0f},{wl.flops / 1e9:.2f}")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="full",
                    choices=sorted(profile.PRESETS))
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--warmup", type=int, default=None)
    ap.add_argument("--json", default="BENCH_kernels.json",
                    help="machine-readable output path ('' disables)")
    ap.add_argument("--verbose", "-v", action="store_true")
    args = ap.parse_args(argv)

    logging.basicConfig(
        stream=sys.stderr,
        level=logging.DEBUG if args.verbose else logging.INFO,
        format="%(message)s",
    )

    host = obs.host_snapshot()
    report = {
        "args": {"preset": args.preset, "reps": args.reps,
                 "warmup": args.warmup},
        "host": host,
        "kernels": run(args.preset, args.reps, args.warmup),
    }
    if obs.write_report(args.json, report, KERNEL_BENCH_SCHEMA):
        log.info("wrote %s%s", args.json,
                 " (CONTENDED host — timings suspect)"
                 if host["contended"] else "")
    return 0


if __name__ == "__main__":
    sys.exit(main())
