"""Strict-parse + schema-check bench artifacts, and the perf regression gate.

Two jobs, both CI-facing:

**Schema validation.**  Every machine-readable artifact the benches emit
promises *strict* JSON (no bare ``NaN``/``Infinity`` tokens) and a stable
shape, dispatched on the ``schema`` field:

* ``placement_bench/v1`` — ``BENCH_*.json`` from ``placement_bench``: at
  least one result section, monotone ``planner_latency`` percentiles;
* ``kernel_bench/v1``    — ``BENCH_kernels.json`` from ``kernel_bench``:
  non-empty per-kernel rows with ``p50_us <= p95_us``;
* ``failures_bench/v1``  — ``BENCH_failures.json`` from
  ``placement_bench --faults``: non-empty fault rows with the
  recovery columns, finite per-commit ``slo_retention`` numbers, and
  ``fault_byte_identity`` strictly True (a wired-but-empty injector
  must not perturb the trace);
* ``calibration/v1``     — ``CALIBRATION.json`` from ``calibrate``:
  per-device whole-device rates (positive, finite), a fitted
  ``parallel_efficiency`` in (0, 1], and raw measurement rows.

**Regression gate** (``--baseline``).  Compares the current reports'
planner-latency p50/p95 and kernel-wall p50/p95 against a committed
``BENCH_baseline.json`` with a fractional tolerance; any metric that
drifts past ``baseline * (1 + tolerance)`` is a violation and the exit
code goes non-zero — unless ``--warn-only`` (the CI setting until a
baseline taken on quiet dedicated hardware is committed, and the right
mode whenever ``host.contended`` is true in a report).  Create or refresh
the baseline from the current reports with ``--write-baseline``.

    python -m benchmarks.validate_bench BENCH_placement.json ...
    python -m benchmarks.validate_bench BENCH_kernels.json \\
        --baseline BENCH_baseline.json [--tolerance 0.5] [--warn-only]
    python -m benchmarks.validate_bench BENCH_kernels.json \\
        --baseline BENCH_baseline.json --write-baseline

Exits non-zero listing every violation.
"""
import argparse
import json
import math
import sys
import time
from typing import Dict, List, Tuple

PLACEMENT_SCHEMA = "placement_bench/v1"
KERNEL_SCHEMA = "kernel_bench/v1"
FAILURES_SCHEMA = "failures_bench/v1"
CALIBRATION_SCHEMA = "calibration/v1"
BASELINE_SCHEMA = "bench_baseline/v1"

#: at least one of these result sections must be present (placement).
SECTIONS = ("snapshot", "trace", "autoscale", "fleet_scale")
PCTL_KEYS = ("count", "total_s", "p50_s", "p95_s", "p99_s")
#: default fractional headroom before a drift counts as a regression.
DEFAULT_TOLERANCE = 0.5


def _reject_constant(token: str):
    raise ValueError(f"non-strict JSON constant {token!r}")


def _load_strict(path: str):
    with open(path) as f:
        # parse_constant fires on NaN/Infinity/-Infinity — the exact
        # tokens json.dump(allow_nan=True) would have emitted.
        return json.load(f, parse_constant=_reject_constant)


def _check_host(path: str, rep: Dict, errors: List[str]) -> None:
    host = rep.get("host")
    if host is None:
        return  # optional section (older reports)
    if not isinstance(host, dict) or not isinstance(
        host.get("contended"), bool
    ):
        errors.append(f"{path}: host section lacks boolean 'contended'")


def _validate_placement(path: str, rep: Dict, errors: List[str]) -> None:
    if not any(k in rep for k in SECTIONS):
        errors.append(f"{path}: no result section (one of {SECTIONS})")
    _check_planner_latency(path, rep, errors)


def _check_planner_latency(path: str, rep: Dict, errors: List[str]) -> None:
    lat = rep.get("planner_latency")
    if lat is not None:
        if not isinstance(lat, dict):
            errors.append(f"{path}: planner_latency is not an object")
        else:
            for verb, row in lat.items():
                missing = [k for k in PCTL_KEYS if k not in row]
                if missing:
                    errors.append(
                        f"{path}: planner_latency[{verb!r}] missing {missing}"
                    )
                    continue
                if not row["p50_s"] <= row["p95_s"] <= row["p99_s"]:
                    errors.append(
                        f"{path}: planner_latency[{verb!r}] percentiles "
                        f"not monotone: {row}"
                    )
                if row["count"] <= 0:
                    errors.append(
                        f"{path}: planner_latency[{verb!r}] empty ({row})"
                    )


def _validate_kernels(path: str, rep: Dict, errors: List[str]) -> None:
    kernels = rep.get("kernels")
    if not isinstance(kernels, dict) or not kernels:
        errors.append(f"{path}: missing non-empty kernels object")
        return
    for key, row in kernels.items():
        if not isinstance(row, dict):
            errors.append(f"{path}: kernels[{key!r}] is not an object")
            continue
        missing = [k for k in ("p50_us", "p95_us", "reps") if k not in row]
        if missing:
            errors.append(f"{path}: kernels[{key!r}] missing {missing}")
            continue
        if not row["p50_us"] <= row["p95_us"]:
            errors.append(
                f"{path}: kernels[{key!r}] p50 > p95: {row['p50_us']} > "
                f"{row['p95_us']}"
            )
        if row["reps"] <= 0 or row["p50_us"] <= 0:
            errors.append(f"{path}: kernels[{key!r}] non-positive ({row})")


def _validate_calibration(path: str, rep: Dict, errors: List[str]) -> None:
    devices = rep.get("devices")
    if not isinstance(devices, dict) or not devices:
        errors.append(f"{path}: missing non-empty devices object")
        return
    for name, entry in devices.items():
        whole = entry.get("whole_device") if isinstance(entry, dict) else None
        if not isinstance(whole, dict):
            errors.append(f"{path}: devices[{name!r}] missing whole_device")
            continue
        for k in ("prefill_tokens_per_s", "decode_tokens_per_s"):
            v = whole.get(k)
            if not isinstance(v, (int, float)) or not math.isfinite(v) or v <= 0:
                errors.append(
                    f"{path}: devices[{name!r}].whole_device.{k} not a "
                    f"positive finite number: {v!r}"
                )
        e = entry.get("parallel_efficiency")
        if not isinstance(e, (int, float)) or not 0.0 < e <= 1.0:
            errors.append(
                f"{path}: devices[{name!r}].parallel_efficiency not in "
                f"(0, 1]: {e!r}"
            )
        if not entry.get("profiles"):
            errors.append(f"{path}: devices[{name!r}] has no profiles")
    kernels = rep.get("kernels")
    if not isinstance(kernels, list) or not kernels:
        errors.append(f"{path}: missing non-empty kernels measurement list")
    else:
        for i, row in enumerate(kernels):
            missing = [
                k for k in ("kernel", "device", "profile_id", "wall_s")
                if not isinstance(row, dict) or k not in row
            ]
            if missing:
                errors.append(f"{path}: kernels[{i}] missing {missing}")


#: recovery columns every ``--faults`` row must carry.
FAULT_ROW_KEYS = (
    "slo_attainment", "n_gpu_failures", "n_node_drains", "n_fault_evictions",
    "n_fault_recovered", "n_recovery_pending", "recovery_seconds_max",
    "capacity_lost_gpu_seconds", "n_requeued_requests", "n_shed_requests",
)


def _validate_failures(path: str, rep: Dict, errors: List[str]) -> None:
    section = rep.get("faults")
    if not isinstance(section, dict):
        errors.append(f"{path}: missing faults section")
        return
    rows = section.get("rows")
    if not isinstance(rows, dict) or not rows:
        errors.append(f"{path}: faults.rows missing or empty")
    else:
        for key, row in rows.items():
            if not isinstance(row, dict):
                errors.append(f"{path}: faults.rows[{key!r}] is not an object")
                continue
            missing = [k for k in FAULT_ROW_KEYS if k not in row]
            if missing:
                errors.append(f"{path}: faults.rows[{key!r}] missing {missing}")
    retention = section.get("retention")
    if not isinstance(retention, dict) or not retention:
        errors.append(f"{path}: faults.retention missing or empty")
    else:
        for commit, r in retention.items():
            v = r.get("slo_retention") if isinstance(r, dict) else None
            if not isinstance(v, (int, float)) or not math.isfinite(v) or v < 0:
                errors.append(
                    f"{path}: faults.retention[{commit!r}].slo_retention not "
                    f"a finite non-negative number: {v!r}"
                )
    if section.get("fault_byte_identity") is not True:
        errors.append(
            f"{path}: fault_byte_identity is "
            f"{section.get('fault_byte_identity')!r} — an empty injector "
            f"perturbed the trace (determinism contract broken)"
        )
    if not isinstance(section.get("fault_events"), list):
        errors.append(f"{path}: faults.fault_events missing (schedule list)")
    _check_planner_latency(path, rep, errors)


_VALIDATORS = {
    PLACEMENT_SCHEMA: _validate_placement,
    KERNEL_SCHEMA: _validate_kernels,
    CALIBRATION_SCHEMA: _validate_calibration,
    FAILURES_SCHEMA: _validate_failures,
}


def validate(path: str) -> List[str]:
    """All violations found in one report file (empty list = valid)."""
    errors: List[str] = []
    try:
        rep = _load_strict(path)
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable or non-strict JSON: {e}"]

    if not isinstance(rep, dict):
        return [f"{path}: top level is {type(rep).__name__}, expected object"]
    schema = rep.get("schema")
    checker = _VALIDATORS.get(schema)
    if checker is None:
        return [
            f"{path}: schema={schema!r}, expected one of "
            f"{sorted(_VALIDATORS)}"
        ]
    if not isinstance(rep.get("generated_unix"), (int, float)):
        errors.append(f"{path}: missing numeric generated_unix")
    if schema != CALIBRATION_SCHEMA and not isinstance(rep.get("args"), dict):
        errors.append(f"{path}: missing args object")
    _check_host(path, rep, errors)
    checker(path, rep, errors)
    return errors


# ---------------------------------------------------------------------------
# regression gate
# ---------------------------------------------------------------------------
def collect_metrics(reports: List[Tuple[str, Dict]]) -> Dict[str, Dict[str, float]]:
    """Gate-able latency metrics from parsed reports.

    Keys: ``planner_latency/<verb@policy>`` (p50/p95 seconds) and
    ``kernels/<kernel@shape>`` (p50/p95 microseconds) — lower is better
    for every metric the gate watches.
    """
    out: Dict[str, Dict[str, float]] = {}
    for _, rep in reports:
        if not isinstance(rep, dict):
            continue
        for verb, row in (rep.get("planner_latency") or {}).items():
            if isinstance(row, dict) and "p50_s" in row and "p95_s" in row:
                out[f"planner_latency/{verb}"] = {
                    "p50": float(row["p50_s"]), "p95": float(row["p95_s"]),
                }
        if rep.get("schema") == KERNEL_SCHEMA:
            for key, row in (rep.get("kernels") or {}).items():
                if isinstance(row, dict) and "p50_us" in row:
                    out[f"kernels/{key}"] = {
                        "p50": float(row["p50_us"]), "p95": float(row["p95_us"]),
                    }
    return out


def gate(
    current: Dict[str, Dict[str, float]],
    baseline: Dict,
    tolerance: float = None,
) -> Tuple[List[str], List[str]]:
    """(violations, notes) of current metrics vs a baseline report."""
    violations: List[str] = []
    notes: List[str] = []
    tol = tolerance if tolerance is not None else float(
        baseline.get("tolerance", DEFAULT_TOLERANCE)
    )
    base_metrics = baseline.get("metrics") or {}
    for key, base in base_metrics.items():
        cur = current.get(key)
        if cur is None:
            notes.append(f"baseline metric {key!r} absent from current "
                         f"reports (renamed or dropped?)")
            continue
        for q in ("p50", "p95"):
            b, c = base.get(q), cur.get(q)
            if b is None or c is None or b <= 0:
                continue
            if c > b * (1.0 + tol):
                violations.append(
                    f"{key} {q}: {c:.6g} exceeds baseline {b:.6g} "
                    f"by more than {tol:.0%}"
                )
            elif c < b / (1.0 + tol):
                notes.append(
                    f"{key} {q}: {c:.6g} well below baseline {b:.6g} — "
                    f"consider refreshing the baseline (--write-baseline)"
                )
    for key in current:
        if key not in base_metrics:
            notes.append(f"new metric {key!r} not in baseline yet")
    return violations, notes


def write_baseline(path: str, current: Dict[str, Dict[str, float]],
                   tolerance: float) -> None:
    with open(path, "w") as f:
        json.dump(
            {
                "schema": BASELINE_SCHEMA,
                "generated_unix": time.time(),
                "tolerance": tolerance,
                "metrics": current,
            },
            f, indent=2, sort_keys=True, allow_nan=False,
        )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("reports", nargs="+",
                    help="BENCH_*.json / CALIBRATION.json paths")
    ap.add_argument("--baseline", default=None, metavar="BENCH_baseline.json",
                    help="regression-gate the reports against this baseline "
                    "(missing file = gate skipped with a warning)")
    ap.add_argument("--tolerance", type=float, default=None,
                    help=f"fractional drift allowed before failing "
                    f"(default: baseline's own, else {DEFAULT_TOLERANCE})")
    ap.add_argument("--warn-only", action="store_true",
                    help="report regressions but exit 0 (schema violations "
                    "still fail) — the CI mode until a baseline from quiet "
                    "dedicated hardware is committed")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write/refresh the --baseline file from the "
                    "current reports instead of gating")
    args = ap.parse_args(argv)

    failures: List[str] = []
    parsed: List[Tuple[str, Dict]] = []
    for path in args.reports:
        errs = validate(path)
        failures.extend(errs)
        print(f"{path}: {'OK' if not errs else f'{len(errs)} violation(s)'}",
              file=sys.stderr)
        if not errs:
            parsed.append((path, _load_strict(path)))

    if args.baseline:
        current = collect_metrics(parsed)
        if args.write_baseline:
            tol = args.tolerance if args.tolerance is not None else DEFAULT_TOLERANCE
            write_baseline(args.baseline, current, tol)
            print(f"wrote baseline {args.baseline} "
                  f"({len(current)} metric(s), tolerance {tol:.0%})",
                  file=sys.stderr)
        else:
            try:
                baseline = _load_strict(args.baseline)
            except OSError:
                print(f"regression gate SKIPPED: no baseline at "
                      f"{args.baseline} (commit one with --write-baseline)",
                      file=sys.stderr)
                baseline = None
            except ValueError as e:
                failures.append(f"{args.baseline}: unreadable baseline: {e}")
                baseline = None
            if baseline is not None:
                if baseline.get("schema") != BASELINE_SCHEMA:
                    failures.append(
                        f"{args.baseline}: schema="
                        f"{baseline.get('schema')!r}, expected "
                        f"{BASELINE_SCHEMA!r}"
                    )
                else:
                    violations, notes = gate(current, baseline, args.tolerance)
                    for n in notes:
                        print(f"  note: {n}", file=sys.stderr)
                    if violations and args.warn_only:
                        for v in violations:
                            print(f"  WARN (gate): {v}", file=sys.stderr)
                        print(f"regression gate: {len(violations)} drift(s) "
                              f"— warn-only, not failing", file=sys.stderr)
                    else:
                        failures.extend(f"gate: {v}" for v in violations)
                        if not violations:
                            print("regression gate: OK", file=sys.stderr)

    for e in failures:
        print(f"  {e}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
