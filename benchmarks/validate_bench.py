"""Strict-parse + schema-check ``BENCH_*.json`` reports (CI gate).

The bench promises *strict* JSON — no bare ``NaN``/``Infinity`` tokens —
and a stable top-level shape (``schema: placement_bench/v1`` plus at least
one result section).  CI runs this validator over every report the smoke
steps produced, so a regression in ``write_json`` (or a new section that
forgets to sanitize) fails the build instead of silently shipping a file
half the world's JSON parsers reject.

    python -m benchmarks.validate_bench BENCH_placement.json [...]

Exits non-zero listing every violation.  When a report carries a
``planner_latency`` section (``--telemetry`` runs), each entry must have
count/total_s/p50_s/p95_s/p99_s with p50 <= p95 <= p99.
"""
import argparse
import json
import sys
from typing import List

SCHEMA = "placement_bench/v1"
#: at least one of these result sections must be present
SECTIONS = ("snapshot", "trace", "autoscale", "fleet_scale")
PCTL_KEYS = ("count", "total_s", "p50_s", "p95_s", "p99_s")


def _reject_constant(token: str):
    raise ValueError(f"non-strict JSON constant {token!r}")


def validate(path: str) -> List[str]:
    """All violations found in one report file (empty list = valid)."""
    errors: List[str] = []
    try:
        with open(path) as f:
            # parse_constant fires on NaN/Infinity/-Infinity — the exact
            # tokens json.dump(allow_nan=True) would have emitted.
            rep = json.load(f, parse_constant=_reject_constant)
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable or non-strict JSON: {e}"]

    if not isinstance(rep, dict):
        return [f"{path}: top level is {type(rep).__name__}, expected object"]
    if rep.get("schema") != SCHEMA:
        errors.append(f"{path}: schema={rep.get('schema')!r}, expected {SCHEMA!r}")
    if not isinstance(rep.get("generated_unix"), (int, float)):
        errors.append(f"{path}: missing numeric generated_unix")
    if not isinstance(rep.get("args"), dict):
        errors.append(f"{path}: missing args object")
    if not any(k in rep for k in SECTIONS):
        errors.append(f"{path}: no result section (one of {SECTIONS})")

    lat = rep.get("planner_latency")
    if lat is not None:
        if not isinstance(lat, dict):
            errors.append(f"{path}: planner_latency is not an object")
        else:
            for verb, row in lat.items():
                missing = [k for k in PCTL_KEYS if k not in row]
                if missing:
                    errors.append(
                        f"{path}: planner_latency[{verb!r}] missing {missing}"
                    )
                    continue
                if not row["p50_s"] <= row["p95_s"] <= row["p99_s"]:
                    errors.append(
                        f"{path}: planner_latency[{verb!r}] percentiles "
                        f"not monotone: {row}"
                    )
                if row["count"] <= 0:
                    errors.append(
                        f"{path}: planner_latency[{verb!r}] empty ({row})"
                    )
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("reports", nargs="+", help="BENCH_*.json paths")
    args = ap.parse_args(argv)
    failures: List[str] = []
    for path in args.reports:
        errs = validate(path)
        failures.extend(errs)
        print(f"{path}: {'OK' if not errs else f'{len(errs)} violation(s)'}",
              file=sys.stderr)
    for e in failures:
        print(f"  {e}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
