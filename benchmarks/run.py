"""Benchmark driver: one section per paper table/figure + the beyond-paper
studies.  ``python -m benchmarks.run`` (add --quick for a fast smoke pass,
--full for the paper-exact 100-case MIP runs at 80 GPUs).

Sections:
  [1] Fig 9  initial deployment    (placement_bench)
  [2] Fig 10 compaction            (placement_bench)
  [3] Fig 11 reconfiguration       (placement_bench)
  [4] solver scaling               (beyond paper)
  [5] kernel micro-bench           (serving substrate)
  [6] roofline table               (from dry-run artifacts)
"""
from __future__ import annotations

import argparse
import time

from . import kernel_bench, roofline, solver_scaling
from .placement_bench import print_table, run_case


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small smoke pass")
    ap.add_argument("--full", action="store_true",
                    help="paper-exact: 100 MIP cases at 80 GPUs, 30s cap")
    args = ap.parse_args()

    if args.quick:
        cases8, cases80, mip80, tl8, tl80 = 10, 10, 2, 5.0, 10.0
    elif args.full:
        cases8, cases80, mip80, tl8, tl80 = 100, 100, 100, 30.0, 30.0
    else:
        cases8, cases80, mip80, tl8, tl80 = 100, 100, 8, 10.0, 30.0

    t00 = time.time()
    for i, case in enumerate(("initial", "compaction", "reconfiguration"), 1):
        print(f"\n######## [{i}] paper Fig {8 + i}: {case} ########")
        t0 = time.time()
        table = run_case(case, 8, cases8, tl8)
        print_table(case, 8, table)
        print(f"   ({time.time() - t0:.0f}s, {cases8} cases, MIP cap {tl8}s)")
        t0 = time.time()
        table = run_case(case, 80, cases80, tl80, mip_cases=mip80)
        print_table(case, 80, table)
        print(f"   ({time.time() - t0:.0f}s, {cases80} cases "
              f"[MIP on first {mip80}], MIP cap {tl80}s)")

    print("\n######## [4] solver scaling (beyond paper) ########")
    import sys

    argv = sys.argv
    sys.argv = ["solver_scaling", "--sizes", "8", "16", "32",
                "--seeds", "2", "--time-limit", "10"]
    if args.full:
        sys.argv += ["80"]
    try:
        solver_scaling.main()
    finally:
        sys.argv = argv

    print("\n######## [5] kernel micro-bench ########")
    kernel_bench.main()

    print("\n######## [6] roofline table (dry-run artifacts) ########")
    cells = roofline.load_cells()
    roofline.print_report(cells)

    print(f"\ntotal: {time.time() - t00:.0f}s")


if __name__ == "__main__":
    main()
