"""Roofline report: read the dry-run artifacts and print the three-term
roofline (compute / memory / collective seconds) per (arch x shape x mesh),
the dominant term, and the useful-FLOPs ratio.

Also the perf-iteration driver: --cell re-lowers one cell with overrides
(sharding / remat / moe impl) and prints the delta against the stored
baseline — the hypothesis->change->measure loop of EXPERIMENTS.md §Perf.

Usage:
  python -m benchmarks.roofline                      # full table from artifacts
  python -m benchmarks.roofline --mesh pod16x16      # one mesh
  python -m benchmarks.roofline --cell deepseek-v3-671b train_4k --sp --tag sp1
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load_cells(root: str = "artifacts/dryrun", mesh: str = "*",
               variants: bool = False) -> List[Dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(root, mesh, "*.json"))):
        tagged = os.path.basename(f).count("__") > 1  # arch__shape__tag.json
        if tagged != variants:
            continue
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def print_report(cells: List[Dict], only_mesh: str = "") -> None:
    hdr = (
        f"{'arch':24} {'shape':12} {'mesh':11} {'status':8} "
        f"{'compute_s':>10} {'memory_s':>10} {'coll_s':>10} {'dominant':>12} "
        f"{'useful':>7} {'frac':>6}"
    )
    print(hdr)
    print("-" * len(hdr))
    worst = None
    most_coll = None
    for c in cells:
        if only_mesh and c["mesh"] != only_mesh:
            continue
        if c["status"] != "ok":
            print(f"{c['arch']:24} {c['shape']:12} {c['mesh']:11} {c['status']:8} "
                  f"{c.get('reason', c.get('error', ''))[:60]}")
            continue
        r = c["roofline"]
        tot = r["compute_s"] + r["memory_s"] + r["collective_s"]
        # roofline fraction: useful compute time over the bound (max term)
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = (c["model_flops"] / c["n_devices"] / 197e12) / bound if bound else 0.0
        print(
            f"{c['arch']:24} {c['shape']:12} {c['mesh']:11} ok       "
            f"{r['compute_s']:10.4f} {r['memory_s']:10.4f} {r['collective_s']:10.4f} "
            f"{r['dominant']:>12} {c['useful_ratio']:7.3f} {frac:6.3f}"
        )
        key = (c["arch"], c["shape"], c["mesh"])
        if worst is None or frac < worst[1]:
            worst = (key, frac)
        cf = r["collective_s"] / tot if tot else 0
        if most_coll is None or cf > most_coll[1]:
            most_coll = (key, cf)
    if worst:
        print(f"\nworst roofline fraction : {worst[0]} ({worst[1]:.4f})")
        print(f"most collective-bound   : {most_coll[0]} ({most_coll[1]:.2%} of terms)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="")
    ap.add_argument("--cell", nargs=2, metavar=("ARCH", "SHAPE"), default=None)
    ap.add_argument("--multi", action="store_true", help="--cell on the 512-chip mesh")
    ap.add_argument("--sp", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--moe-impl", default="alltoall", choices=["dispatch", "alltoall"])
    ap.add_argument("--kv-quant", action="store_true", help="int8 KV cache")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    if args.cell:
        # perf-iteration mode: re-lower one cell with overrides
        from repro.launch.dryrun import run_cell  # sets XLA_FLAGS on import

        arch, shape = args.cell
        cell = run_cell(
            arch, shape, args.multi, sp=args.sp, fsdp=not args.no_fsdp,
            moe_impl=args.moe_impl, kv_quant=args.kv_quant,
            out_dir=args.root, tag=args.tag,
        )
        if cell["status"] != "ok":
            print(cell.get("error", cell.get("reason")))
            return
        base_f = os.path.join(
            args.root, cell["mesh"], f"{arch}__{shape}.json"
        )
        r = cell["roofline"]
        print(f"\n{arch} x {shape} x {cell['mesh']} [{args.tag or 'variant'}]")
        print(f"  compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
              f"collective={r['collective_s']:.4f}s dom={r['dominant']} "
              f"useful={cell['useful_ratio']:.3f}")
        if os.path.exists(base_f) and args.tag:
            with open(base_f) as fh:
                base = json.load(fh)
            if base.get("status") == "ok":
                b = base["roofline"]
                for k in ("compute_s", "memory_s", "collective_s"):
                    d = (r[k] - b[k]) / b[k] * 100 if b[k] else 0.0
                    print(f"  {k}: {b[k]:.4f} -> {r[k]:.4f} ({d:+.1f}%)")
        return

    cells = load_cells(args.root, args.mesh or "*")
    print_report(cells, args.mesh)


if __name__ == "__main__":
    main()
