"""ArchConfig -> runnable model bundle: init / loss / prefill / decode +
ShapeDtypeStruct input specs for every assigned input shape."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from .transformer import Model

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig

    @property
    def model(self) -> Model:
        return Model(self.cfg)

    # ---- init --------------------------------------------------------------
    def init(self, key) -> Params:
        return self.model.init(key)

    def param_shapes(self) -> Params:
        """ShapeDtypeStruct pytree without materializing anything."""
        return jax.eval_shape(self.init, jax.random.key(0))

    def param_count(self) -> int:
        import math

        return sum(math.prod(l.shape) for l in jax.tree.leaves(self.param_shapes()))

    def active_param_count(self) -> int:
        """Per-token active params (MoE: only routed-in experts count)."""
        import math

        cfg = self.cfg
        total = self.param_count()
        if not cfg.n_experts:
            return total
        shapes = self.param_shapes()
        expert_total = 0
        for g in shapes["groups"]:
            if "moe" in g:
                e = g["moe"]["experts"]
                expert_total += sum(math.prod(l.shape) for l in jax.tree.leaves(e))
        active_frac = cfg.experts_per_token / cfg.n_experts
        return int(total - expert_total * (1 - active_frac))

    # ---- steps --------------------------------------------------------------
    def loss_fn(self, params: Params, batch: Dict[str, jnp.ndarray]):
        return self.model.loss(params, batch)

    def prefill_fn(
        self, params: Params, batch: Dict[str, jnp.ndarray], max_len: int
    ) -> Tuple[jnp.ndarray, Params]:
        """Full-sequence forward that returns logits + a filled cache."""
        b, s = batch["tokens"].shape
        enc_len = self.cfg.frontend_len if self.cfg.enc_dec else 0
        cache = self.model.init_cache(b, max_len, enc_len)
        logits, cache, _ = self.model.forward(params, batch, cache=cache)
        return logits, cache

    def decode_fn(
        self,
        params: Params,
        cache: Params,
        tokens: jnp.ndarray,  # (B, 1)
        index: jnp.ndarray,  # scalar current position
    ) -> Tuple[jnp.ndarray, Params]:
        b = tokens.shape[0]
        positions = jnp.broadcast_to(index, (b, 1))
        logits, cache, _ = self.model.forward(
            params, {"tokens": tokens}, cache=cache, positions=positions
        )
        return logits, cache

    # ---- input specs ----------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for the step function's inputs."""
        cfg = self.cfg
        b = shape.global_batch
        s = shape.seq_len
        i32 = jnp.int32

        def tok(bb, ss):
            return jax.ShapeDtypeStruct((bb, ss), i32)

        if shape.kind in ("train", "prefill"):
            batch: Dict[str, Any] = {"tokens": tok(b, s)}
            if cfg.frontend == "vit":
                batch["patch_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.frontend_len, cfg.frontend_dim), jnp.dtype(cfg.dtype)
                )
            if cfg.enc_dec:
                batch["frames"] = jax.ShapeDtypeStruct(
                    (b, cfg.frontend_len, cfg.frontend_dim), jnp.dtype(cfg.dtype)
                )
            return {"batch": batch}

        # decode: one new token against a cache of size seq_len
        enc_len = cfg.frontend_len if cfg.enc_dec else 0
        cache = jax.eval_shape(lambda: self.model.init_cache(b, s, enc_len))
        return {
            "cache": cache,
            "tokens": tok(b, 1),
            "index": jax.ShapeDtypeStruct((), i32),
        }

    def supports_shape(self, shape: ShapeConfig) -> bool:
        """long_500k requires sub-quadratic decode (DESIGN.md table)."""
        if shape.name == "long_500k":
            return self.cfg.supports_long_decode
        return True


def bundle(cfg: ArchConfig) -> ModelBundle:
    return ModelBundle(cfg)
