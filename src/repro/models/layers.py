"""Foundational JAX layers: norms, RoPE, attention (GQA/MLA), MLPs.

Everything is functional: ``init_*`` builds param pytrees, ``apply``-style
functions consume them.  Attention math routes through ``kernels.ops`` so
the Pallas kernels (TPU) and the pure-jnp oracle (CPU / dry-run) share one
call site.  Softmax/logits accumulate in f32 regardless of param dtype.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# sharding hints (no-ops outside a mesh context; see distribution.sharding)
# ---------------------------------------------------------------------------
def hint(x: jnp.ndarray, *logical_axes: Optional[str]) -> jnp.ndarray:
    from ..distribution import sharding

    return sharding.constrain(x, logical_axes)


#: int8 KV-cache quantization (serving lever, EXPERIMENTS.md §Perf C3).
#: Applies to non-ring GQA caches; MLA's latent cache is already compressed.
_KV_QUANT = {"enabled": False}


def set_kv_quant(enabled: bool) -> None:
    _KV_QUANT["enabled"] = bool(enabled)


def kv_quant_enabled() -> bool:
    return _KV_QUANT["enabled"]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------
def _dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = (1.0 / d_in) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def init_norm(d: int, dtype, kind: str = "rmsnorm") -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: Params, x: jnp.ndarray, kind: str = "rmsnorm", eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_tables(
    positions: jnp.ndarray, dim: int, theta: float
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions (...,S) -> (…,S,dim/2) sin/cos tables in f32."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(
    x: jnp.ndarray,
    sin: jnp.ndarray,
    cos: jnp.ndarray,
    mode: str = "full",
) -> jnp.ndarray:
    """x (B,S,H,D); rotate pairs (even, odd).  mode='half' rotates only the
    first half of D (ChatGLM-style partial rotary)."""
    if mode == "none":
        return x
    d = x.shape[-1]
    rot_d = d if mode == "full" else d // 2
    xr, xp = x[..., :rot_d], x[..., rot_d:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    s = sin[:, :, None, : rot_d // 2]
    c = cos[:, :, None, : rot_d // 2]
    y1 = x1 * c - x2 * s
    y2 = x2 * c + x1 * s
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([yr, xp], axis=-1) if mode == "half" else yr


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ArchConfig, dtype) -> Params:
    d, hd = cfg.d_model, cfg.head_dim_
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": _dense_init(kq, d, cfg.n_heads * hd, dtype),
        "wk": _dense_init(kk, d, cfg.n_kv_heads * hd, dtype),
        "wv": _dense_init(kv, d, cfg.n_kv_heads * hd, dtype),
        "wo": _dense_init(ko, cfg.n_heads * hd, d, dtype),
    }


def init_cross_attention(key, cfg: ArchConfig, dtype) -> Params:
    return init_attention(key, cfg, dtype)


def _split_heads(x, n_heads, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, hd)


def attention(
    p: Params,
    x: jnp.ndarray,
    cfg: ArchConfig,
    positions: jnp.ndarray,
    cache: Optional[Params] = None,
    kv_x: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    """GQA self- or cross-attention.

    cache: None (training/prefill without cache) or
           {"k","v" (B,Smax,Hkv,Dh), "index" scalar} for decode; the updated
           cache is returned.  kv_x: encoder states for cross-attention
           (cache then holds precomputed K/V; positions ignored for K).
    """
    hd = cfg.head_dim_
    b, s, _ = x.shape
    q = _split_heads(x @ p["wq"], cfg.n_heads, hd)
    q = hint(q, "batch", "seq", "heads", None)

    cross = kv_x is not None
    if cross:
        if cache is not None and "k" in cache:  # precomputed at prefill
            k, v = cache["k"], cache["v"]
        else:
            k = _split_heads(kv_x @ p["wk"], cfg.n_kv_heads, hd)
            v = _split_heads(kv_x @ p["wv"], cfg.n_kv_heads, hd)
            if cache is not None:
                cache = {**cache, "k": k, "v": v}
        sin = cos = None
    else:
        k = _split_heads(x @ p["wk"], cfg.n_kv_heads, hd)
        v = _split_heads(x @ p["wv"], cfg.n_kv_heads, hd)
        sin, cos = rope_tables(positions, hd, cfg.rope_theta)
        q = apply_rope(q, sin, cos, cfg.rope_mode)
        k = apply_rope(k, sin, cos, cfg.rope_mode)

    from ..kernels import ops as kops

    if cache is not None and not cross:
        idx = cache["index"]
        smax = cache["k"].shape[1]
        ring = bool(cfg.sliding_window) and smax == cfg.sliding_window
        quant = "k_s" in cache  # int8 KV cache (set_kv_quant)
        if s == 1:
            # decode: append one token into the (ring) buffer, attend to all
            from ..kernels.ref import quantize_kv

            if quant:
                k_w, ks_w = quantize_kv(k)
                v_w, vs_w = quantize_kv(v)
            else:
                k_w, v_w, ks_w, vs_w = k, v, None, None
            if jnp.ndim(idx) == 1:
                # ragged continuous batching: per-slot write position/length
                wr = idx % smax if ring else jnp.minimum(idx, smax - 1)
                bix = jnp.arange(b)
                ck = cache["k"].at[bix, wr].set(k_w[:, 0].astype(cache["k"].dtype))
                cv = cache["v"].at[bix, wr].set(v_w[:, 0].astype(cache["v"].dtype))
                if quant:
                    cks = cache["k_s"].at[bix, wr].set(ks_w[:, 0])
                    cvs = cache["v_s"].at[bix, wr].set(vs_w[:, 0])
            else:
                wr = idx % smax if ring else idx
                ck = jax.lax.dynamic_update_slice(
                    cache["k"], k_w.astype(cache["k"].dtype), (0, wr, 0, 0)
                )
                cv = jax.lax.dynamic_update_slice(
                    cache["v"], v_w.astype(cache["v"].dtype), (0, wr, 0, 0)
                )
                if quant:
                    cks = jax.lax.dynamic_update_slice(
                        cache["k_s"], ks_w, (0, wr, 0)
                    )
                    cvs = jax.lax.dynamic_update_slice(
                        cache["v_s"], vs_w, (0, wr, 0)
                    )
            cache = {**cache, "k": ck, "v": cv, "index": idx + 1}
            if quant:
                cache.update(k_s=cks, v_s=cvs)
                out = kops.decode_attention_q8(q, ck, cks, cv, cvs, length=idx + 1)
            else:
                out = kops.decode_attention(
                    q, ck, cv, length=idx + 1, sliding_window=cfg.sliding_window
                )
        else:
            # prefill (from an empty cache): causal attention over the fresh
            # block; keys/values recorded into the cache for later decode.
            out = kops.flash_attention(
                q, k, v, causal=True, sliding_window=cfg.sliding_window
            )
            if quant:
                from ..kernels.ref import quantize_kv

                kq_b, ks_b = quantize_kv(k)
                vq_b, vs_b = quantize_kv(v)
                ck = jax.lax.dynamic_update_slice(
                    cache["k"], kq_b, (0, idx, 0, 0)
                )
                cv = jax.lax.dynamic_update_slice(
                    cache["v"], vq_b, (0, idx, 0, 0)
                )
                cks = jax.lax.dynamic_update_slice(cache["k_s"], ks_b, (0, idx, 0))
                cvs = jax.lax.dynamic_update_slice(cache["v_s"], vs_b, (0, idx, 0))
                cache = {**cache, "k": ck, "v": cv, "k_s": cks, "v_s": cvs,
                         "index": idx + s}
            else:
                if ring and s >= smax:
                    r = s % smax
                    kw = jnp.roll(k[:, -smax:], r, axis=1).astype(cache["k"].dtype)
                    vw = jnp.roll(v[:, -smax:], r, axis=1).astype(cache["v"].dtype)
                    ck = kw
                    cv = vw
                else:
                    ck = jax.lax.dynamic_update_slice(
                        cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0)
                    )
                    cv = jax.lax.dynamic_update_slice(
                        cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0)
                    )
                cache = {**cache, "k": ck, "v": cv, "index": idx + s}
    elif cross:
        out = kops.cross_attention(q, k, v)
    else:
        out = kops.flash_attention(
            q, k, v, causal=True, sliding_window=cfg.sliding_window
        )
    out = hint(out, "batch", "seq", "heads", None)
    y = out.reshape(b, s, cfg.n_heads * hd) @ p["wo"]
    return hint(y, "batch", "seq", None), cache


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V3) — latent-compressed KV cache
# ---------------------------------------------------------------------------
def init_mla(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    qk_dim = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    return {
        "wq_a": _dense_init(ks[0], d, cfg.q_lora_rank, dtype),
        "q_norm": init_norm(cfg.q_lora_rank, dtype),
        "wq_b": _dense_init(ks[1], cfg.q_lora_rank, cfg.n_heads * qk_dim, dtype),
        "wkv_a": _dense_init(ks[2], d, cfg.kv_lora_rank + cfg.qk_rope_head_dim, dtype),
        "kv_norm": init_norm(cfg.kv_lora_rank, dtype),
        "wkv_b": _dense_init(
            ks[3],
            cfg.kv_lora_rank,
            cfg.n_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim),
            dtype,
        ),
        "wo": _dense_init(ks[4], cfg.n_heads * cfg.v_head_dim, d, dtype),
    }


def mla_attention(
    p: Params,
    x: jnp.ndarray,
    cfg: ArchConfig,
    positions: jnp.ndarray,
    cache: Optional[Params] = None,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Multi-head Latent Attention.  The cache stores only the compressed
    latent c_kv (kv_lora_rank) and the shared rotary key k_pe — DeepSeek-V3's
    memory saving, reproduced exactly."""
    b, s, _ = x.shape
    nope, rope_d, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    h = cfg.n_heads

    q = apply_norm(p["q_norm"], x @ p["wq_a"]) @ p["wq_b"]
    q = q.reshape(b, s, h, nope + rope_d)
    q_nope, q_pe = q[..., :nope], q[..., nope:]

    kv_a = x @ p["wkv_a"]
    c_kv, k_pe = kv_a[..., : cfg.kv_lora_rank], kv_a[..., cfg.kv_lora_rank :]
    c_kv = apply_norm(p["kv_norm"], c_kv)
    sin, cos = rope_tables(positions, rope_d, cfg.rope_theta)
    q_pe = apply_rope(q_pe, sin, cos)
    k_pe = apply_rope(k_pe[:, :, None, :], sin, cos)  # single shared rope head

    from ..kernels import ops as kops

    if cache is not None:
        idx = cache["index"]
        if jnp.ndim(idx) == 1:
            # ragged continuous batching (s == 1): per-slot write position
            smax0 = cache["c_kv"].shape[1]
            wr = jnp.minimum(idx, smax0 - 1)
            bix = jnp.arange(b)
            c_all = cache["c_kv"].at[bix, wr].set(
                c_kv[:, 0].astype(cache["c_kv"].dtype)
            )
            pe_all = cache["k_pe"].at[bix, wr].set(
                k_pe[:, 0, 0, :].astype(cache["k_pe"].dtype)
            )
        else:
            c_all = jax.lax.dynamic_update_slice(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, idx, 0)
            )
            pe_all = jax.lax.dynamic_update_slice(
                cache["k_pe"], k_pe[:, :, 0, :].astype(cache["k_pe"].dtype), (0, idx, 0)
            )
        cache = {**cache, "c_kv": c_all, "k_pe": pe_all, "index": idx + s}
        smax = c_all.shape[1]
    if cache is not None and s > 1:
        # prefill: cache recorded above; attention over the fresh block only
        cache_for_math = None
    else:
        cache_for_math = cache
    if cache_for_math is not None:
        # ---- decode with weight ABSORPTION (DeepSeek-V3's trick) ----------
        # Never decompress the latent cache: fold wkv_b's key half into the
        # query and apply its value half after attending over the latents.
        wb = p["wkv_b"].reshape(cfg.kv_lora_rank, h, nope + vh)
        wb_k, wb_v = wb[..., :nope], wb[..., nope:]
        q_eff = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32), wb_k.astype(jnp.float32))
        scores = jnp.einsum("bshr,btr->bhst", q_eff, c_all.astype(jnp.float32))
        scores += jnp.einsum(
            "bshd,btd->bhst", q_pe.astype(jnp.float32), pe_all.astype(jnp.float32)
        )
        scores = scores / jnp.sqrt(jnp.float32(nope + rope_d))
        lim = jnp.broadcast_to(idx + s, (b,))
        valid = jnp.arange(smax)[None, None, None, :] < lim[:, None, None, None]
        scores = jnp.where(valid, scores, -1e30)
        pr = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhst,btr->bshr", pr, c_all.astype(jnp.float32))
        out = jnp.einsum("bshr,rhv->bshv", ctx, wb_v.astype(jnp.float32)).astype(x.dtype)
    else:
        # ---- train / prefill: decompress K/V (dense MXU matmuls) ----------
        kv = (c_kv @ p["wkv_b"]).reshape(b, s, h, nope + vh)
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k = jnp.concatenate(
            [
                k_nope,
                jnp.broadcast_to(
                    k_pe[:, :, 0, :][:, :, None, :], k_nope.shape[:3] + (rope_d,)
                ),
            ],
            axis=-1,
        )
        qfull = jnp.concatenate([q_nope, q_pe], axis=-1)
        out = kops.flash_attention(qfull, k, v, causal=True)
    y = out.reshape(b, s, h * vh) @ p["wo"]
    return hint(y, "batch", "seq", None), cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def init_mlp(key, d: int, f: int, kind: str, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_out": _dense_init(k2, f, d, dtype)}
    if kind == "swiglu":
        p["w_gate"] = _dense_init(k1, d, f, dtype)
        p["w_up"] = _dense_init(k3, d, f, dtype)
    else:
        p["w_in"] = _dense_init(k1, d, f, dtype)
    return p


def apply_mlp(p: Params, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["w_in"]))
    else:  # gelu
        h = jax.nn.gelu(x @ p["w_in"])
    h = hint(h, "batch", "seq", "mlp")
    return h @ p["w_out"]


# ---------------------------------------------------------------------------
# embeddings / LM head
# ---------------------------------------------------------------------------
def init_embedding(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def embed(table: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return hint(jnp.take(table, tokens, axis=0), "batch", "seq", None)


def lm_logits(table_or_w: jnp.ndarray, x: jnp.ndarray, tied: bool) -> jnp.ndarray:
    w = table_or_w.T if tied else table_or_w
    logits = x.astype(jnp.float32) @ w.astype(jnp.float32)
    return hint(logits, "batch", "seq", "vocab")
