"""Mixture-of-Experts layer: router + capacity-bounded expert dispatch.

Two interchangeable implementations (selected via ``set_moe_impl``; both
compute identical math up to token dropping at capacity):

* "dispatch" — baseline: GShard/MaxText-style grouped one-hot dispatch.
               Tokens are split into G groups; dispatch/combine are dense
               einsums over (group, token, expert, capacity) masks, which
               GSPMD shards cleanly (groups over the data axes, experts
               over 'model').  Costs ~2 extra (T x E*C x D) matmuls — the
               known einsum-MoE overhead.
* "alltoall" — production EP: shard_map over the 'model' axis with explicit
               all_to_all dispatch (a §Perf iteration; see EXPERIMENTS.md).

Token dropping: tokens beyond an expert's per-group capacity
C = ceil(Tg*k/E * cf) are dropped (contribute zero) — the standard
Switch/GShard discipline.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import layers

_MOE_IMPL = {"mode": "dispatch"}


def set_moe_impl(mode: str) -> None:
    assert mode in ("dispatch", "alltoall")
    _MOE_IMPL["mode"] = mode


def get_moe_impl() -> str:
    return _MOE_IMPL["mode"]


def init_moe(key, cfg: ArchConfig, dtype) -> Dict[str, Any]:
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    e = cfg.n_experts
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {
        "router": (jax.random.normal(ks[0], (d, e), jnp.float32) * 0.02).astype(
            jnp.float32  # router stays f32 for stable softmax
        ),
        "experts": {
            "w_gate": jnp.stack(
                [layers._dense_init(k, d, f, dtype) for k in jax.random.split(ks[1], e)]
            ),
            "w_up": jnp.stack(
                [layers._dense_init(k, d, f, dtype) for k in jax.random.split(ks[2], e)]
            ),
            "w_out": jnp.stack(
                [layers._dense_init(k, f, d, dtype) for k in jax.random.split(ks[3], e)]
            ),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = layers.init_mlp(
            ks[4], d, f * cfg.n_shared_experts, "swiglu", dtype
        )
    return p


def _route(p, xt: jnp.ndarray, cfg: ArchConfig):
    logits = xt.astype(jnp.float32) @ p["router"]  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balancing auxiliary loss (Switch): E * sum_e f_e * P_e
    me = probs.mean(0)
    ce = jnp.zeros_like(me).at[eidx.reshape(-1)].add(1.0) / eidx.size
    aux = cfg.n_experts * jnp.sum(me * ce)
    return gates, eidx, aux


def _expert_ffn(experts, h: jnp.ndarray) -> jnp.ndarray:
    """h (E,...,D) -> (E,...,D) via per-expert SwiGLU (batched einsum)."""
    g = jnp.einsum("e...d,edf->e...f", h, experts["w_gate"])
    u = jnp.einsum("e...d,edf->e...f", h, experts["w_up"])
    a = jax.nn.silu(g) * u
    return jnp.einsum("e...f,efd->e...d", a, experts["w_out"])


def _group_count(t: int) -> int:
    """~1024-token groups, power-of-two, >= 1 (shardable over data axes)."""
    g = max(1, t // 1024)
    return 1 << (g - 1).bit_length() if g & (g - 1) else g


def apply_moe(
    p: Dict[str, Any], x: jnp.ndarray, cfg: ArchConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,D) -> (y, aux_loss)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    gates, eidx, aux = _route(p, xt, cfg)
    if _MOE_IMPL["mode"] == "alltoall":
        from ..distribution import moe_ep

        y = moe_ep.apply_moe_alltoall(p, xt, gates, eidx, cfg)
    else:
        y = _apply_dispatch(p, xt, gates, eidx, cfg)
    if "shared" in p:
        y = y + layers.apply_mlp(p["shared"], xt, "swiglu")
    return y.reshape(b, s, d).astype(x.dtype), aux


def _apply_dispatch(p, xt, gates, eidx, cfg: ArchConfig) -> jnp.ndarray:
    """GShard grouped dense dispatch/combine."""
    t, d = xt.shape
    k, e = cfg.experts_per_token, cfg.n_experts
    g = _group_count(t)
    tg = t // g
    cap = max(4, int(math.ceil(tg * k / e * cfg.capacity_factor)))
    cap = min(cap, tg * k)

    eidx_g = eidx.reshape(g, tg, k)
    gates_g = gates.reshape(g, tg, k)
    x_g = layers.hint(xt.reshape(g, tg, d), "batch", None, None)

    # expert one-hot per slot: (g, tg, k, e)
    onehot = jax.nn.one_hot(eidx_g, e, dtype=jnp.float32)
    onehot = layers.hint(onehot, "batch", None, None, "experts")
    # position of each slot within its expert's buffer (token-major priority)
    flat = onehot.reshape(g, tg * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat  # exclusive cumsum
    pos = pos.reshape(g, tg, k, e)
    keep = (pos < cap) & (onehot > 0)
    # a token picks an expert in AT MOST one top-k slot, so the k axis
    # collapses: rank-4 dispatch, never a (.., k, e, cap) rank-5 mask.
    sel = keep.any(2)  # (g, tg, e)
    pos_te = (pos * keep).sum(2).astype(jnp.int32)  # (g, tg, e)
    gate_te = (gates_g[..., None] * keep).sum(2)  # (g, tg, e)

    dispatch = jax.nn.one_hot(pos_te, cap, dtype=jnp.float32) * sel[..., None]
    dispatch = layers.hint(dispatch, "batch", None, "experts", None)
    combine = dispatch * gate_te[..., None]  # (g, tg, e, cap)

    dt = xt.dtype
    expert_in = jnp.einsum("gtec,gtd->gecd", dispatch.astype(dt), x_g)
    expert_in = layers.hint(
        expert_in.swapaxes(0, 1), "experts", "batch", None, None
    )  # (e, g, cap, d)
    expert_out = _expert_ffn(p["experts"], expert_in)  # (e, g, cap, d)
    expert_out = expert_out.swapaxes(0, 1)  # (g, e, cap, d)
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(dt), expert_out)
    return y.reshape(t, d)
