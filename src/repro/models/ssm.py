"""Recurrent blocks: Mamba-2 (SSD), xLSTM's mLSTM and sLSTM.

Training uses parallel/chunkwise forms (MXU-friendly matmuls); decoding uses
the O(1)-state recurrent forms.  State pytrees double as the "KV cache" for
these blocks, which is what makes the ``long_500k`` shape feasible.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import layers
from ..kernels import ops as kops

Params = Dict[str, Any]
_CONV_K = 4  # mamba short-conv width


# ---------------------------------------------------------------------------
# Mamba-2
# ---------------------------------------------------------------------------
def mamba_dims(cfg: ArchConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = cfg.ssm_heads or max(1, d_inner // 64)
    head_p = d_inner // heads
    return d_inner, heads, head_p, cfg.ssm_state


def init_mamba2(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    d_inner, h, p_, n = mamba_dims(cfg)
    ks = jax.random.split(key, 6)
    conv_dim = d_inner + 2 * n
    return {
        "w_in": layers._dense_init(ks[0], d, 2 * d_inner + 2 * n + h, dtype),
        "conv_w": (jax.random.normal(ks[1], (_CONV_K, conv_dim), jnp.float32) * 0.1).astype(dtype),
        "a_log": jnp.zeros((h,), jnp.float32),  # A = -exp(a_log) = -1
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": layers.init_norm(d_inner, dtype),
        "w_out": layers._dense_init(ks[2], d_inner, d, dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, state: Optional[jnp.ndarray]):
    """Depthwise causal conv; x (B,S,C), w (K,C).  Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(k))
    new_state = xp[:, -(k - 1) :] if k > 1 else None
    return jax.nn.silu(y), new_state


def mamba2_block(
    p: Params,
    x: jnp.ndarray,
    cfg: ArchConfig,
    state: Optional[Params] = None,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    """x (B,S,D).  state = {"ssm" (B,H,P,N), "conv" (B,K-1,convdim)} or None."""
    b, s, d = x.shape
    d_inner, h, pdim, n = mamba_dims(cfg)
    z_xbc_dt = x @ p["w_in"]
    z = z_xbc_dt[..., :d_inner]
    xbc = z_xbc_dt[..., d_inner : d_inner + d_inner + 2 * n]
    dt_raw = z_xbc_dt[..., -h:]

    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], conv_state)
    xin = xbc[..., :d_inner].reshape(b, s, h, pdim)
    Bm = xbc[..., d_inner : d_inner + n]
    Cm = xbc[..., d_inner + n :]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["a_log"])

    if state is not None and s == 1:
        # recurrent decode step
        h_prev = state["ssm"]
        decay = jnp.exp(A[None, :] * dt[:, 0])  # (B,H)
        upd = jnp.einsum(
            "bh,bhp,bn->bhpn", dt[:, 0], xin[:, 0].astype(jnp.float32), Bm[:, 0].astype(jnp.float32)
        )
        h_new = h_prev * decay[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", h_new, Cm[:, 0].astype(jnp.float32))[:, None]
        new_state = {"ssm": h_new, "conv": new_conv}
    else:
        init = state["ssm"] if state is not None else None
        y, h_new = kops.ssd_scan(xin, dt, A, Bm, Cm, chunk=cfg.ssm_chunk, initial_state=init)
        new_state = {"ssm": h_new, "conv": new_conv} if state is not None else None

    y = y.astype(x.dtype) + xin * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, s, d_inner) * jax.nn.silu(z)
    y = layers.apply_norm(p["norm"], y)
    return y @ p["w_out"], new_state


def init_mamba_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> Params:
    d_inner, h, pdim, n = mamba_dims(cfg)
    return {
        "ssm": jnp.zeros((batch, h, pdim, n), jnp.float32),
        "conv": jnp.zeros((batch, _CONV_K - 1, d_inner + 2 * n), dtype),
    }


# ---------------------------------------------------------------------------
# mLSTM (xLSTM): matrix-memory LSTM with parallel (attention-like) training
# ---------------------------------------------------------------------------
def init_mlstm(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    dh = 2 * d // h  # up-projection factor 2
    ks = jax.random.split(key, 8)
    return {
        "w_up": layers._dense_init(ks[0], d, 2 * d, dtype),
        "w_z": layers._dense_init(ks[1], d, 2 * d, dtype),
        "wq": layers._dense_init(ks[2], 2 * d, h * dh, dtype),
        "wk": layers._dense_init(ks[3], 2 * d, h * dh, dtype),
        "wv": layers._dense_init(ks[4], 2 * d, h * dh, dtype),
        "w_if": (jax.random.normal(ks[5], (2 * d, 2 * h), jnp.float32) * 0.02).astype(dtype),
        "if_bias": jnp.concatenate([jnp.zeros((h,)), 3.0 * jnp.ones((h,))]).astype(jnp.float32),
        "norm": layers.init_norm(2 * d, dtype),
        "w_down": layers._dense_init(ks[6], 2 * d, d, dtype),
    }


def mlstm_block(
    p: Params,
    x: jnp.ndarray,
    cfg: ArchConfig,
    state: Optional[Params] = None,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    """state = {"C" (B,H,Dk,Dv), "n" (B,H,Dk), "m" (B,H)} for decode."""
    b, s, d = x.shape
    h = cfg.n_heads
    dh = 2 * d // h
    up = x @ p["w_up"]
    z = jax.nn.silu(x @ p["w_z"])
    q = (up @ p["wq"]).reshape(b, s, h, dh)
    k = (up @ p["wk"]).reshape(b, s, h, dh) / jnp.sqrt(dh).astype(x.dtype)
    v = (up @ p["wv"]).reshape(b, s, h, dh)
    gates = (up.astype(jnp.float32) @ p["w_if"].astype(jnp.float32)) + p["if_bias"]
    i_pre, f_pre = gates[..., :h], gates[..., h:]  # (B,S,H)
    logf = -jax.nn.softplus(-f_pre)  # log sigmoid(f)

    if state is not None and s == 1:
        C, n, m = state["C"], state["n"], state["m"]
        m_new = jnp.maximum(logf[:, 0] + m, i_pre[:, 0])
        i_g = jnp.exp(i_pre[:, 0] - m_new)
        f_g = jnp.exp(logf[:, 0] + m - m_new)
        qf = q[:, 0].astype(jnp.float32)
        kf = k[:, 0].astype(jnp.float32)
        vf = v[:, 0].astype(jnp.float32)
        C = C * f_g[..., None, None] + i_g[..., None, None] * kf[..., :, None] * vf[..., None, :]
        n = n * f_g[..., None] + i_g[..., None] * kf
        num = jnp.einsum("bhk,bhkv->bhv", qf, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n)), jnp.exp(-m_new))
        y = (num / den[..., None])[:, None]  # (B,1,H,Dv)
        new_state = {"C": C, "n": n, "m": m_new}
    else:
        # parallel stabilized form (xLSTM paper eq. 19-27)
        lf_cum = jnp.cumsum(logf, axis=1)  # (B,S,H)
        dmat = lf_cum[:, :, None, :] - lf_cum[:, None, :, :] + i_pre[:, None, :, :]
        tri = jnp.tril(jnp.ones((s, s), bool))
        dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)
        m_row = jnp.max(dmat, axis=2)  # (B,S,H)
        dprime = jnp.exp(dmat - m_row[:, :, None, :])
        scores = jnp.einsum("bqhd,bkhd->bqkh", q.astype(jnp.float32), k.astype(jnp.float32))
        w = scores * dprime
        den = jnp.maximum(jnp.abs(w.sum(2)), jnp.exp(-m_row))  # (B,S,H)
        y = jnp.einsum("bqkh,bkhd->bqhd", w, v.astype(jnp.float32)) / den[..., None]
        new_state = state
        if state is not None:
            # prefill: derive the final recurrent state in closed form
            # m_T = max_u (i_u + lf_T - lf_u); C_T = sum_u e^{i_u+lf_T-lf_u-m_T} k_u v_u^T
            tailw = i_pre + lf_cum[:, -1:, :] - lf_cum  # (B,S,H)
            m_T = jnp.max(tailw, axis=1)  # (B,H)
            wgt = jnp.exp(tailw - m_T[:, None, :])  # (B,S,H)
            kf = k.astype(jnp.float32)
            vf = v.astype(jnp.float32)
            C_T = jnp.einsum("bsh,bshk,bshv->bhkv", wgt, kf, vf)
            n_T = jnp.einsum("bsh,bshk->bhk", wgt, kf)
            new_state = {"C": C_T, "n": n_T, "m": m_T}

    y = y.astype(x.dtype).reshape(b, s, 2 * d)
    y = layers.apply_norm(p["norm"], y) * z
    return y @ p["w_down"], new_state


def init_mlstm_state(cfg: ArchConfig, batch: int) -> Params:
    h = cfg.n_heads
    dh = 2 * cfg.d_model // h
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.zeros((batch, h), jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM (xLSTM): scalar-memory recurrent LSTM with exponential gating
# ---------------------------------------------------------------------------
def init_slstm(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "w_gates": layers._dense_init(ks[0], d, 4 * d, dtype),  # i,f,z,o
        "r_gates": layers._dense_init(ks[1], d, 4 * d, dtype),  # recurrent
        "g_bias": jnp.zeros((4 * d,), jnp.float32),
        "norm": layers.init_norm(d, dtype),
        "w_ff": layers.init_mlp(ks[2], d, int(d * 4 / 3), "swiglu", dtype),
    }


def slstm_block(
    p: Params,
    x: jnp.ndarray,
    cfg: ArchConfig,
    state: Optional[Params] = None,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    """state = {"c","n","h","m"} each (B,D)."""
    b, s, d = x.shape
    wx = (x @ p["w_gates"]).astype(jnp.float32)  # (B,S,4D)

    if state is None:
        st = {
            "c": jnp.zeros((b, d), jnp.float32),
            "n": jnp.ones((b, d), jnp.float32),
            "h": jnp.zeros((b, d), jnp.float32),
            "m": jnp.zeros((b, d), jnp.float32),
        }
    else:
        st = state

    rw = p["r_gates"].astype(jnp.float32)
    gb = p["g_bias"]

    def step(carry, wx_t):
        c, n, hprev, m = carry["c"], carry["n"], carry["h"], carry["m"]
        g = wx_t + hprev @ rw + gb
        ig, fg, zg, og = jnp.split(g, 4, axis=-1)
        m_new = jnp.maximum(-jax.nn.softplus(-fg) + m, ig)
        i = jnp.exp(ig - m_new)
        f = jnp.exp(-jax.nn.softplus(-fg) + m - m_new)
        c_new = f * c + i * jnp.tanh(zg)
        n_new = f * n + i
        h_new = jax.nn.sigmoid(og) * c_new / jnp.maximum(n_new, 1e-6)
        return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}, h_new

    carry, hs = jax.lax.scan(step, st, jnp.moveaxis(wx, 0, 1))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)  # (B,S,D)
    y = layers.apply_norm(p["norm"], y)
    y = y + layers.apply_mlp(p["w_ff"], y, "swiglu")
    return y, (carry if state is not None else None)


def init_slstm_state(cfg: ArchConfig, batch: int) -> Params:
    d = cfg.d_model
    z = lambda: jnp.zeros((batch, d), jnp.float32)
    return {"c": z(), "n": jnp.ones((batch, d), jnp.float32), "h": z(), "m": z()}
