"""Composable LM: decoder-only / encoder-decoder stacks over heterogeneous
block types (attention, MoE, Mamba-2, mLSTM, sLSTM, Zamba shared-attention).

Layers of one kind are *stacked* (leading L dim) and executed with
``jax.lax.scan`` so compile time is O(#block kinds), not O(#layers) — a hard
requirement for 61-96-layer configs.  Hybrid archs (Zamba2) split their runs
at shared-attention boundaries, so the weight-shared block is applied between
scans without unrolling the backbone.

The same apply code serves three modes:
  * train   — full-sequence causal, no cache
  * prefill — full-sequence causal, cache written and returned
  * decode  — one token against the cache/state
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import layers, moe, ssm

Params = Dict[str, Any]

#: activation rematerialization for the layer scans: None (save everything)
#: or "block" (save only the residual stream between layers; recompute the
#: block interior in the backward pass).
_REMAT = {"mode": None}


def set_remat(mode: Optional[str]) -> None:
    assert mode in (None, "block")
    _REMAT["mode"] = mode


def _maybe_remat(fn):
    if _REMAT["mode"] == "block":
        return jax.checkpoint(fn, prevent_cse=False)
    return fn


# ---------------------------------------------------------------------------
# per-block init / apply
# ---------------------------------------------------------------------------
def _init_block(key, kind: str, cfg: ArchConfig, dtype, dense_mlp: bool) -> Params:
    ks = jax.random.split(key, 4)
    if kind in ("attn", "moe"):
        p: Params = {
            "ln1": layers.init_norm(cfg.d_model, dtype, cfg.norm),
            "ln2": layers.init_norm(cfg.d_model, dtype, cfg.norm),
        }
        if cfg.attention == "mla":
            p["attn"] = layers.init_mla(ks[0], cfg, dtype)
        else:
            p["attn"] = layers.init_attention(ks[0], cfg, dtype)
        if kind == "moe":
            p["moe"] = moe.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp, dtype)
        return p
    if kind == "mamba2":
        return {
            "ln1": layers.init_norm(cfg.d_model, dtype, cfg.norm),
            "mixer": ssm.init_mamba2(ks[0], cfg, dtype),
        }
    if kind == "mlstm":
        return {
            "ln1": layers.init_norm(cfg.d_model, dtype, cfg.norm),
            "mixer": ssm.init_mlstm(ks[0], cfg, dtype),
        }
    if kind == "slstm":
        return {
            "ln1": layers.init_norm(cfg.d_model, dtype, cfg.norm),
            "mixer": ssm.init_slstm(ks[0], cfg, dtype),
        }
    raise ValueError(kind)


def _apply_block(
    p: Params,
    x: jnp.ndarray,
    kind: str,
    cfg: ArchConfig,
    positions: jnp.ndarray,
    cache: Optional[Params],
) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    """Returns (x_out, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "moe"):
        h = layers.apply_norm(p["ln1"], x, cfg.norm)
        attn_cache = cache["attn"] if cache is not None else None
        if cfg.attention == "mla":
            a, attn_cache = layers.mla_attention(p["attn"], h, cfg, positions, attn_cache)
        else:
            a, attn_cache = layers.attention(p["attn"], h, cfg, positions, attn_cache)
        x = x + a
        h = layers.apply_norm(p["ln2"], x, cfg.norm)
        if kind == "moe":
            y, aux = moe.apply_moe(p["moe"], h, cfg)
        else:
            y = layers.apply_mlp(p["mlp"], h, cfg.mlp)
        x = x + y
        new_cache = {"attn": attn_cache} if cache is not None else None
        return x, new_cache, aux
    # recurrent mixers
    h = layers.apply_norm(p["ln1"], x, cfg.norm)
    mix_state = cache["mixer"] if cache is not None else None
    fn = {"mamba2": ssm.mamba2_block, "mlstm": ssm.mlstm_block, "slstm": ssm.slstm_block}[kind]
    y, mix_state = fn(p["mixer"], h, cfg, mix_state)
    x = x + y
    new_cache = {"mixer": mix_state} if cache is not None else None
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def _attn_cache(cfg: ArchConfig, batch: int, max_len: int, dtype, ragged=False):
    idx = jnp.zeros((batch,) if ragged else (), jnp.int32)
    if cfg.attention == "mla":
        return {
            "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_pe": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
            "index": idx,
        }
    s = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    if layers.kv_quant_enabled() and not cfg.sliding_window:
        # int8 KV + per-(token, head) scales (serving lever, §Perf C3)
        return {
            "k": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.head_dim_), jnp.int8),
            "k_s": jnp.zeros((batch, s, cfg.n_kv_heads), jnp.float32),
            "v": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.head_dim_), jnp.int8),
            "v_s": jnp.zeros((batch, s, cfg.n_kv_heads), jnp.float32),
            "index": idx,
        }
    return {
        "k": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.head_dim_), dtype),
        "v": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.head_dim_), dtype),
        "index": idx,
    }


def _block_cache(kind: str, cfg: ArchConfig, batch: int, max_len: int, dtype,
                 ragged=False):
    if kind in ("attn", "moe"):
        return {"attn": _attn_cache(cfg, batch, max_len, dtype, ragged)}
    if kind == "mamba2":
        return {"mixer": ssm.init_mamba_state(cfg, batch, dtype)}
    if kind == "mlstm":
        return {"mixer": ssm.init_mlstm_state(cfg, batch)}
    if kind == "slstm":
        return {"mixer": ssm.init_slstm_state(cfg, batch)}
    raise ValueError(kind)


def _stack(n: int, tree):
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape).copy(), tree)


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    def _groups(self) -> Tuple[Tuple[str, int], ...]:
        """Layer runs, split at shared-attention boundaries for hybrids."""
        cfg = self.cfg
        runs = cfg.layer_groups()
        if not cfg.shared_attn_every:
            return runs
        out: List[Tuple[str, int]] = []
        for kind, count in runs:
            while count > 0:
                take = min(cfg.shared_attn_every, count)
                out.append((kind, take))
                count -= take
        return tuple(out)

    @property
    def n_shared_apps(self) -> int:
        cfg = self.cfg
        return cfg.n_layers // cfg.shared_attn_every if cfg.shared_attn_every else 0

    # ---- init ------------------------------------------------------------
    def init(self, key) -> Params:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        keys = jax.random.split(key, 16)
        params: Params = {
            "embedding": layers.init_embedding(keys[0], cfg.vocab_size, cfg.d_model, dtype),
            "ln_f": layers.init_norm(cfg.d_model, dtype, cfg.norm),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = layers._dense_init(keys[1], cfg.d_model, cfg.vocab_size, dtype)
        if cfg.frontend:
            params["frontend"] = {
                "patch_proj": layers._dense_init(keys[2], cfg.frontend_dim, cfg.d_model, dtype)
            }
        groups = []
        gkeys = jax.random.split(keys[3], len(self._groups()))
        layer_idx = 0
        for gi, (kind, count) in enumerate(self._groups()):
            dense = layer_idx < cfg.n_dense_layers
            bkeys = jax.random.split(gkeys[gi], count)
            groups.append(jax.vmap(lambda k: _init_block(k, kind, cfg, dtype, dense))(bkeys))
            layer_idx += count
        params["groups"] = groups
        if cfg.shared_attn_every:
            params["shared_attn"] = _init_block(keys[4], "attn", cfg, dtype, True)
        if cfg.enc_dec:
            ekeys = jax.random.split(keys[5], cfg.n_encoder_layers)
            params["encoder"] = {
                "blocks": jax.vmap(lambda k: _init_block(k, "attn", cfg, dtype, True))(ekeys),
                "ln_f": layers.init_norm(cfg.d_model, dtype, cfg.norm),
            }
            ckeys = jax.random.split(keys[6], cfg.n_layers)
            params["cross"] = jax.vmap(
                lambda k: {
                    "ln": layers.init_norm(cfg.d_model, dtype, cfg.norm),
                    "attn": layers.init_cross_attention(k, cfg, dtype),
                }
            )(ckeys)
        if cfg.mtp_depth:
            params["mtp"] = {
                "proj": layers._dense_init(keys[7], 2 * cfg.d_model, cfg.d_model, dtype),
                "block": _init_block(keys[8], "attn", cfg, dtype, True),
                "ln": layers.init_norm(cfg.d_model, dtype, cfg.norm),
            }
        return params

    # ---- cache init --------------------------------------------------------
    def init_cache(
        self, batch: int, max_len: int, enc_len: int = 0, ragged: bool = False
    ) -> Params:
        """ragged=True gives every batch slot its own cache index — the
        continuous-batching decode state used by serving/engine.py."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        cache: Params = {
            "groups": [
                _stack(count, _block_cache(kind, cfg, batch, max_len, dtype, ragged))
                for kind, count in self._groups()
            ]
        }
        if cfg.shared_attn_every:
            cache["shared"] = _stack(
                self.n_shared_apps,
                _block_cache("attn", cfg, batch, max_len, dtype, ragged),
            )
        if cfg.enc_dec:
            cache["cross"] = {
                "k": jnp.zeros(
                    (cfg.n_layers, batch, enc_len, cfg.n_kv_heads, cfg.head_dim_), dtype
                ),
                "v": jnp.zeros(
                    (cfg.n_layers, batch, enc_len, cfg.n_kv_heads, cfg.head_dim_), dtype
                ),
            }
        return cache

    # ---- embedding + frontends ----------------------------------------------
    def _embed_inputs(self, params: Params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        cfg = self.cfg
        x = layers.embed(params["embedding"], batch["tokens"])
        if cfg.frontend == "vit" and "patch_embeds" in batch:
            pe = batch["patch_embeds"] @ params["frontend"]["patch_proj"]
            npatch = min(pe.shape[1], x.shape[1])
            x = jnp.concatenate([pe[:, :npatch].astype(x.dtype), x[:, npatch:]], axis=1)
        return x

    def _encode(self, params: Params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        """Audio encoder over precomputed frame embeddings (stub frontend)."""
        cfg = self.cfg
        frames = batch["frames"]
        h = frames @ params["frontend"]["patch_proj"] if cfg.frontend else frames
        h = h.astype(jnp.dtype(cfg.dtype))

        from ..kernels import ops as kops

        def body(x, bp):
            hh = layers.apply_norm(bp["ln1"], x, cfg.norm)
            hd = cfg.head_dim_
            b, s, _ = x.shape
            q = (hh @ bp["attn"]["wq"]).reshape(b, s, cfg.n_heads, hd)
            k = (hh @ bp["attn"]["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
            v = (hh @ bp["attn"]["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
            a = kops.flash_attention(q, k, v, causal=False)
            x = x + a.reshape(b, s, cfg.n_heads * hd) @ bp["attn"]["wo"]
            hh = layers.apply_norm(bp["ln2"], x, cfg.norm)
            x = x + layers.apply_mlp(bp["mlp"], hh, cfg.mlp)
            return x, None

        h, _ = jax.lax.scan(body, h, params["encoder"]["blocks"])
        return layers.apply_norm(params["encoder"]["ln_f"], h, cfg.norm)

    # ---- decoder trunk -------------------------------------------------------
    def _trunk(
        self,
        params: Params,
        x: jnp.ndarray,
        positions: jnp.ndarray,
        cache: Optional[Params],
        enc_out: Optional[jnp.ndarray],
    ) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
        cfg = self.cfg
        aux_total = jnp.zeros((), jnp.float32)

        if cfg.enc_dec:
            return self._trunk_encdec(params, x, positions, cache, enc_out)

        new_groups: List[Any] = []
        new_shared: List[Any] = []
        shared_cache = cache.get("shared") if cache is not None else None
        cum = 0
        shared_ct = 0
        for gi, (kind, count) in enumerate(self._groups()):
            gp = params["groups"][gi]
            gc = cache["groups"][gi] if cache is not None else None
            x, new_gc, aux = self._scan_group(gp, gc, x, kind, positions)
            aux_total = aux_total + aux
            new_groups.append(new_gc)
            cum += count
            if (
                cfg.shared_attn_every
                and cum % cfg.shared_attn_every == 0
                and shared_ct < self.n_shared_apps
            ):
                sc = (
                    jax.tree.map(lambda a: a[shared_ct], shared_cache)
                    if shared_cache is not None
                    else None
                )
                x, nsc, aux2 = _apply_block(
                    params["shared_attn"], x, "attn", cfg, positions, sc
                )
                aux_total = aux_total + aux2
                if nsc is not None:
                    new_shared.append(nsc)
                shared_ct += 1

        new_cache = None
        if cache is not None:
            new_cache = {"groups": new_groups}
            if new_shared:
                new_cache["shared"] = jax.tree.map(lambda *ls: jnp.stack(ls), *new_shared)
            elif "shared" in cache:
                new_cache["shared"] = cache["shared"]
        return x, new_cache, aux_total

    def _scan_group(self, gp, gc, x, kind: str, positions):
        cfg = self.cfg
        aux0 = jnp.zeros((), jnp.float32)
        if gc is not None:
            def body(carry, xs):
                xx, auxc = carry
                bp, bc = xs
                xx, nbc, aux = _apply_block(bp, xx, kind, cfg, positions, bc)
                return (xx, auxc + aux), nbc

            (x, aux), new_gc = jax.lax.scan(_maybe_remat(body), (x, aux0), (gp, gc))
            return x, new_gc, aux

        def body_nc(carry, bp):
            xx, auxc = carry
            xx, _, aux = _apply_block(bp, xx, kind, cfg, positions, None)
            return (xx, auxc + aux), None

        (x, aux), _ = jax.lax.scan(_maybe_remat(body_nc), (x, aux0), gp)
        return x, None, aux

    def _trunk_encdec(self, params, x, positions, cache, enc_out):
        """Uniform decoder scan with interleaved cross-attention.

        prefill/train: enc_out given -> cross K/V computed, returned in cache.
        decode: enc_out None -> cross K/V read from cache.
        """
        cfg = self.cfg
        aux0 = jnp.zeros((), jnp.float32)
        gp = params["groups"][0]
        gc = cache["groups"][0] if cache is not None else None
        cross_p = params["cross"]
        cross_c = cache["cross"] if cache is not None else None

        def body(carry, xs):
            xx, auxc = carry
            if cache is not None:
                bp, cp, bc, cck, ccv = xs
            else:
                bp, cp = xs
                bc, cck, ccv = None, None, None
            xx, nbc, aux = _apply_block(bp, xx, "attn", cfg, positions, bc)
            h = layers.apply_norm(cp["ln"], xx, cfg.norm)
            if enc_out is not None:
                a, ckv = layers.attention(
                    cp["attn"], h, cfg, positions, cache={}, kv_x=enc_out
                )
                nck, ncv = ckv["k"], ckv["v"]
            else:
                a, _ = layers.attention(
                    cp["attn"], h, cfg, positions, cache={"k": cck, "v": ccv},
                    kv_x=jnp.zeros((xx.shape[0], 0, cfg.d_model), xx.dtype),
                )
                nck, ncv = cck, ccv
            xx = xx + a
            ys = (nbc, nck, ncv) if cache is not None else None
            return (xx, auxc + aux), ys

        if cache is not None:
            xs = (gp, cross_p, gc, cross_c["k"], cross_c["v"])
            (x, aux), (new_gc, nk, nv) = jax.lax.scan(body, (x, aux0), xs)
            new_cache = {"groups": [new_gc], "cross": {"k": nk, "v": nv}}
        else:
            (x, aux), _ = jax.lax.scan(body, (x, aux0), (gp, cross_p))
            new_cache = None
        return x, new_cache, aux

    # ---- public entry points -------------------------------------------------
    def forward(
        self,
        params: Params,
        batch: Dict[str, jnp.ndarray],
        cache: Optional[Params] = None,
        positions: Optional[jnp.ndarray] = None,
    ) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        b, s = batch["tokens"].shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        enc_out = self._encode(params, batch) if (cfg.enc_dec and "frames" in batch) else None
        x, new_cache, aux = self._trunk(params, x, positions, cache, enc_out)
        x = layers.apply_norm(params["ln_f"], x, cfg.norm)
        head = params["embedding"] if cfg.tie_embeddings else params["lm_head"]
        logits = layers.lm_logits(head, x, cfg.tie_embeddings)
        return logits, new_cache, aux

    # ---- loss -----------------------------------------------------------------
    def loss(
        self, params: Params, batch: Dict[str, jnp.ndarray]
    ) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        logits, _, aux = self.forward(params, batch)
        tokens = batch["tokens"]
        targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
        mask = jnp.ones_like(targets, jnp.float32).at[:, -1].set(0.0)
        ce = _xent(logits, targets, mask)
        total = ce + 0.01 * aux
        metrics = {"ce": ce, "aux": aux}
        if cfg.mtp_depth and "mtp" in params:
            mtp_loss = self._mtp_loss(params, batch)
            total = total + 0.3 * mtp_loss
            metrics["mtp"] = mtp_loss
        return total, metrics

    def _mtp_loss(self, params, batch) -> jnp.ndarray:
        """DeepSeek-V3 multi-token prediction (depth 1, simplified): an extra
        block over [emb(t) ; emb(t+1)] predicting token t+2."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        emb = layers.embed(params["embedding"], tokens)
        nxt = jnp.concatenate([emb[:, 1:], emb[:, :1]], axis=1)
        h = jnp.concatenate([emb, nxt], axis=-1) @ params["mtp"]["proj"]
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        h, _, _ = _apply_block(params["mtp"]["block"], h, "attn", cfg, positions, None)
        h = layers.apply_norm(params["mtp"]["ln"], h, cfg.norm)
        head = params["embedding"] if cfg.tie_embeddings else params["lm_head"]
        logits = layers.lm_logits(head, h, cfg.tie_embeddings)
        t2 = jnp.roll(tokens, -2, axis=1)
        mask = jnp.ones_like(t2, jnp.float32).at[:, -2:].set(0.0)
        return _xent(logits, t2, mask)


def _xent(logits: jnp.ndarray, targets: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)
