from .model_zoo import ModelBundle, bundle  # noqa: F401
from .transformer import Model  # noqa: F401
