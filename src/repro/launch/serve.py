"""Serving driver: continuous-batching engine fed by a synthetic request
stream, optionally scheduled across a cluster by the paper's placement
engine.

Engine mode (one replica, real forward passes):
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --requests 16 --slots 4

Cluster mode (placement-integrated, paper use cases live):
  PYTHONPATH=src python -m repro.launch.serve --cluster --nodes 4 \
      --policy heuristic
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import bundle
from repro.serving import Engine, EngineConfig, Request
from repro.serving.cluster import ClusterServer


def run_engine(args) -> int:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg, capacity_factor=8.0)
    mb = bundle(cfg)
    params = mb.init(jax.random.key(0))
    eng = Engine(mb, params, EngineConfig(max_slots=args.slots, max_len=args.max_len))
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        plen = int(rng.integers(4, args.max_len // 4))
        prompt = list(map(int, rng.integers(1, cfg.vocab_size, size=plen)))
        eng.submit(Request(rid=f"req{i}", prompt=prompt,
                           max_new_tokens=int(rng.integers(4, args.max_new))))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    toks = sum(len(c.tokens) for c in done)
    print(f"{len(done)} completions, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:,.1f} tok/s), {eng.stats['decode_steps']} decode steps, "
          f"{eng.stats['prefills']} prefills")
    assert len(done) == args.requests
    return 0


def run_cluster(args) -> int:
    srv = ClusterServer(n_nodes=args.nodes, policy=args.policy)
    print(f"cluster: {args.nodes} pods, policy={args.policy}")
    # Scale-up wave (paper: initial deployment)
    for model, arch, n in (
        ("chat", "smollm-135m", 5),
        ("code", "chatglm3-6b", 3),
        ("draft", "xlstm-125m", 2),
    ):
        rep = srv.deploy(model, arch, n, max_batch=8, max_len=4096)
        print(f"  deploy {model} ({arch}) x{n}: placed={len(rep.placed)} "
              f"pending={len(rep.pending)} nodes_used={rep.metrics.n_gpus}")
    print(f"  utilization: {srv.utilization()}")
    # Scale-down + compaction (paper Sec 2.3.2)
    srv.retire("chat", 3)
    srv.retire("code", 1)
    rep = srv.compact()
    print(f"  compaction: {rep.before.n_gpus} -> {rep.after.n_gpus} nodes, "
          f"{rep.plan.n_moves} moves ({rep.plan.n_sequential} sequential)")
    # Maintenance reconfiguration (paper Sec 2.3.3)
    rep = srv.reconfigure()
    print(f"  reconfiguration: {rep.before.n_gpus} -> {rep.after.n_gpus} nodes, "
          f"wastage {rep.before.compute_wastage} -> {rep.after.compute_wastage}")
    print(f"  final: {srv.utilization()}")
    srv.state.validate()
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cluster", action="store_true")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--policy", default="heuristic",
                    choices=["heuristic", "mip", "first_fit", "load_balanced"])
    args = ap.parse_args()
    return run_cluster(args) if args.cluster else run_engine(args)


if __name__ == "__main__":
    raise SystemExit(main())
