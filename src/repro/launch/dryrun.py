import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input shape x mesh) cell
lowers AND compiles under the production meshes, and extract the roofline
terms from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k --mesh both
Artifacts: artifacts/dryrun/<mesh>/<arch>__<shape>.json
"""
import argparse
import json
import math
import time
import traceback
from typing import Any, Dict

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config
from repro.distribution import sharding as shd
from repro.distribution.hlo_analysis import analyze
from repro.kernels import ops as kops
from repro.launch.mesh import make_production_mesh
from repro.models import bundle
from repro.models import moe as moe_mod
from repro.training import optimizer as opt
from repro.training.train_loop import TrainConfig, make_train_step

# ---------------------------------------------------------------------------
# per-arch training memory policy (see DESIGN.md: memory-fit decisions)
# ---------------------------------------------------------------------------
_DEFAULT_POLICY = dict(moment_dtype="float32", accum_dtype="float32", microbatch=16)
TRAIN_POLICY: Dict[str, Dict[str, Any]] = {
    "mistral-large-123b": dict(moment_dtype="bfloat16", accum_dtype="bfloat16", microbatch=16),
    "nemotron-4-340b": dict(moment_dtype="int8", accum_dtype="bfloat16", microbatch=16),
    "deepseek-v3-671b": dict(moment_dtype="int8", accum_dtype="bfloat16", microbatch=16),
    "mixtral-8x7b": dict(moment_dtype="bfloat16", accum_dtype="bfloat16", microbatch=16),
    "pixtral-12b": dict(moment_dtype="float32", accum_dtype="bfloat16", microbatch=16),
}

#: hardware constants (TPU v5e)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / ICI link


def _policy(arch: str) -> Dict[str, Any]:
    return {**_DEFAULT_POLICY, **TRAIN_POLICY.get(arch, {})}


def build_cell(arch: str, shape_name: str, mesh, *, sp: bool, fsdp: bool,
               moe_impl: str = "dispatch"):
    """Returns (jitted fn, abstract args) ready to lower under `mesh`."""
    cfg = get_config(arch)
    mb = bundle(cfg)
    shape = SHAPES[shape_name]
    pol = _policy(arch)
    moe_mod.set_moe_impl(moe_impl)
    params_s = mb.param_shapes()
    pspecs = shd.param_specs(params_s, mesh, fsdp)
    pnamed = shd.named(pspecs, mesh)

    if shape.kind == "train":
        ocfg = opt.AdamWConfig(moment_dtype=pol["moment_dtype"])
        opt_s = jax.eval_shape(lambda p: opt.init(p, ocfg), params_s)
        onamed = shd.named(shd.opt_state_specs(params_s, opt_s, mesh, fsdp), mesh)
        tcfg = TrainConfig(microbatch=pol["microbatch"], remat=True,
                           accum_dtype=pol["accum_dtype"])
        step = make_train_step(mb, ocfg, tcfg)
        batch = mb.input_specs(shape)["batch"]
        bnamed = shd.named(shd.batch_specs(batch, mesh), mesh)
        fn = jax.jit(
            step,
            in_shardings=(pnamed, onamed, bnamed),
            out_shardings=(pnamed, onamed, None),
            donate_argnums=(0, 1),
        )
        return fn, (params_s, opt_s, batch)

    if shape.kind == "prefill":
        batch = mb.input_specs(shape)["batch"]
        bnamed = shd.named(shd.batch_specs(batch, mesh), mesh)

        def prefill(params, b):
            return mb.prefill_fn(params, b, max_len=shape.seq_len)

        fn = jax.jit(prefill, in_shardings=(pnamed, bnamed))
        return fn, (params_s, batch)

    # decode
    specs = mb.input_specs(shape)
    cache_s, tokens_s, index_s = specs["cache"], specs["tokens"], specs["index"]
    cnamed = shd.named(shd.cache_specs(cache_s, mesh, shape.global_batch), mesh)
    tnamed = shd.named(shd.batch_specs(tokens_s, mesh), mesh)
    inamed = NamedSharding(mesh, P())
    fn = jax.jit(
        mb.decode_fn,
        in_shardings=(pnamed, cnamed, tnamed, inamed),
        donate_argnums=(1,),
    )
    return fn, (params_s, cache_s, tokens_s, index_s)


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic useful-FLOPs for the cell (6·N_active·tokens train,
    2·N_active·tokens inference)."""
    mb = bundle(get_config(arch))
    n_active = mb.active_param_count()
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # one token per sequence


def run_cell(arch: str, shape_name: str, multi_pod: bool, *, sp: bool = False,
             fsdp: bool = True, moe_impl: str = "alltoall",
             kv_quant: bool = False,
             out_dir: str = "artifacts/dryrun", tag: str = "") -> Dict[str, Any]:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "sp": sp, "fsdp": fsdp, "moe_impl": moe_impl, "kv_quant": kv_quant,
        "status": "ok",
    }
    cfg = get_config(arch)
    mb = bundle(cfg)
    if not mb.supports_shape(SHAPES[shape_name]):
        cell["status"] = "skipped"
        cell["reason"] = "full-attention arch; long_500k needs sub-quadratic decode (DESIGN.md)"
        _write(cell, out_dir, mesh_name, arch, shape_name, tag)
        return cell
    # Weights-stationary inference: FSDP gathering re-collects every weight
    # per decoded token (§Perf iteration C1: -95% decode collective bytes).
    if SHAPES[shape_name].kind != "train":
        fsdp = False
        cell["fsdp"] = False
    from repro.models import layers as _layers

    try:
        kops.set_impl("jnp")
        _layers.set_kv_quant(kv_quant)
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = math.prod(mesh.shape.values())
        with shd.use_mesh(mesh, sequence_parallel=sp, fsdp=fsdp):
            t0 = time.time()
            fn, args = build_cell(arch, shape_name, mesh, sp=sp, fsdp=fsdp,
                                  moe_impl=moe_impl)
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0

        ma = compiled.memory_analysis()
        print(ma)  # proves it fits
        ca = compiled.cost_analysis()
        print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})
        tot = analyze(compiled.as_text())

        mf = model_flops(arch, shape_name)
        hlo_flops_total = tot.flops * n_dev
        # kernelized memory: bytes inside pallas_* named scopes are VMEM-
        # resident tiles on TPU (attention scores/probs, SSD chunk products)
        # — the CPU-lowered jnp path materializes them, the real kernel
        # does not.  Both terms are recorded; dominance uses the kernelized
        # one (that is what the TPU system ships).
        hbm_kernelized = max(tot.bytes - tot.kernel_bytes, 0.0)
        cell.update(
            n_devices=n_dev,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            per_device=dict(
                flops=tot.flops,
                hbm_bytes=tot.bytes,
                kernel_interior_bytes=tot.kernel_bytes,
                hbm_bytes_kernelized=hbm_kernelized,
                collective_bytes=tot.collective_bytes,
                argument_bytes=getattr(ma, "argument_size_in_bytes", None),
                temp_bytes=getattr(ma, "temp_size_in_bytes", None),
                output_bytes=getattr(ma, "output_size_in_bytes", None),
            ),
            xla_cost_analysis=dict(
                flops=ca.get("flops"), bytes_accessed=ca.get("bytes accessed")
            ),
            model_flops=mf,
            hlo_flops_total=hlo_flops_total,
            useful_ratio=(mf / hlo_flops_total) if hlo_flops_total else None,
            roofline=dict(
                compute_s=hlo_flops_total / (n_dev * PEAK_FLOPS),
                memory_s=hbm_kernelized / HBM_BW,
                collective_s=tot.total_collective_bytes / LINK_BW,
                memory_s_raw=tot.bytes / HBM_BW,
            ),
        )
        r = cell["roofline"]
        r["dominant"] = max(
            ("compute_s", "memory_s", "collective_s"), key=lambda k: r[k]
        )
    except Exception as e:  # noqa
        cell["status"] = "error"
        cell["error"] = f"{type(e).__name__}: {e}"
        cell["traceback"] = traceback.format_exc()[-4000:]
    finally:
        _layers.set_kv_quant(False)
    _write(cell, out_dir, mesh_name, arch, shape_name, tag)
    return cell


def _write(cell, out_dir, mesh_name, arch, shape_name, tag=""):
    d = os.path.join(out_dir, mesh_name)
    os.makedirs(d, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    with open(os.path.join(d, f"{arch}__{shape_name}{suffix}.json"), "w") as f:
        json.dump(cell, f, indent=1, default=str)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--sp", action="store_true", help="sequence parallelism")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--moe-impl", default="alltoall", choices=["dispatch", "alltoall"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                t0 = time.time()
                cell = run_cell(
                    arch, shape, mp, sp=args.sp, fsdp=not args.no_fsdp,
                    moe_impl=args.moe_impl, out_dir=args.out, tag=args.tag,
                )
                status = cell["status"]
                extra = ""
                if status == "ok":
                    r = cell["roofline"]
                    extra = (
                        f"compute={r['compute_s'] * 1e3:.1f}ms "
                        f"mem={r['memory_s'] * 1e3:.1f}ms "
                        f"coll={r['collective_s'] * 1e3:.1f}ms "
                        f"dom={r['dominant']} useful={cell['useful_ratio']:.2f}"
                    )
                elif status == "error":
                    failures += 1
                    extra = cell["error"][:160]
                print(
                    f"[{time.strftime('%H:%M:%S')}] {arch} x {shape} x "
                    f"{'multi' if mp else 'single'}: {status} "
                    f"({time.time() - t0:.0f}s) {extra}",
                    flush=True,
                )
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
