"""End-to-end training driver with fault tolerance.

Runs on whatever devices exist: the host mesh (CPU dev loop / smoke) or the
production pod mesh on TPU.  Features:

  * auto-resume: restores the latest atomic checkpoint if one exists —
    restart-after-failure IS the fault-tolerance path (kill the process at
    any step; relaunching continues from the last checkpoint);
  * elastic re-shard: checkpoints are device-count-agnostic (host-flat
    npz); restore re-places leaves onto the CURRENT mesh, so a job saved
    on N chips restores onto M;
  * async checkpointing off the critical path (``--ckpt-blocking`` to
    force synchronous writes);
  * deterministic data: batch t is a pure function of (seed, t), so a
    resumed run consumes exactly the tokens a never-failed run would.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 200 --batch 32 --seq 1024   # full config, real mesh
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg
from repro.distribution import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import bundle
from repro.training import data as data_mod
from repro.training import optimizer as opt
from repro.training.checkpoint import CheckpointManager
from repro.training.train_loop import TrainConfig, make_train_step


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", help="tiny config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-blocking", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--no-fsdp", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg, capacity_factor=8.0)
    mb = bundle(cfg)
    mesh = make_host_mesh()
    fsdp = not args.no_fsdp
    print(f"arch={cfg.name} params={mb.param_count():,} mesh={dict(mesh.shape)}")

    ocfg = opt.AdamWConfig(lr=args.lr)
    tcfg = TrainConfig(microbatch=args.microbatch, remat=True)
    step_fn = make_train_step(mb, ocfg, tcfg)
    dcfg = data_mod.DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed, frontend=cfg.frontend or ("audio" if cfg.enc_dec else None),
        frontend_len=cfg.frontend_len, frontend_dim=cfg.frontend_dim,
        dtype=cfg.dtype,
    )

    with shd.use_mesh(mesh, fsdp=fsdp):
        params = mb.init(jax.random.key(args.seed))
        opt_state = opt.init(params, ocfg)
        pnamed = shd.named(shd.param_specs(params, mesh, fsdp), mesh)
        onamed = shd.named(shd.opt_state_specs(params, opt_state, mesh, fsdp), mesh)
        params = jax.tree.map(jax.device_put, params, pnamed)
        opt_state = jax.tree.map(jax.device_put, opt_state, onamed)

        start = 0
        ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        if ckpt is not None:
            latest = ckpt.latest_step()
            if latest is not None:
                params, opt_state = ckpt.restore(
                    latest, params, opt_state, shardings=(pnamed, onamed)
                )
                start = latest + 1
                print(f"resumed from step {latest}")

        jitted = jax.jit(
            step_fn,
            in_shardings=(pnamed, onamed, None),
            out_shardings=(pnamed, onamed, None),
            donate_argnums=(0, 1),
        )

        losses = []
        t0 = time.time()
        for step in range(start, args.steps):
            batch = data_mod.shard_batch(data_mod.get_batch(dcfg, step), mesh)
            params, opt_state, metrics = jitted(params, opt_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if np.isnan(loss):
                raise FloatingPointError(f"NaN loss at step {step}")
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t0
                tput = args.batch * args.seq * args.log_every / max(dt, 1e-9)
                print(f"step {step:5d} loss {loss:8.4f} ({dt:5.1f}s, {tput:,.0f} tok/s)")
                t0 = time.time()
            if ckpt is not None and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step, params, opt_state, blocking=args.ckpt_blocking)
        if ckpt is not None:
            ckpt.save(args.steps - 1, params, opt_state, blocking=True)
            ckpt.wait()
        first = np.mean(losses[: max(1, len(losses) // 10)])
        last = np.mean(losses[-max(1, len(losses) // 10):])
        print(f"loss {first:.4f} -> {last:.4f} "
              f"({'improved' if last < first else 'NOT improved'})")
        return 0 if last < first else 1


if __name__ == "__main__":
    raise SystemExit(main())
