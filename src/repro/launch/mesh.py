"""Production mesh definitions.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before importing jax)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 v5e = 256 chips, axes (data, model).
    Multi-pod: 2 pods = 512 chips, axes (pod, data, model); the pod axis is
    the DCN boundary (data parallel / pipeline stage axis)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist right now, as a 1-D 'data' mesh (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
