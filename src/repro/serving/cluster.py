"""Placement-integrated cluster serving: core/ placement engine <-> engines.

This is where the paper's contribution becomes the framework's scheduler.
Each *replica* of a served model is a paper "workload"; its partition profile
is derived from the replica's real memory footprint (params + ragged KV cache
for its serving shape) via the TPU pod-partition device model.  The
ClusterServer then drives the three paper use cases over the live cluster:

  * ``deploy``      -> initial deployment (Sec 2.3.1)
  * ``compact``     -> compaction (Sec 2.3.2), periodic
  * ``reconfigure`` -> reconfiguration (Sec 2.3.3), maintenance windows

Placement policy is pluggable through ``core.engine.PlacementEngine``: the
Sec-4.2 heuristic (default), the WPM MIP, the fragmentation-aware
``frag_aware`` policy, or the first-fit / load-balanced baselines — the same
approaches the paper benchmarks, now acting on replicas instead of synthetic
workloads.  This layer holds NO policy dispatch of its own; it only
translates replicas <-> workloads and calls engine verbs.  ``fabric``
("auto"/"on"/"off") selects the vectorized fleet-scale fast path
(``core/fabric.py``) for large clusters.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Tuple

import jax

from ..configs import get_config
from ..core.engine import PlacementEngine
from ..core.metrics import PlacementMetrics, evaluate
from ..core.migration import MigrationPlan, plan_migration
from ..core.profiles import DeviceModel, Profile
from ..core.state import ClusterState, Workload
from ..core.tpu_profiles import TPU_V5E_POD, profile_for_chips
from ..models import bundle

__all__ = [
    "replica_footprint_bytes",
    "replica_profile",
    "ClusterServer",
    "DeployReport",
    "PlacementReport",
]


# ---------------------------------------------------------------------------
# replica sizing: arch -> memory footprint -> pod-partition profile
# ---------------------------------------------------------------------------
def replica_footprint_bytes(
    arch: str, max_batch: int = 8, max_len: int = 8192, headroom: float = 0.2
) -> int:
    """Serving HBM footprint of one replica: bf16 params + ragged decode
    cache for (max_batch, max_len), plus activation headroom."""
    mb = bundle(get_config(arch))
    params_b = 2 * mb.param_count()  # bf16 weights
    cfg = mb.cfg
    enc_len = cfg.frontend_len if cfg.enc_dec else 0
    cache = jax.eval_shape(
        lambda: mb.model.init_cache(max_batch, max_len, enc_len, ragged=True)
    )
    cache_b = sum(
        leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(cache)
    )
    return int((params_b + cache_b) * (1.0 + headroom))


def replica_profile(
    arch: str,
    max_batch: int = 8,
    max_len: int = 8192,
    device: DeviceModel = TPU_V5E_POD,
) -> Profile:
    """Smallest pod partition whose HBM fits one serving replica."""
    return profile_for_chips(replica_footprint_bytes(arch, max_batch, max_len), device)


# ---------------------------------------------------------------------------
# reports
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class DeployReport:
    placed: List[str]
    pending: List[str]
    plan: MigrationPlan
    metrics: PlacementMetrics


@dataclasses.dataclass
class PlacementReport:
    before: PlacementMetrics
    after: PlacementMetrics
    plan: MigrationPlan

    @property
    def gpus_saved(self) -> int:
        return self.before.n_gpus - self.after.n_gpus


# ---------------------------------------------------------------------------
# the cluster server
# ---------------------------------------------------------------------------
class ClusterServer:
    """A cluster of partitionable accelerators scheduled by the paper's
    placement engine.  GPUs are "pods" under the TPU device model but the
    class is device-model-agnostic (pass profiles.A100_80GB to schedule MIG
    GPUs instead)."""

    def __init__(
        self,
        n_nodes: int,
        device: DeviceModel = TPU_V5E_POD,
        policy: str = "heuristic",
        mip_time_limit: float = 30.0,
        fabric: str = "auto",
    ):
        self.device = device
        self.engine = PlacementEngine(policy, time_limit=mip_time_limit, fabric=fabric)
        self.policy = self.engine.policy_name
        self.mip_time_limit = mip_time_limit
        self.state = ClusterState.homogeneous(n_nodes, device, prefix="node")
        #: wid -> (model name, arch id)
        self.replicas: Dict[str, Tuple[str, str]] = {}
        self._counter = itertools.count()
        self._rr: Dict[str, int] = {}
        #: wid -> attached live Engine (local demos / tests)
        self.engines: Dict[str, Any] = {}

    # ---------------------------------------------------------------- deploy
    def deploy(
        self,
        model: str,
        arch: str,
        n_replicas: int = 1,
        *,
        max_batch: int = 8,
        max_len: int = 8192,
        profile_id: Optional[int] = None,
    ) -> DeployReport:
        """Initial deployment of n_replicas of ``model`` (paper Sec 2.3.1)."""
        if profile_id is None:
            profile_id = replica_profile(
                arch, max_batch, max_len, self.device
            ).profile_id
        news = []
        for _ in range(n_replicas):
            wid = f"{model}/r{next(self._counter)}"
            news.append(Workload(wid=wid, profile_id=profile_id, model=model))
            self.replicas[wid] = (model, arch)
        before = self.state.clone()
        pending = self._place_new(news)
        for w in pending:
            del self.replicas[w.wid]
        plan = plan_migration(before, self.state)
        return DeployReport(
            placed=[w.wid for w in news if w not in pending],
            pending=[w.wid for w in pending],
            plan=plan,
            metrics=self.metrics(),
        )

    def _place_new(self, news: List[Workload]) -> List[Workload]:
        return self.engine.deploy(self.state, news).pending

    # ---------------------------------------------------------------- retire
    def retire(self, model: str, n: int = 1) -> List[str]:
        """Remove up to n replicas of ``model`` (scale-down)."""
        victims = [w for w, (m, _) in self.replicas.items() if m == model][:n]
        for wid in victims:
            gid = self.state.gpu_of(wid)
            if gid is not None:
                self.state.gpus[gid].remove(wid)
            self.state.workloads.pop(wid, None)
            self.replicas.pop(wid, None)
            self.engines.pop(wid, None)
        return victims

    # ----------------------------------------------------------- compaction
    def compact(self) -> PlacementReport:
        """Vacate underutilized nodes (paper Sec 2.3.2); run periodically.

        Note: each policy now compacts with its OWN rule (the engine verb);
        the pre-engine code silently fell back to the Sec-4.2 heuristic for
        non-MIP policies, so baseline policies may pack less tightly here.
        """
        before_state = self.state.clone()
        before = evaluate(before_state)
        self.engine.compact(self.state)
        plan = plan_migration(before_state, self.state)
        return PlacementReport(before=before, after=evaluate(self.state, before_state), plan=plan)

    # -------------------------------------------------------- reconfiguration
    def reconfigure(self) -> PlacementReport:
        """Optimal re-placement of everything (paper Sec 2.3.3); maintenance."""
        before_state = self.state.clone()
        before = evaluate(before_state)
        self.engine.reconfigure(self.state)
        plan = plan_migration(before_state, self.state)
        return PlacementReport(before=before, after=evaluate(self.state, before_state), plan=plan)

    # ---------------------------------------------------------------- serving
    def replicas_of(self, model: str) -> List[str]:
        return [
            w for w, (m, _) in self.replicas.items()
            if m == model and self.state.gpu_of(w) is not None
        ]

    def route(self, model: str) -> str:
        """Round-robin replica choice for an incoming request."""
        reps = sorted(self.replicas_of(model))
        if not reps:
            raise LookupError(f"no live replicas of {model}")
        i = self._rr.get(model, 0) % len(reps)
        self._rr[model] = i + 1
        return reps[i]

    def attach_engine(self, wid: str, engine) -> None:
        self.engines[wid] = engine

    def submit(self, model: str, request) -> str:
        """Route a request to a replica's engine; returns the replica wid."""
        wid = self.route(model)
        if wid in self.engines:
            self.engines[wid].submit(request)
        return wid

    def pump(self, max_steps: int = 10_000) -> int:
        """Drive all attached engines until drained; returns tokens produced."""
        total = 0
        for _ in range(max_steps):
            live = [e for e in self.engines.values() if e.has_work]
            if not live:
                break
            for e in live:
                total += e.step()
        return total

    # ---------------------------------------------------------------- metrics
    def metrics(self) -> PlacementMetrics:
        return evaluate(self.state)

    def utilization(self) -> Dict[str, float]:
        used = self.state.used_gpus()
        if not used:
            return {"compute": 0.0, "memory": 0.0, "nodes_used": 0}
        c = sum(g.used_compute_slices() for g in used)
        m = sum(g.used_memory_slices() for g in used)
        return {
            "compute": c / (len(used) * self.device.n_gpu_slices),
            "memory": m / (len(used) * self.device.n_memory_slices),
            "nodes_used": len(used),
        }
