"""Placement-integrated cluster serving: core/ placement engine <-> engines.

This is where the paper's contribution becomes the framework's scheduler.
Each *replica* of a served model is a paper "workload"; its partition profile
is derived from the replica's real memory footprint (params + ragged KV cache
for its serving shape) via the TPU pod-partition device model.  The
ClusterServer then drives the three paper use cases over the live cluster:

  * ``deploy``      -> initial deployment (Sec 2.3.1)
  * ``compact``     -> compaction (Sec 2.3.2), periodic
  * ``reconfigure`` -> reconfiguration (Sec 2.3.3), maintenance windows

Placement policy is pluggable through ``core.engine.PlacementEngine``: the
Sec-4.2 heuristic (default), the WPM MIP, the fragmentation-aware
``frag_aware`` policy, or the first-fit / load-balanced baselines — the same
approaches the paper benchmarks, now acting on replicas instead of synthetic
workloads.  This layer holds NO policy dispatch of its own; it only
translates replicas <-> workloads and calls engine verbs.  ``fabric``
("auto"/"on"/"off") selects the vectorized fleet-scale fast path
(``core/fabric.py``) for large clusters.

Migration control plane
-----------------------
``compact`` / ``reconfigure`` ride the engine's plan/score/commit path: the
engine prices every plan with per-replica live bytes (bf16 weights + the
live KV cache of any attached engine, via ``kvcache.live_kv_bytes``) and a
``CommitPolicy`` decides whether the saved nodes justify the disruption.
Committed plans are then *executed stepwise* instead of teleporting:
disruptive moves drain their replica's in-flight work first, wave moves copy
state with KV handoff (the live decode cache follows the replica), and
drained replicas resume last — the ``ExecutionReport`` records every step.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple, Union

import jax

from ..configs import get_config
from ..core.autoscaler import Autoscaler, ModelLoad, ScaleDecision
from ..core.engine import PlacementEngine
from ..core.metrics import PlacementMetrics, evaluate
from ..core.migration import CommitPolicy, MigrationCostModel, MigrationPlan, PlanCost
from ..core.perfmodel import PerfModel
from ..core.profiles import DeviceModel, Profile
from ..core.state import ClusterState, Workload
from ..core.tpu_profiles import TPU_V5E_POD, profile_for_chips
from ..core.traffic import RequestShape
from ..models import bundle
from ..obs import get_telemetry
from .kvcache import live_kv_bytes

__all__ = [
    "replica_footprint_bytes",
    "replica_footprint_parts",
    "replica_profile",
    "ClusterServer",
    "DeployReport",
    "PlacementReport",
    "ExecutionReport",
    "MigrationStep",
    "AutoscaleReport",
    "NoReplicaError",
    "StepPolicy",
    "PlanExecutionError",
]


# ---------------------------------------------------------------------------
# faults & execution hardening
# ---------------------------------------------------------------------------
class NoReplicaError(LookupError):
    """``route()`` found no live replica of the model (all failed/retired).

    Callers that cannot wait should catch this; ``submit()`` catches it
    itself and parks the request in the model's backlog until a replica
    comes back (redeploy, repair, or recovery)."""

    def __init__(self, model: str):
        super().__init__(f"no live replicas of {model!r}")
        self.model = model


@dataclasses.dataclass(frozen=True)
class StepPolicy:
    """Retry/timeout envelope for one plan-execution step.

    Steps are synchronous, so ``timeout_seconds`` cannot preempt a stuck
    step — it measures the elapsed wall time after the step returns and
    treats an overrun as a failure (the runtime equivalent gave up on the
    worker and must redo the step elsewhere).  Failures back off
    exponentially from ``backoff_seconds`` up to ``backoff_cap_seconds``.
    """

    timeout_seconds: float = 30.0
    max_attempts: int = 3
    backoff_seconds: float = 0.05
    backoff_cap_seconds: float = 2.0

    def __post_init__(self) -> None:
        if self.max_attempts < 1 or self.timeout_seconds <= 0:
            raise ValueError(f"invalid step policy: {self}")


# ---------------------------------------------------------------------------
# replica sizing: arch -> memory footprint -> pod-partition profile
# ---------------------------------------------------------------------------
def replica_footprint_parts(
    arch: str, max_batch: int = 8, max_len: int = 8192
) -> Tuple[int, int]:
    """(weights bytes, reserved KV-cache bytes) of one serving replica:
    bf16 params + ragged decode cache for (max_batch, max_len)."""
    mb = bundle(get_config(arch))
    params_b = 2 * mb.param_count()  # bf16 weights
    cfg = mb.cfg
    enc_len = cfg.frontend_len if cfg.enc_dec else 0
    cache = jax.eval_shape(
        lambda: mb.model.init_cache(max_batch, max_len, enc_len, ragged=True)
    )
    return int(params_b), live_kv_bytes(cache)


#: activation headroom applied on top of weights + KV when sizing partitions.
FOOTPRINT_HEADROOM = 0.2


def replica_footprint_bytes(
    arch: str, max_batch: int = 8, max_len: int = 8192,
    headroom: float = FOOTPRINT_HEADROOM,
) -> int:
    """Serving HBM footprint of one replica: bf16 params + ragged decode
    cache for (max_batch, max_len), plus activation headroom."""
    params_b, cache_b = replica_footprint_parts(arch, max_batch, max_len)
    return int((params_b + cache_b) * (1.0 + headroom))


def replica_profile(
    arch: str,
    max_batch: int = 8,
    max_len: int = 8192,
    device: DeviceModel = TPU_V5E_POD,
) -> Profile:
    """Smallest pod partition whose HBM fits one serving replica."""
    return profile_for_chips(replica_footprint_bytes(arch, max_batch, max_len), device)


# ---------------------------------------------------------------------------
# reports
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MigrationStep:
    """One step of a plan's stepwise execution."""

    kind: str  # "drain" | "copy" | "cutover" | "resume"
    wid: str
    wave: int = -1  # -1 for drain/resume of disruptive moves
    kv_handoff: bool = False  # live decode cache followed the replica


@dataclasses.dataclass
class ExecutionReport:
    """What actually happened when a committed plan was executed."""

    steps: List[MigrationStep]
    drained: List[str]  # replicas that lost in-flight state windows
    handoffs: List[str]  # replicas whose live KV cache moved with them
    bytes_moved: int = 0
    downtime_seconds: float = 0.0
    #: step-machine outcome: did every step land (after retries)?
    completed: bool = True
    failed_step: str = ""  # "" when completed
    n_retries: int = 0  # step attempts beyond the first, summed
    rolled_back: bool = False  # failure undone: state byte-identical to pre-verb
    resumable: bool = False  # failure journaled: ``resume_execution()`` continues


class PlanExecutionError(RuntimeError):
    """A plan step kept failing after its retry budget.

    Carries the execution ``journal`` (keys of every step that DID land,
    in order) and the partial ``report`` so the caller can roll back or
    resume idempotently from the first unfinished step."""

    def __init__(self, step: str, attempts: int, cause: BaseException,
                 journal: List[Tuple[str, str, int]], report: "ExecutionReport"):
        super().__init__(
            f"plan step {step!r} failed after {attempts} attempts: {cause}"
        )
        self.step = step
        self.attempts = attempts
        self.cause = cause
        self.journal = journal
        self.report = report


@dataclasses.dataclass
class DeployReport:
    placed: List[str]
    pending: List[str]
    plan: MigrationPlan
    metrics: PlacementMetrics
    cost: Optional[PlanCost] = None


@dataclasses.dataclass
class AutoscaleReport:
    """One ``ClusterServer.autoscale()`` control tick."""

    decisions: List[ScaleDecision]
    offered_rps: Dict[str, float]
    deployed: Dict[str, List[str]] = dataclasses.field(default_factory=dict)
    retired: Dict[str, List[str]] = dataclasses.field(default_factory=dict)
    #: scale-up replicas the engine could not place this tick.
    rejected: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def scaled(self) -> bool:
        return bool(self.deployed or self.retired)


@dataclasses.dataclass
class PlacementReport:
    before: PlacementMetrics
    after: PlacementMetrics
    plan: MigrationPlan
    cost: Optional[PlanCost] = None
    committed: bool = True
    execution: Optional[ExecutionReport] = None
    #: replicas a committed baseline-replay reconfigure failed to re-place
    #: (measured Sec-5.2.3 behavior) — fully retired from the server.
    evicted: List[str] = dataclasses.field(default_factory=list)

    @property
    def gpus_saved(self) -> int:
        return self.before.n_gpus - self.after.n_gpus


# ---------------------------------------------------------------------------
# the cluster server
# ---------------------------------------------------------------------------
class ClusterServer:
    """A cluster of partitionable accelerators scheduled by the paper's
    placement engine.  GPUs are "pods" under the TPU device model but the
    class is device-model-agnostic (pass profiles.A100_80GB to schedule MIG
    GPUs instead)."""

    def __init__(
        self,
        n_nodes: int,
        device: DeviceModel = TPU_V5E_POD,
        policy: str = "heuristic",
        mip_time_limit: float = 30.0,
        fabric: str = "auto",
        commit: Union[str, CommitPolicy] = "always",
        cost_model: Optional[MigrationCostModel] = None,
        plan_deploys: bool = True,
        autoscaler: Optional[Autoscaler] = None,
        perf: Optional[PerfModel] = None,
        engine_factory: Optional[Callable[[str, str, str], Any]] = None,
        autoscale_window: float = 30.0,
        step_policy: Optional[StepPolicy] = None,
        on_execution_failure: str = "rollback",
    ):
        if on_execution_failure not in ("rollback", "resume"):
            raise ValueError(
                "on_execution_failure must be 'rollback' or 'resume', "
                f"got {on_execution_failure!r}"
            )
        self.device = device
        # plan_deploys=True gives DeployReport a scored plan; turn it off on
        # fleet-scale servers where the per-deploy clone + diff walk would
        # defeat the fabric fast path (DeployReport.plan/cost become None).
        self.engine = PlacementEngine(
            policy,
            time_limit=mip_time_limit,
            fabric=fabric,
            commit=commit,
            cost_model=cost_model,
            plan_deploys=plan_deploys,
        )
        self.engine.bytes_for = self._replica_bytes
        self.policy = self.engine.policy_name
        self.mip_time_limit = mip_time_limit
        self.state = ClusterState.homogeneous(n_nodes, device, prefix="node")
        #: wid -> (model name, arch id)
        self.replicas: Dict[str, Tuple[str, str]] = {}
        self._counter = itertools.count()
        self._rr: Dict[str, int] = {}
        #: wid -> attached live Engine (local demos / tests)
        self.engines: Dict[str, Any] = {}
        #: wid -> (weights bytes, reserved KV bytes) for migration pricing
        self._footprints: Dict[str, Tuple[int, int]] = {}
        #: (arch, max_batch, max_len) -> parts, so repeat deploys stay cheap
        self._parts_cache: Dict[Tuple[str, int, int], Tuple[int, int]] = {}
        # -- demand loop (autoscale) ----------------------------------------
        self.autoscaler = autoscaler
        self.perf = perf or PerfModel()
        #: (model, arch, wid) -> live Engine, attached to scale-up replicas.
        self.engine_factory = engine_factory
        self.autoscale_window = autoscale_window
        #: model -> (arch, profile_id) remembered from the first deploy, so
        #: autoscale() knows how to mint more replicas of the model.
        self._model_specs: Dict[str, Tuple[str, Optional[int]]] = {}
        #: model -> recent submit() timestamps (offered-load window).
        self._req_times: Dict[str, Deque[float]] = collections.defaultdict(
            collections.deque
        )
        #: model -> running request shape for capacity estimation.
        self._req_shapes: Dict[str, RequestShape] = {}
        # -- fault tolerance -------------------------------------------------
        self.step_policy = step_policy or StepPolicy()
        #: "rollback": a failed plan execution undoes the verb entirely;
        #: "resume": keep the committed layout + journal and let
        #: ``resume_execution()`` finish the remaining steps.
        self.on_execution_failure = on_execution_failure
        #: step kind -> remaining injected failures (tests / chaos drills).
        self._failpoints: Dict[str, int] = {}
        self._sleep: Callable[[float], None] = time.sleep
        #: (plan, journal) of a partially-executed plan awaiting resume.
        self._pending_plan: Optional[
            Tuple[MigrationPlan, List[Tuple[str, str, int]]]
        ] = None
        #: model -> requests parked by submit() while no replica was live.
        self._backlog: Dict[str, Deque[Any]] = collections.defaultdict(
            collections.deque
        )
        #: fault-evicted wids: a late departure/retire for one is a no-op.
        self._fault_evicted: set = set()
        self.n_ghost_departures = 0

    # -- migration pricing: live bytes per replica --------------------------
    def _replica_bytes(self, wid: str) -> Optional[int]:
        """Weights + live KV bytes of ``wid`` for the migration cost model.

        The weight half comes from the replica's sized footprint; the KV
        half prefers the *live* decode cache of an attached engine (what a
        KV handoff actually copies) over the reservation-sized estimate.
        Returns None for unknown replicas (cost model falls back to the
        partition-sized estimate).
        """
        parts = self._footprints.get(wid)
        if parts is None:
            return None
        weights_b, kv_b = parts
        eng = self.engines.get(wid)
        if eng is not None and getattr(eng, "cache", None) is not None:
            kv_b = live_kv_bytes(eng.cache)
        return weights_b + kv_b

    # ---------------------------------------------------------------- deploy
    def deploy(
        self,
        model: str,
        arch: str,
        n_replicas: int = 1,
        *,
        max_batch: int = 8,
        max_len: int = 8192,
        profile_id: Optional[int] = None,
    ) -> DeployReport:
        """Initial deployment of n_replicas of ``model`` (paper Sec 2.3.1)."""
        parts: Optional[Tuple[int, int]] = None
        if profile_id is None:
            key = (arch, max_batch, max_len)
            parts = self._parts_cache.get(key)
            if parts is None:
                parts = replica_footprint_parts(arch, max_batch, max_len)
                self._parts_cache[key] = parts
            total = int(sum(parts) * (1.0 + FOOTPRINT_HEADROOM))
            profile_id = profile_for_chips(total, self.device).profile_id
        self._model_specs.setdefault(model, (arch, profile_id))
        news = []
        for _ in range(n_replicas):
            wid = f"{model}/r{next(self._counter)}"
            news.append(Workload(wid=wid, profile_id=profile_id, model=model))
            self.replicas[wid] = (model, arch)
            if parts is not None:
                self._footprints[wid] = parts
        res = self.engine.deploy(self.state, news)
        pending = res.pending
        for w in pending:
            del self.replicas[w.wid]
            self._footprints.pop(w.wid, None)
        if self._backlog.get(model):
            self._flush_backlog(model)
        return DeployReport(
            placed=[w.wid for w in news if w not in pending],
            pending=[w.wid for w in pending],
            plan=res.plan,
            metrics=self.metrics(),
            cost=res.cost,
        )

    # ---------------------------------------------------------------- retire
    def retire(self, model: str, n: int = 1) -> List[str]:
        """Remove up to n replicas of ``model`` (scale-down).

        Replicas whose attached engine is idle go first; a busy victim is
        pumped dry before teardown so no in-flight request is lost."""
        candidates = [w for w, (m, _) in self.replicas.items() if m == model]
        candidates.sort(
            key=lambda w: (getattr(self.engines.get(w), "has_work", False), w)
        )
        victims = candidates[:n]
        for wid in victims:
            eng = self.engines.get(wid)
            while eng is not None and getattr(eng, "has_work", False):
                eng.step()
        for wid in victims:
            gid = self.state.gpu_of(wid)
            if gid is not None:
                self.state.gpus[gid].remove(wid)
            self.state.workloads.pop(wid, None)
            self.replicas.pop(wid, None)
            self.engines.pop(wid, None)
            self._footprints.pop(wid, None)
        return victims

    # ----------------------------------------------------------- compaction
    def compact(self) -> PlacementReport:
        """Vacate underutilized nodes (paper Sec 2.3.2); run periodically.

        Note: each policy now compacts with its OWN rule (the engine verb);
        the pre-engine code silently fell back to the Sec-4.2 heuristic for
        non-MIP policies, so baseline policies may pack less tightly here.
        """
        return self._gated_verb("compact")

    # -------------------------------------------------------- reconfiguration
    def reconfigure(self) -> PlacementReport:
        """Optimal re-placement of everything (paper Sec 2.3.3); maintenance."""
        return self._gated_verb("reconfigure")

    def _gated_verb(self, verb: str) -> PlacementReport:
        """Engine plan/score/commit, then stepwise execution of the plan.

        The whole verb runs inside an outer state transaction: the engine's
        own commit splices into it, so when plan *execution* dies mid-step
        with ``on_execution_failure="rollback"`` the fleet is restored
        byte-identical to its pre-verb layout (the committed-but-unexecuted
        placements are undone).  With ``"resume"`` the committed layout and
        the execution journal survive; ``resume_execution()`` continues from
        the first unfinished step.
        """
        committed = False
        execution: Optional[ExecutionReport] = None
        with self.state.transaction() as txn:
            res = getattr(self.engine, verb)(self.state)
            # res.baseline is the engine's own pre-verb snapshot — reuse it
            # for the before/after metrics rather than cloning the fleet
            # twice.
            before_state = res.baseline
            committed = res.committed
            if res.committed and res.plan is not None:
                try:
                    execution = self._execute_plan(res.plan)
                except PlanExecutionError as e:
                    execution = e.report
                    if self.on_execution_failure == "resume":
                        self._pending_plan = (res.plan, list(e.journal))
                        execution.resumable = True
                    else:
                        txn.rollback()
                        execution.rolled_back = True
                        committed = False
        evicted = []
        if committed:
            # A committed baseline-replay reconfigure may fail to re-place
            # some replicas (its adopt removed them): retire them everywhere
            # so no ghost replica lingers in routing/engines/footprints.
            for w in res.pending:
                if w.wid in self._fault_evicted:
                    self.n_ghost_departures += 1
                    self._fault_evicted.discard(w.wid)
                    continue
                if w.wid in self.replicas:
                    evicted.append(w.wid)
                self.state.workloads.pop(w.wid, None)
                self.replicas.pop(w.wid, None)
                self.engines.pop(w.wid, None)
                self._footprints.pop(w.wid, None)
        return PlacementReport(
            before=evaluate(before_state),
            after=evaluate(self.state, before_state),
            plan=res.plan,
            cost=res.cost,
            committed=committed,
            execution=execution,
            evicted=evicted,
        )

    # ------------------------------------------------------- plan execution
    def inject_step_failure(self, kind: str, times: int = 1) -> None:
        """Arm a failpoint: the next ``times`` attempts of any step of
        ``kind`` ("drain" / "copy" / "cutover" / "resume") raise.  Chaos
        drills and tests use this to exercise retry / rollback / resume."""
        if times < 1:
            raise ValueError(f"times must be >= 1, got {times}")
        self._failpoints[kind] = self._failpoints.get(kind, 0) + times

    def _maybe_failpoint(self, kind: str) -> None:
        n = self._failpoints.get(kind, 0)
        if n > 0:
            if n == 1:
                del self._failpoints[kind]
            else:
                self._failpoints[kind] = n - 1
            raise RuntimeError(f"injected failure at step {kind!r}")

    def _plan_steps(
        self, plan: MigrationPlan
    ) -> List[Tuple[str, List[Tuple[str, str, int, bool]]]]:
        """Expand a plan into phases of (kind, wid, wave, kv_handoff) steps.

        Order matches the runtime transition: disruptive moves drain their
        replica first, wave moves copy + cut over, drained replicas copy
        weights and resume last, cold.  Step keys ``(kind, wid, wave)`` are
        stable across calls — the execution journal is keyed on them so a
        resumed execution skips exactly the steps that already landed.
        """
        phases: List[Tuple[str, List[Tuple[str, str, int, bool]]]] = []
        phases.append(
            ("drain", [("drain", mv.wid, -1, False) for mv in plan.disruptive])
        )
        for i, wave in enumerate(plan.waves):
            steps: List[Tuple[str, str, int, bool]] = []
            for mv in wave:
                if mv.src_gid is None:
                    continue  # fresh deployment: nothing to copy
                handoff = mv.wid in self.engines
                steps.append(("copy", mv.wid, i, handoff))
                steps.append(("cutover", mv.wid, i, False))
            phases.append((f"copy_wave:{i}", steps))
        resume: List[Tuple[str, str, int, bool]] = []
        for mv in plan.disruptive:
            # drained replicas still transfer their weights (KV went cold
            # with the drain, so no handoff) before the cold resume.
            resume.append(("copy", mv.wid, -1, False))
            resume.append(("resume", mv.wid, -1, False))
        phases.append(("resume", resume))
        return phases

    def _perform_step(self, step: Tuple[str, str, int, bool]) -> None:
        """One step's side effects.  Steps are idempotent: a drain pumps an
        already-dry engine zero times, copy/cutover/resume re-assert
        bookkeeping — a retry or resume may safely redo a step whose first
        attempt died after the work landed."""
        kind, wid, _, _ = step
        if kind == "drain":
            eng = self.engines.get(wid)
            while eng is not None and getattr(eng, "has_work", False):
                eng.step()  # finish in-flight requests before teardown

    def _run_step(self, step: Tuple[str, str, int, bool], tel) -> int:
        """Run one step under the ``StepPolicy`` envelope; returns the
        number of retries spent.  Raises the last failure once the attempt
        budget is exhausted."""
        pol = self.step_policy
        kind = step[0]
        delay = pol.backoff_seconds
        last: Optional[BaseException] = None
        for attempt in range(1, pol.max_attempts + 1):
            t0 = time.monotonic()
            try:
                self._maybe_failpoint(kind)
                self._perform_step(step)
                if time.monotonic() - t0 > pol.timeout_seconds:
                    # Synchronous steps can't be preempted: an overrun is
                    # detected after the fact and treated as a failure (the
                    # runtime gave up on this worker).
                    raise TimeoutError(
                        f"step {kind!r} overran {pol.timeout_seconds}s"
                    )
                return attempt - 1
            except Exception as e:  # noqa: BLE001 - every failure retries
                last = e
                if tel.enabled:
                    tel.metrics.counter(
                        "plan_step_retries_total",
                        "plan-execution step attempts that failed",
                        labels={"kind": kind},
                    ).inc()
                if attempt < pol.max_attempts:
                    self._sleep(min(delay, pol.backoff_cap_seconds))
                    delay *= 2.0
        assert last is not None
        raise last

    def _execute_plan(
        self,
        plan: MigrationPlan,
        completed: Optional[List[Tuple[str, str, int]]] = None,
    ) -> ExecutionReport:
        """Execute a committed plan as a journaled step machine.

        The cluster state already holds the final layout (the engine
        committed it); this walks the *runtime* transition.  Disruptive
        moves drain their replica first (in-flight work on an attached
        engine is pumped to completion — no tokens are lost, but the
        replica's slots go cold).  Wave moves copy state with a cutover; an
        attached engine object stays bound to its wid through the move —
        the live decode cache rides along (KV handoff).  Drained replicas
        resume last, cold.

        Every step runs under the server's ``StepPolicy`` (timeout +
        bounded exponential-backoff retry) and its key is journaled when it
        lands.  ``completed`` (from a prior attempt's journal) skips steps
        that already executed, making resume idempotent.  A step that
        exhausts its budget raises ``PlanExecutionError`` carrying the
        journal and the partial report.
        """
        tel = get_telemetry()
        done = set(completed or ())
        journal: List[Tuple[str, str, int]] = list(completed or ())
        steps: List[MigrationStep] = []
        drained: List[str] = []
        handoffs: List[str] = []
        n_retries = 0
        failure: Optional[Tuple[str, BaseException]] = None
        with tel.tracer.span("execute_plan") as sp:
            for label, phase_steps in self._plan_steps(plan):
                # span names stay "drain" / "copy_wave" / "resume"
                with tel.tracer.span(label.split(":")[0]) as psp:
                    n_landed = 0
                    for st in phase_steps:
                        kind, wid, wave, handoff = st
                        key = (kind, wid, wave)
                        if key in done:
                            continue  # landed in a previous attempt
                        try:
                            n_retries += self._run_step(st, tel)
                        except Exception as e:  # noqa: BLE001
                            failure = (kind, e)
                            break
                        done.add(key)
                        journal.append(key)
                        steps.append(
                            MigrationStep(kind, wid, wave=wave, kv_handoff=handoff)
                        )
                        if kind == "drain":
                            drained.append(wid)
                        if handoff:
                            handoffs.append(wid)
                        n_landed += 1
                    if tel.enabled:
                        psp.set(n_steps=n_landed)
                        if label.startswith("copy_wave"):
                            psp.set(wave=int(label.split(":")[1]))
                if failure is not None:
                    break
            # The engine already priced this exact plan (same state, same
            # bytes_for) when it scored the commit; fresh deployments priced at
            # zero there, so the totals are the executed moves' totals.
            cost = plan.cost
            if cost is None:  # plans from older call sites: price once here
                cost = self.engine.cost_model.price(
                    plan, self.state, bytes_for=self.engine.bytes_for
                )
            bytes_moved = cost.total_bytes
            downtime = cost.downtime_seconds
            if tel.enabled:
                sp.set(n_steps=len(steps), n_waves=len(plan.waves),
                       n_drained=len(drained), n_handoffs=len(handoffs),
                       n_retries=n_retries, completed=failure is None,
                       bytes_moved=bytes_moved, downtime_seconds=downtime)
                tel.metrics.counter(
                    "kv_handoffs_total", "replicas whose live KV moved with them",
                ).inc(float(len(handoffs)))
        report = ExecutionReport(
            steps=steps,
            drained=drained,
            handoffs=handoffs,
            bytes_moved=bytes_moved,
            downtime_seconds=downtime,
            completed=failure is None,
            failed_step=failure[0] if failure else "",
            n_retries=n_retries,
        )
        if failure is not None:
            raise PlanExecutionError(
                step=failure[0],
                attempts=self.step_policy.max_attempts,
                cause=failure[1],
                journal=journal,
                report=report,
            )
        return report

    def resume_execution(self) -> Optional[ExecutionReport]:
        """Finish a plan whose execution died mid-step (``"resume"`` mode).

        Re-runs the pending plan, skipping every journaled step; returns
        the new report, or None when nothing is pending.  If execution
        fails again the (extended) journal is kept for the next attempt.
        """
        if self._pending_plan is None:
            return None
        plan, journal = self._pending_plan
        try:
            report = self._execute_plan(plan, completed=journal)
        except PlanExecutionError as e:
            self._pending_plan = (plan, list(e.journal))
            e.report.resumable = True
            raise
        self._pending_plan = None
        return report

    # ------------------------------------------------------- fault handling
    def fail_node(self, gid: str) -> Dict[str, Any]:
        """A node died: quarantine it, evict its replicas, and re-place
        them through the engine.

        Queued requests on evicted replicas' engines move to their model's
        backlog (requeued, not lost).  If the plain re-deploy cannot fit
        every evicted replica, the commit policy's emergency tier kicks in:
        budgets are lifted and a compact/reconfigure repacks the surviving
        fleet to make room.  Replicas that still don't fit are retired
        (capacity is really gone); their requests stay backlogged for
        ``repair_node`` / a later ``deploy``.
        """
        gpu = self.state.gpus[gid]
        tel = get_telemetry()
        with tel.tracer.span("fail_node") as sp:
            self.state.set_health(gid, "failed")
            victims = [pl.wid for pl in gpu.placements]
            evicted: List[Workload] = []
            models: List[str] = []
            for wid in victims:
                w = self.state.workloads.get(wid)
                eng = self.engines.pop(wid, None)
                if eng is not None and wid in self.replicas:
                    model = self.replicas[wid][0]
                    for req in list(getattr(eng, "queue", ())):
                        self._backlog[model].append(req)
                self.state.remove(wid, gid)
                if w is not None and wid in self.replicas:
                    self.state.forget_workload(wid)
                    evicted.append(w)
                    models.append(self.replicas[wid][0])
            if tel.enabled:
                tel.metrics.counter(
                    "failures_total", "injected/declared node failures",
                    labels={"kind": "gpu_failure"},
                ).inc()
            tel.tracer.event(
                "fault", time=time.time(), kind="gpu_failure", gid=gid,
                n_evicted=len(evicted),
            )
            recovered, lost, emergency = self._replace_evicted(evicted)
            for model in dict.fromkeys(models):
                self._flush_backlog(model)
            if tel.enabled:
                sp.set(gid=gid, n_evicted=len(evicted),
                       n_recovered=len(recovered), n_lost=len(lost),
                       emergency=emergency)
        return {
            "gid": gid,
            "evicted": [w.wid for w in evicted],
            "recovered": recovered,
            "lost": lost,
            "emergency": emergency,
        }

    def _replace_evicted(
        self, evicted: List[Workload]
    ) -> Tuple[List[str], List[str], bool]:
        """Re-place fault-evicted replicas; escalate if they don't fit."""
        if not evicted:
            return [], [], False
        res = self.engine.deploy(self.state, list(evicted))
        pending = {w.wid for w in res.pending}
        emergency = False
        if pending and self.engine.commit_policy.escalate() is not None:
            saved = self.engine.commit_policy
            self.engine.commit_policy = saved.escalate()
            try:
                for verb in ("compact", "reconfigure"):
                    if verb not in self.engine.policy.supports:
                        continue
                    report = self._gated_verb(verb)
                    if report.committed:
                        emergency = True
                        tel = get_telemetry()
                        tel.tracer.event(
                            "emergency_commit", time=time.time(), verb=verb
                        )
                    retry = [
                        self.state.workloads[wid] for wid in sorted(pending)
                        if wid in self.state.workloads
                    ]
                    if not retry:
                        break
                    res = self.engine.deploy(self.state, retry)
                    pending = {w.wid for w in res.pending}
                    if not pending:
                        break
            finally:
                self.engine.commit_policy = saved
        lost = sorted(pending)
        for wid in lost:  # capacity is really gone: retire everywhere
            self.state.workloads.pop(wid, None)
            self.replicas.pop(wid, None)
            self.engines.pop(wid, None)
            self._footprints.pop(wid, None)
            self._fault_evicted.add(wid)
        recovered = [w.wid for w in evicted if w.wid not in pending]
        return recovered, lost, emergency

    def repair_node(self, gid: str) -> None:
        """Return a quarantined node to service and drain any backlog."""
        self.state.set_health(gid, "healthy")
        tel = get_telemetry()
        tel.tracer.event("repair", time=time.time(), gid=gid)
        for model in list(self._backlog):
            if self._backlog[model]:
                self._flush_backlog(model)

    # ---------------------------------------------------------------- serving
    def replicas_of(self, model: str) -> List[str]:
        return [
            w for w, (m, _) in self.replicas.items()
            if m == model and self.state.gpu_of(w) is not None
        ]

    def route(self, model: str) -> str:
        """Round-robin replica choice for an incoming request.

        Raises ``NoReplicaError`` when no replica of ``model`` is placed
        (all failed, evicted, or retired)."""
        reps = sorted(self.replicas_of(model))
        if not reps:
            raise NoReplicaError(model)
        i = self._rr.get(model, 0) % len(reps)
        self._rr[model] = i + 1
        return reps[i]

    def attach_engine(self, wid: str, engine) -> None:
        self.engines[wid] = engine

    def submit(self, model: str, request, now: Optional[float] = None) -> Optional[str]:
        """Route a request to a replica's engine; returns the replica wid.

        Every submit is logged into the model's offered-load window so
        ``autoscale()`` can derive arrival rates; pass ``now`` to drive a
        simulated clock (defaults to wall time).  When no replica is live
        (mid-outage) the request is parked in the model's backlog and
        ``None`` is returned; the backlog drains on the next successful
        ``deploy`` / ``repair_node`` of the model."""
        ts = time.time() if now is None else now
        times = self._req_times[model]
        times.append(ts)
        # keep the log bounded to the window even if autoscale() never runs
        while times and times[0] < ts - self.autoscale_window:
            times.popleft()
        self._req_shapes.setdefault(model, RequestShape()).add(
            len(getattr(request, "prompt", ())),
            int(getattr(request, "max_new_tokens", 0)),
        )
        try:
            wid = self.route(model)
        except NoReplicaError:
            self._backlog[model].append(request)
            tel = get_telemetry()
            if tel.enabled:
                tel.metrics.counter(
                    "backlogged_requests_total",
                    "requests parked while a model had no live replica",
                    labels={"model": model},
                ).inc()
            return None
        if wid in self.engines:
            self.engines[wid].submit(request)
        return wid

    def _flush_backlog(self, model: str) -> int:
        """Re-route parked requests once ``model`` has live replicas again.

        The requests were already logged into the offered-load window at
        their original ``submit()``, so flushing routes them directly."""
        q = self._backlog.get(model)
        n = 0
        while q:
            try:
                wid = self.route(model)
            except NoReplicaError:
                break
            req = q.popleft()
            if wid in self.engines:
                self.engines[wid].submit(req)
            n += 1
        return n

    # -------------------------------------------------------------- autoscale
    def _offered_rps(self, model: str, now: float) -> float:
        """Arrival rate over the trailing ``autoscale_window`` seconds."""
        times = self._req_times[model]
        while times and times[0] < now - self.autoscale_window:
            times.popleft()
        return len(times) / max(self.autoscale_window, 1e-9)

    def _queue_depth(self, model: str) -> int:
        return sum(
            len(getattr(self.engines[w], "queue", ()))
            for w in self.replicas_of(model)
            if w in self.engines
        )

    def autoscale(
        self,
        now: Optional[float] = None,
        attainment: Optional[Dict[str, float]] = None,
    ) -> AutoscaleReport:
        """One control tick of the demand loop over LIVE engines.

        Measures each deployed model's offered load from its recent
        ``submit()`` history, sizes replica capacity with the perf model,
        and applies the ``Autoscaler``'s decisions through ``deploy`` /
        ``retire`` — the same engine-gated paths a human operator would use.
        Newly placed replicas get an engine from ``engine_factory`` when one
        is configured.  ``attainment`` (model -> fraction meeting SLO over
        the caller's window) feeds the controller's slo mode; callers that
        do not measure latency omit it and run target-utilization sizing.
        """
        if self.autoscaler is None:
            raise RuntimeError("ClusterServer built without an autoscaler")
        ts = time.time() if now is None else now
        observations: List[ModelLoad] = []
        for model in sorted(self._model_specs):
            arch, profile_id = self._model_specs[model]
            mean_p, mean_d = self._req_shapes.get(
                model, RequestShape()
            ).means()
            observations.append(ModelLoad(
                model=model,
                offered_rps=self._offered_rps(model, ts),
                capacity_rps=self.perf.capacity_rps(
                    self.device, profile_id, mean_p, mean_d
                ),
                replicas=len(self.replicas_of(model)),
                queue_depth=self._queue_depth(model),
                slo_attainment=(attainment or {}).get(model, 1.0),
            ))
        decisions = self.autoscaler.tick(ts, observations)
        report = AutoscaleReport(
            decisions=decisions,
            offered_rps={o.model: o.offered_rps for o in observations},
        )
        for dec in decisions:
            if dec.delta > 0:
                arch, profile_id = self._model_specs[dec.model]
                rep = self.deploy(
                    dec.model, arch, n_replicas=dec.delta, profile_id=profile_id
                )
                report.deployed[dec.model] = rep.placed
                if rep.pending:
                    report.rejected[dec.model] = len(rep.pending)
                if self.engine_factory is not None:
                    for wid in rep.placed:
                        self.attach_engine(
                            wid, self.engine_factory(dec.model, arch, wid)
                        )
            elif dec.delta < 0:
                report.retired[dec.model] = self.retire(dec.model, -dec.delta)
        return report

    def pump(self, max_steps: int = 10_000) -> int:
        """Drive all attached engines until drained; returns tokens produced."""
        total = 0
        for _ in range(max_steps):
            live = [e for e in self.engines.values() if e.has_work]
            if not live:
                break
            for e in live:
                total += e.step()
        return total

    # ---------------------------------------------------------------- metrics
    def metrics(self) -> PlacementMetrics:
        return evaluate(self.state)

    def utilization(self) -> Dict[str, float]:
        used = self.state.used_gpus()
        if not used:
            return {"compute": 0.0, "memory": 0.0, "nodes_used": 0}
        c = sum(g.used_compute_slices() for g in used)
        m = sum(g.used_memory_slices() for g in used)
        return {
            "compute": c / (len(used) * self.device.n_gpu_slices),
            "memory": m / (len(used) * self.device.n_memory_slices),
            "nodes_used": len(used),
        }
