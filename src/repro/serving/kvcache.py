"""KV-cache state management for continuous-batching serving.

Two layers:

1. ``insert_prefix`` — JetStream-style decode-state surgery: a batch-1
   prefill cache is copied into one *slot* of the ragged decode cache
   (every non-index leaf has batch at axis 1 because layer stacks put the
   scan dim first; ``index`` leaves hold the per-slot valid length).

2. ``PagedKVCache`` — a paged cache substrate (block pool + block tables),
   the TPU analogue of vLLM's PagedAttention memory manager.  Pages remove
   the contiguous-max_len reservation per slot: HBM is allocated in
   fixed-size blocks and sequences map to scattered blocks via a table.
   ``gather`` linearizes a sequence's pages for the decode-attention kernel;
   the host-side ``BlockAllocator`` does alloc/free bookkeeping.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

__all__ = ["insert_prefix", "live_kv_bytes", "BlockAllocator", "PagedKVCache"]


def live_kv_bytes(cache: Any) -> int:
    """Bytes held by a live KV-cache pytree (decode state or PagedKVCache).

    This is the *live-state* half of a migration's transfer size: when a
    replica moves between partitions with KV handoff, its decode cache rides
    along with the weights.  Works on any pytree of arrays (ragged decode
    caches, paged pools, ShapeDtypeStructs from ``jax.eval_shape``).
    """
    if isinstance(cache, PagedKVCache):
        cache = (cache.pool_k, cache.pool_v)
    return int(
        sum(
            leaf.size * leaf.dtype.itemsize
            for leaf in jax.tree_util.tree_leaves(cache)
            if hasattr(leaf, "dtype")
        )
    )


# ---------------------------------------------------------------------------
# decode-state slot insertion
# ---------------------------------------------------------------------------
def _is_index_leaf(path) -> bool:
    last = path[-1]
    key = getattr(last, "key", getattr(last, "name", None))
    return key == "index"


@functools.partial(jax.jit, donate_argnums=(0,))
def insert_prefix(
    decode_cache: Params, prefix_cache: Params, slot: jnp.ndarray, length: jnp.ndarray
) -> Params:
    """Copy a batch-1 prefill cache into ``slot`` of the ragged decode cache.

    ``length`` is the TRUE prompt length (excluding right-padding); the
    per-slot index is set to it, so padded-prefill KV beyond the prompt is
    masked out by the ragged decode mask and overwritten by later tokens.
    """

    def ins(path, dst, src):
        if _is_index_leaf(path):
            # dst (..., n_slots) per-slot lengths; src is the scalar-stacked
            # prefill index (includes padding) — use the host-passed length.
            return dst.at[..., slot].set(jnp.asarray(length, dst.dtype))
        # dst (stack, n_slots, ...) <- src (stack, 1, ...)
        return dst.at[:, slot].set(src[:, 0].astype(dst.dtype))

    return jax.tree_util.tree_map_with_path(ins, decode_cache, prefix_cache)


# ---------------------------------------------------------------------------
# paged KV cache
# ---------------------------------------------------------------------------
class BlockAllocator:
    """Host-side free-list allocator over a fixed pool of cache blocks."""

    def __init__(self, n_blocks: int):
        self.n_blocks = n_blocks
        self._free: List[int] = list(range(n_blocks - 1, -1, -1))
        self.tables: Dict[int, List[int]] = {}

    @property
    def n_free(self) -> int:
        return len(self._free)

    def allocate(self, seq_id: int, n: int = 1) -> List[int]:
        if n > len(self._free):
            raise MemoryError(
                f"paged cache exhausted: want {n} blocks, {len(self._free)} free"
            )
        got = [self._free.pop() for _ in range(n)]
        self.tables.setdefault(seq_id, []).extend(got)
        return got

    def free(self, seq_id: int) -> None:
        self._free.extend(reversed(self.tables.pop(seq_id, [])))

    def table(self, seq_id: int) -> List[int]:
        return self.tables.get(seq_id, [])


@dataclasses.dataclass
class PagedKVCache:
    """Block-pooled K/V storage for one attention layer group.

    pool_k/pool_v: (n_blocks, block_size, n_kv_heads, head_dim).
    A sequence of length L owns ceil(L / block_size) blocks; ``block_table``
    (max_blocks_per_seq,) int32 rows map logical block i -> pool block id.
    """

    pool_k: jnp.ndarray
    pool_v: jnp.ndarray
    block_size: int

    @classmethod
    def create(
        cls,
        n_blocks: int,
        block_size: int,
        n_kv_heads: int,
        head_dim: int,
        dtype=jnp.bfloat16,
    ) -> "PagedKVCache":
        shape = (n_blocks, block_size, n_kv_heads, head_dim)
        return cls(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype), block_size)

    # -- device ops ---------------------------------------------------------
    def append(
        self, block_id: jnp.ndarray, offset: jnp.ndarray,
        k: jnp.ndarray, v: jnp.ndarray,
    ) -> "PagedKVCache":
        """Write one token's (n_kv_heads, head_dim) K/V at (block, offset)."""
        pk = self.pool_k.at[block_id, offset].set(k.astype(self.pool_k.dtype))
        pv = self.pool_v.at[block_id, offset].set(v.astype(self.pool_v.dtype))
        return PagedKVCache(pk, pv, self.block_size)

    def append_batch(
        self, block_ids: jnp.ndarray, offsets: jnp.ndarray,
        k: jnp.ndarray, v: jnp.ndarray,
    ) -> "PagedKVCache":
        """Batched one-token append: block_ids/offsets (B,), k/v (B, Hkv, D)."""
        pk = self.pool_k.at[block_ids, offsets].set(k.astype(self.pool_k.dtype))
        pv = self.pool_v.at[block_ids, offsets].set(v.astype(self.pool_v.dtype))
        return PagedKVCache(pk, pv, self.block_size)

    def gather(self, block_table: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Linearize pages: (max_blocks,) table -> (max_blocks*bs, Hkv, D).

        Unused table entries should point at a zero block; the caller masks
        by true length, so stale contents there are never attended to.
        """
        k = self.pool_k[block_table]  # (nb, bs, H, D)
        v = self.pool_v[block_table]
        nb, bs, h, d = k.shape
        return k.reshape(nb * bs, h, d), v.reshape(nb * bs, h, d)

    def gather_batch(
        self, block_tables: jnp.ndarray
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(B, max_blocks) tables -> (B, max_blocks*bs, Hkv, D)."""
        k = self.pool_k[block_tables]  # (B, nb, bs, H, D)
        v = self.pool_v[block_tables]
        b, nb, bs, h, d = k.shape
        return k.reshape(b, nb * bs, h, d), v.reshape(b, nb * bs, h, d)


def paged_decode_attention(
    q: jnp.ndarray,  # (B, 1, Hq, D)
    cache: PagedKVCache,
    block_tables: jnp.ndarray,  # (B, max_blocks) int32
    lengths: jnp.ndarray,  # (B,) int32 true sequence lengths
) -> jnp.ndarray:
    """Decode attention over paged KV: gather pages, mask by true length."""
    from ..kernels import ops as kops

    k, v = cache.gather_batch(block_tables)
    return kops.decode_attention(q, k, v, length=lengths)
