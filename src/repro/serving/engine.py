"""Continuous-batching inference engine (iteration-level scheduling).

JetStream-style slot architecture on top of the model zoo:

  * a fixed decode batch of ``max_slots`` sequence slots shares one ragged
    cache (per-slot ``index`` lengths — see models/transformer.init_cache);
  * a new request is PREFILLED at batch 1 (padded to a power-of-two bucket
    for attention archs so jit shapes are reused; exact length for recurrent
    archs, whose state would otherwise be advanced through padding), then
    INSERTED into a free slot via kvcache.insert_prefix;
  * one ``step()`` = admit waiting requests into free slots + one ragged
    decode step advancing every active slot by one token;
  * finished sequences (EOS / max_new_tokens) release their slot — the next
    admission overwrites it, no cache zeroing needed.

This is the workload the paper places: one Engine == one model replica in a
MIG/pod partition.  serving/cluster.py binds engines to placements.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model_zoo import ModelBundle
from .kvcache import insert_prefix

__all__ = ["Request", "Completion", "Engine", "EngineConfig"]


@dataclasses.dataclass
class Request:
    rid: str
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    #: extra prefill inputs (e.g. patch_embeds for VLM, frames for enc-dec)
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Completion:
    rid: str
    prompt: List[int]
    tokens: List[int]
    prefill_len: int
    finish_reason: str  # "eos" | "length"


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 4
    max_len: int = 256
    bucket_prefill: bool = True  # pad prompts to pow2 (attention archs only)


@dataclasses.dataclass
class _SlotState:
    req: Request
    generated: List[int]
    length: int  # true tokens in cache (prompt + generated)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class Engine:
    """One model replica serving requests with continuous batching."""

    def __init__(self, bundle: ModelBundle, params, cfg: EngineConfig = EngineConfig()):
        self.bundle = bundle
        self.model = bundle.model
        self.params = params
        self.cfg = cfg
        mcfg = bundle.cfg
        self._recurrent = mcfg.is_recurrent
        enc_len = mcfg.frontend_len if mcfg.enc_dec else 0
        self.cache = jax.jit(
            lambda: self.model.init_cache(
                cfg.max_slots, cfg.max_len, enc_len, ragged=True
            )
        )()
        self.queue: Deque[Request] = collections.deque()
        self.slots: List[Optional[_SlotState]] = [None] * cfg.max_slots
        self.completed: List[Completion] = []
        self.stats = {"prefills": 0, "decode_steps": 0, "tokens": 0}

        # --- jitted steps ----------------------------------------------------
        @jax.jit
        def _prefill(params, batch):
            logits, cache = bundle.prefill_fn(params, batch, max_len=cfg.max_len)
            return logits, cache

        @functools.partial(jax.jit, donate_argnums=(1,))
        def _decode(params, cache, tokens, lengths):
            logits, cache, _ = self.model.forward(
                params, {"tokens": tokens}, cache=cache, positions=lengths[:, None]
            )
            return jnp.argmax(logits[:, -1, :], axis=-1), cache

        self._prefill = _prefill
        self._decode = _decode

    # ------------------------------------------------------------------ API
    def submit(self, req: Request) -> None:
        if len(req.prompt) + req.max_new_tokens > self.cfg.max_len:
            raise ValueError(
                f"{req.rid}: prompt+max_new={len(req.prompt)}+{req.max_new_tokens} "
                f"exceeds max_len={self.cfg.max_len}"
            )
        self.queue.append(req)

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or self.n_active > 0

    def step(self) -> int:
        """Admit waiting requests, then advance all active slots one token.

        Returns the number of tokens produced this step (incl. the first
        token each admitted request gets from its prefill logits)."""
        produced = self._admit()
        return produced + self._decode_step()

    def run(self, max_steps: int = 100_000) -> List[Completion]:
        for _ in range(max_steps):
            if not self.has_work:
                break
            self.step()
        return self.completed

    # ------------------------------------------------------------- internals
    def _admit(self) -> int:
        produced = 0
        for slot_id, st in enumerate(self.slots):
            if st is not None or not self.queue:
                continue
            req = self.queue.popleft()
            first_tok = self._prefill_into(slot_id, req)
            self.slots[slot_id] = _SlotState(
                req=req, generated=[first_tok], length=len(req.prompt) + 1
            )
            self.stats["prefills"] += 1
            self.stats["tokens"] += 1
            produced += 1
            self._retire_if_done(slot_id)
        return produced

    def _prefill_into(self, slot_id: int, req: Request) -> int:
        plen = len(req.prompt)
        pad = (
            _next_pow2(plen)
            if (self.cfg.bucket_prefill and not self._recurrent)
            else plen
        )
        toks = np.zeros((1, pad), np.int32)
        toks[0, :plen] = req.prompt
        batch = {"tokens": jnp.asarray(toks), **req.extras}
        logits, prefix = self._prefill(self.params, batch)
        # first generated token: logits at the LAST TRUE prompt position
        first = int(jnp.argmax(logits[0, plen - 1, :]))
        self.cache = insert_prefix(
            self.cache, prefix, jnp.int32(slot_id), jnp.int32(plen)
        )
        # account for the first token: it is appended by the next decode
        # step's write (its KV is not in the cache yet; decode writes it).
        return first

    def _decode_step(self) -> int:
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        tokens = np.zeros((self.cfg.max_slots, 1), np.int32)
        lengths = np.zeros((self.cfg.max_slots,), np.int32)
        for i, st in enumerate(self.slots):
            if st is not None:
                tokens[i, 0] = st.generated[-1]
                lengths[i] = st.length - 1  # position OF the fed token
        # inactive slots: keep device/host index agreement by feeding their
        # device-side index (the model bumps every slot's index by 1).
        dev_idx = np.asarray(self._slot_indexes())
        for i in range(self.cfg.max_slots):
            if self.slots[i] is None:
                lengths[i] = dev_idx[i]
        nxt, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(lengths)
        )
        nxt = np.asarray(nxt)
        produced = 0
        self.stats["decode_steps"] += 1
        for i in active:
            st = self.slots[i]
            st.generated.append(int(nxt[i]))
            st.length += 1
            produced += 1
            self.stats["tokens"] += 1
            self._retire_if_done(i)
        return produced

    def _slot_indexes(self) -> np.ndarray:
        """Device-side per-slot cache index (from the first attn leaf)."""
        leaf = None

        def find(path, x):
            nonlocal leaf
            last = path[-1]
            if getattr(last, "key", None) == "index" and leaf is None:
                leaf = x
            return x

        jax.tree_util.tree_map_with_path(find, self.cache)
        if leaf is None:  # pure-recurrent arch: no index leaves
            return np.zeros((self.cfg.max_slots,), np.int32)
        arr = np.asarray(leaf)
        return arr[0] if arr.ndim == 2 else np.broadcast_to(arr, (self.cfg.max_slots,))

    def _retire_if_done(self, slot_id: int) -> None:
        st = self.slots[slot_id]
        req = st.req
        done_eos = req.eos_id is not None and st.generated[-1] == req.eos_id
        done_len = len(st.generated) >= req.max_new_tokens
        if done_eos or done_len:
            self.completed.append(
                Completion(
                    rid=req.rid,
                    prompt=list(req.prompt),
                    tokens=list(st.generated),
                    prefill_len=len(req.prompt),
                    finish_reason="eos" if done_eos else "length",
                )
            )
            self.slots[slot_id] = None
