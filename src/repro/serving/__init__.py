"""Serving: continuous-batching engine + placement-integrated cluster.

    kvcache — ragged decode-state insertion + paged KV cache substrate
    engine  — JetStream-style slot engine (prefill / insert / ragged decode)
    cluster — ClusterServer: the paper's placement engine as the scheduler
"""
from .cluster import NoReplicaError, PlanExecutionError, StepPolicy  # noqa: F401
from .engine import Completion, Engine, EngineConfig, Request  # noqa: F401
from .kvcache import BlockAllocator, PagedKVCache, insert_prefix  # noqa: F401

__all__ = [
    "Completion",
    "Engine",
    "EngineConfig",
    "Request",
    "BlockAllocator",
    "PagedKVCache",
    "insert_prefix",
    "NoReplicaError",
    "PlanExecutionError",
    "StepPolicy",
]
