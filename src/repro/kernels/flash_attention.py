"""Pallas TPU flash attention (forward), GQA-aware, causal + sliding window.

TPU adaptation notes (vs. the CUDA flash-attention algorithm):
  * tiling is chosen for VMEM and the 128x128 MXU: block_q x d and
    block_k x d tiles stream HBM->VMEM while the online-softmax accumulators
    (acc, m, l) live in VMEM scratch across the k-block grid dimension;
  * the k-block loop is the innermost grid dimension with "arbitrary"
    semantics (sequential), q/head/batch dims are parallel;
  * GQA is handled in the BlockSpec index map: query head h reads kv head
    h // group — no materialized key/value replication.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, scale: float, block_q: int, block_k: int, n_k: int,
    causal: bool, window: Optional[int],
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = kj * block_k

    # skip blocks that are fully masked out (above the causal diagonal /
    # outside the sliding window)
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window is not None:
        run = jnp.logical_and(run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]  # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)  # (bq,1)
        l_ref[:, :1] = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        m_ref[:, :1] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(kj == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sliding_window", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jnp.ndarray,  # (B, S, Hq, D)
    k: jnp.ndarray,  # (B, S, Hkv, D)
    v: jnp.ndarray,  # (B, S, Hkv, Dv)
    causal: bool = True,
    sliding_window: Optional[int] = None,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    b, s, hq, d = q.shape
    hkv, dv = k.shape[2], v.shape[-1]
    g = hq // hkv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    n_q, n_k = s // block_q, s // block_k

    # layout: (B, H, S, D) blocks
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)

    kernel = functools.partial(
        _fa_kernel,
        scale=1.0 / (d ** 0.5),
        block_q=block_q,
        block_k=block_k,
        n_k=n_k,
        causal=causal,
        window=sliding_window,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bb, h, i, j: (bb, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bb, h, i, j: (bb, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, dv), lambda bb, h, i, j: (bb, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dv), lambda bb, h, i, j: (bb, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, s, dv), q.dtype),
        scratch_shapes=[
            pl.MemorySpace.ANY if False else _vmem((block_q, dv)),
            _vmem((block_q, 128)),
            _vmem((block_q, 128)),
        ],
        compiler_params=_tpu_params(("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)  # back to (B, S, Hq, Dv)


def _vmem(shape):
    import jax.experimental.pallas.tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)


def _tpu_params(semantics):
    try:
        import jax.experimental.pallas.tpu as pltpu

        return pltpu.CompilerParams(dimension_semantics=semantics)
    except Exception:
        return None
