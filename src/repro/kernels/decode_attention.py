"""Pallas TPU flash-decoding: one query token against a long KV cache.

The serving hot spot of the paper's workloads.  TPU adaptation:
  * the KV sequence is tiled into block_k x d VMEM tiles; the (tiny) query
    tile stays resident; online-softmax accumulators live in VMEM scratch
    across the sequential k grid dimension;
  * all q-heads of one KV group are PACKED into a single (G, d) MXU operand
    so the matmul sees a >=8x128 tile instead of a vector — the
    GQA-packing trick that keeps the MXU busy at decode time;
  * the valid-length mask is a scalar broadcast against the block iota.
"""
from __future__ import annotations

import functools
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _dec_kernel(
    len_ref,  # (B*Hkv, 1) int32 in SMEM — per-row valid length (ragged batch)
    q_ref,  # (1, 1, G, D)
    k_ref,  # (1, block_k, D)
    v_ref,  # (1, block_k, Dv)
    o_ref,  # (1, 1, G, Dv)
    acc_ref, m_ref, l_ref,
    *, scale: float, block_k: int, n_k: int,
):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[pl.program_id(0), 0]
    k_start = kj * block_k

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
        k = k_ref[0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0].astype(jnp.float32)  # (bk, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (G, bk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)

        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(kpos < length, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[:, :1] = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        m_ref[:, :1] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(kj == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention_pallas(
    q: jnp.ndarray,  # (B, 1, Hq, D)
    k: jnp.ndarray,  # (B, Smax, Hkv, D)
    v: jnp.ndarray,  # (B, Smax, Hkv, Dv)
    length,  # int32: valid cache slots — scalar (uniform) or (B,) (ragged)
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, sq, hq, d = q.shape
    assert sq == 1, "decode kernel takes a single query token"
    smax, hkv, dv = k.shape[1], k.shape[2], v.shape[-1]
    g = hq // hkv
    block_k = min(block_k, smax)
    assert smax % block_k == 0
    n_k = smax // block_k

    qt = q.reshape(b, hkv, g, d)  # pack group heads
    kt = jnp.moveaxis(k, 2, 1).reshape(b * hkv, smax, d)
    vt = jnp.moveaxis(v, 2, 1).reshape(b * hkv, smax, dv)
    qt = qt.reshape(b * hkv, 1, g, d)
    # per-(batch x kv-head) valid length in SMEM; a scalar length broadcasts,
    # a (B,) vector gives each continuous-batching slot its own mask.
    lb = jnp.broadcast_to(jnp.minimum(jnp.asarray(length, jnp.int32), smax), (b,))
    lsc = jnp.repeat(lb, hkv)[:, None]

    kernel = functools.partial(
        _dec_kernel, scale=1.0 / (d ** 0.5), block_k=block_k, n_k=n_k
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * hkv, 1, n_k),
        in_specs=[
            _smem_spec(),
            pl.BlockSpec((1, 1, g, d), lambda bh, z, j: (bh, 0, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, z, j: (bh, j, 0)),
            pl.BlockSpec((1, block_k, dv), lambda bh, z, j: (bh, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv), lambda bh, z, j: (bh, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, 1, g, dv), q.dtype),
        scratch_shapes=[_vmem((g, dv)), _vmem((g, 128)), _vmem((g, 128))],
        compiler_params=_tpu_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lsc, qt.reshape(b * hkv, 1, g, d), kt, vt)
    return out.reshape(b, hkv, g, dv).reshape(b, 1, hq, dv)


# ---------------------------------------------------------------------------
# int8-KV variant: dequantize per VMEM tile — HBM KV reads halve
# ---------------------------------------------------------------------------
def _dec_q8_kernel(
    len_ref,  # (B*Hkv, 1) int32 in SMEM
    q_ref,  # (1, 1, G, D)
    kq_ref,  # (1, block_k, D) int8
    ks_ref,  # (1, block_k) f32
    vq_ref,  # (1, block_k, Dv) int8
    vs_ref,  # (1, block_k) f32
    o_ref,  # (1, 1, G, Dv)
    acc_ref, m_ref, l_ref,
    *, scale: float, block_k: int, n_k: int,
):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[pl.program_id(0), 0]
    k_start = kj * block_k

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
        # dequantize the tile in VMEM
        k = kq_ref[0].astype(jnp.float32) * ks_ref[0][:, None]  # (bk, D)
        v = vq_ref[0].astype(jnp.float32) * vs_ref[0][:, None]  # (bk, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(kpos < length, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[:, :1] = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        m_ref[:, :1] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(kj == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention_q8_pallas(
    q: jnp.ndarray,  # (B, 1, Hq, D)
    k_q: jnp.ndarray,  # (B, Smax, Hkv, D) int8
    k_s: jnp.ndarray,  # (B, Smax, Hkv) f32
    v_q: jnp.ndarray,
    v_s: jnp.ndarray,
    length,  # scalar or (B,) int32
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, sq, hq, d = q.shape
    assert sq == 1
    smax, hkv, dv = k_q.shape[1], k_q.shape[2], v_q.shape[-1]
    g = hq // hkv
    block_k = min(block_k, smax)
    assert smax % block_k == 0
    n_k = smax // block_k

    qt = q.reshape(b, hkv, g, d).reshape(b * hkv, 1, g, d)
    kt = jnp.moveaxis(k_q, 2, 1).reshape(b * hkv, smax, d)
    vt = jnp.moveaxis(v_q, 2, 1).reshape(b * hkv, smax, dv)
    kst = jnp.moveaxis(k_s, 2, 1).reshape(b * hkv, smax)
    vst = jnp.moveaxis(v_s, 2, 1).reshape(b * hkv, smax)
    lb = jnp.broadcast_to(jnp.minimum(jnp.asarray(length, jnp.int32), smax), (b,))
    lsc = jnp.repeat(lb, hkv)[:, None]

    kernel = functools.partial(
        _dec_q8_kernel, scale=1.0 / (d ** 0.5), block_k=block_k, n_k=n_k
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * hkv, 1, n_k),
        in_specs=[
            _smem_spec(),
            pl.BlockSpec((1, 1, g, d), lambda bh, z, j: (bh, 0, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, z, j: (bh, j, 0)),
            pl.BlockSpec((1, block_k), lambda bh, z, j: (bh, j)),
            pl.BlockSpec((1, block_k, dv), lambda bh, z, j: (bh, j, 0)),
            pl.BlockSpec((1, block_k), lambda bh, z, j: (bh, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv), lambda bh, z, j: (bh, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, 1, g, dv), q.dtype),
        scratch_shapes=[_vmem((g, dv)), _vmem((g, 128)), _vmem((g, 128))],
        compiler_params=_tpu_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lsc, qt, kt, kst, vt, vst)
    return out.reshape(b, hkv, g, dv).reshape(b, 1, hq, dv)


def _vmem(shape):
    import jax.experimental.pallas.tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)


def _smem_spec():
    try:
        import jax.experimental.pallas.tpu as pltpu

        return pl.BlockSpec(memory_space=pltpu.SMEM)
    except Exception:
        return pl.BlockSpec(memory_space=pl.ANY)


def _tpu_params(semantics):
    try:
        import jax.experimental.pallas.tpu as pltpu

        return pltpu.CompilerParams(dimension_semantics=semantics)
    except Exception:
        return None
