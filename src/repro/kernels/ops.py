"""Kernel dispatch: Pallas on TPU, memory-efficient jnp elsewhere.

One call site for the model code.  ``set_impl`` switches globally:
  * "pallas"  — pl.pallas_call kernels (TPU; or interpret=True in tests)
  * "jnp"     — query-chunked online-softmax jnp (identical math; used for
                the CPU dry-run so the lowered HLO carries real FLOPs)
  * "ref"     — naive oracle (tiny smoke tests)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import ref as _ref

_IMPL = {"mode": "jnp", "interpret": False}


def set_impl(mode: str, interpret: bool = False) -> None:
    assert mode in ("pallas", "jnp", "ref")
    _IMPL["mode"] = mode
    _IMPL["interpret"] = interpret


def get_impl() -> str:
    return _IMPL["mode"]


# ---------------------------------------------------------------------------
# flash attention (train / prefill)
# ---------------------------------------------------------------------------
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    q_chunk: int = 512,
) -> jnp.ndarray:
    if _IMPL["mode"] == "pallas":
        from .flash_attention import flash_attention_pallas

        return flash_attention_pallas(
            q, k, v, causal=causal, sliding_window=sliding_window,
            interpret=_IMPL["interpret"],
        )
    # named_scope marks the region in HLO metadata: on TPU this runs as the
    # Pallas kernel whose score/prob tiles stay in VMEM, so the roofline
    # analyzer (distribution/hlo_analysis) books interior bytes separately.
    with jax.named_scope("pallas_flash_attention"):
        if _IMPL["mode"] == "ref" or q.shape[1] <= q_chunk:
            return _ref.attention_ref(q, k, v, causal, sliding_window)
        return _chunked_attention(q, k, v, causal, sliding_window, q_chunk)


def _chunked_attention(q, k, v, causal, window, q_chunk):
    """Query-chunked attention: peak memory O(chunk x S) not O(S^2)."""
    b, s, hq, d = q.shape
    if s % q_chunk:
        return _ref.attention_ref(q, k, v, causal, window)
    sk = k.shape[1]  # may differ from s (cross-attention)
    hkv = k.shape[2]
    g = hq // hkv
    n_chunks = s // q_chunk
    qc = q.reshape(b, n_chunks, q_chunk, hkv, g, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    kpos = jnp.arange(sk)

    def one_chunk(ci):
        qi = qc[:, ci].astype(jnp.float32)  # (B,C,Hkv,G,D)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qi, kf) * scale
        qpos = ci * q_chunk + jnp.arange(q_chunk) + (sk - s)  # align ends
        mask = jnp.ones((q_chunk, sk), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        p = jnp.exp(scores - scores.max(-1, keepdims=True))
        p = p / p.sum(-1, keepdims=True)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
        return out.reshape(b, q_chunk, hq, vf.shape[-1]).astype(q.dtype)

    # checkpoint per q-chunk: the backward pass RECOMPUTES scores/probs
    # chunk-by-chunk instead of saving the stacked (n_chunks x C x S) prob
    # tensor as a residual — the flash-attention backward structure, so the
    # lowered HLO's HBM buffers match what the Pallas kernel materializes.
    out = jax.lax.map(
        jax.checkpoint(one_chunk, prevent_cse=False), jnp.arange(n_chunks)
    )  # (n,B,C,Hq,Dv)
    return jnp.moveaxis(out, 0, 1).reshape(b, s, hq, v.shape[-1])


# ---------------------------------------------------------------------------
# decode attention (one new token vs a long KV cache)
# ---------------------------------------------------------------------------
def decode_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    length,
    sliding_window: Optional[int] = None,
) -> jnp.ndarray:
    if _IMPL["mode"] == "pallas":
        from .decode_attention import decode_attention_pallas

        return decode_attention_pallas(
            q, k, v, length=length, interpret=_IMPL["interpret"]
        )
    with jax.named_scope("pallas_decode_attention"):
        return _ref.decode_attention_ref(q, k, v, length, sliding_window)


def decode_attention_q8(
    q: jnp.ndarray,
    k_q: jnp.ndarray,
    k_s: jnp.ndarray,
    v_q: jnp.ndarray,
    v_s: jnp.ndarray,
    length,
) -> jnp.ndarray:
    """int8-KV flash decoding: HBM KV reads halve; dequant happens per VMEM
    tile inside the kernel (beyond-paper serving lever, EXPERIMENTS.md §Perf
    Cell C)."""
    if _IMPL["mode"] == "pallas":
        from .decode_attention import decode_attention_q8_pallas

        return decode_attention_q8_pallas(
            q, k_q, k_s, v_q, v_s, length=length, interpret=_IMPL["interpret"]
        )
    with jax.named_scope("pallas_decode_attention_q8"):
        return _ref.decode_attention_q8_ref(q, k_q, k_s, v_q, v_s, length)


def cross_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    return flash_attention(q, k, v, causal=False, sliding_window=None)


# ---------------------------------------------------------------------------
# Mamba-2 SSD scan
# ---------------------------------------------------------------------------
def ssd_scan(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    B: jnp.ndarray,
    C: jnp.ndarray,
    chunk: int = 256,
    initial_state=None,
):
    if _IMPL["mode"] == "pallas":
        from .ssd_scan import ssd_scan_pallas

        return ssd_scan_pallas(
            x, dt, A, B, C, chunk=chunk, initial_state=initial_state,
            interpret=_IMPL["interpret"],
        )
    with jax.named_scope("pallas_ssd_scan"):
        if _IMPL["mode"] == "ref" or x.shape[1] <= chunk:
            return _ref.ssd_scan_ref(x, dt, A, B, C, initial_state)
        return _chunked_ssd(x, dt, A, B, C, chunk, initial_state)


def _chunked_ssd(x, dt, A, B, C, chunk, initial_state):
    """Chunkwise SSD (Mamba-2 Sec 6): intra-chunk dense matmuls (MXU work)
    + inter-chunk state recurrence via lax.scan.  Identical math to the
    sequential oracle."""
    bt, s, h, p = x.shape
    if s % chunk:
        return _ref.ssd_scan_ref(x, dt, A, B, C, initial_state)
    n = B.shape[-1]
    nc = s // chunk
    xf = x.astype(jnp.float32).reshape(bt, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(bt, nc, chunk, h)
    Bf = B.astype(jnp.float32).reshape(bt, nc, chunk, n)
    Cf = C.astype(jnp.float32).reshape(bt, nc, chunk, n)
    Af = A.astype(jnp.float32)

    # per-step log decay a_t = A*dt_t ; cumulative within chunk
    la = Af[None, None, None, :] * dtf  # (bt,nc,L,h)
    cum = jnp.cumsum(la, axis=2)  # inclusive cumsum_{t'<=t}

    # intra-chunk: y_intra[t] = sum_{u<=t} C_t . B_u dt_u x_u * exp(cum_t - cum_u)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (bt,nc,T,U,h)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bctn,bcun->bctu", Cf, Bf)  # (bt,nc,T,U)
    w = cb[..., None] * decay * dtf[:, :, None, :, :]  # (bt,nc,T,U,h)
    y_intra = jnp.einsum("bctuh,bcuhp->bcthp", w, xf)

    # chunk state contribution: S_c = sum_u exp(cum_L - cum_u) dt_u x_u B_u^T
    tail = jnp.exp(cum[:, :, -1:, :] - cum) * dtf  # (bt,nc,L,h)
    S_c = jnp.einsum("bcuh,bcuhp,bcun->bchpn", tail, xf, Bf)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (bt,nc,h)

    h0 = (
        jnp.zeros((bt, h, p, n), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def scan_fn(hprev, c):
        hnew = hprev * chunk_decay[:, c][:, :, None, None] + S_c[:, c]
        return hnew, hprev

    hT, hprevs = jax.lax.scan(scan_fn, h0, jnp.arange(nc))
    hprevs = jnp.moveaxis(hprevs, 0, 1)  # (bt,nc,h,p,n) state entering chunk

    # inter-chunk: y_inter[t] = C_t . (exp(cum_t) * h_prev)
    y_inter = jnp.einsum(
        "bcth,bchpn,bctn->bcthp", jnp.exp(cum), hprevs, Cf
    )
    y = (y_intra + y_inter).reshape(bt, s, h, p)
    return y.astype(x.dtype), hT
