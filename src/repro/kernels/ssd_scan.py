"""Pallas TPU chunked Mamba-2 SSD scan.

TPU adaptation of the GPU SSD algorithm: instead of warp-level parallel
scans, the sequence is tiled into L-step chunks; within a chunk everything
is dense (chunk x chunk and chunk x state matmuls on the MXU), and the
inter-chunk recurrence is the innermost sequential grid dimension carrying
the (P x N) state in VMEM scratch.  Grid: (batch, heads, chunks).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(
    a_ref,  # (1,) f32 in SMEM: A for this head
    x_ref,  # (1, chunk, 1, P)
    dt_ref,  # (1, chunk, 1)
    b_ref,  # (1, chunk, N)
    c_ref,  # (1, chunk, N)
    h0_ref,  # (1, 1, P, N) initial state
    y_ref,  # (1, chunk, 1, P)
    hT_ref,  # (1, 1, P, N) final state
    state_ref,  # VMEM scratch (P, N)
    *, chunk: int, n_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    A = a_ref[0]
    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (L,)
    Bm = b_ref[0].astype(jnp.float32)  # (L, N)
    Cm = c_ref[0].astype(jnp.float32)  # (L, N)

    la = A * dt  # (L,)
    cum = jnp.cumsum(la)  # inclusive
    # intra-chunk: w[t,u] = (C_t.B_u) * exp(cum_t - cum_u) * dt_u,  u <= t
    seg = cum[:, None] - cum[None, :]
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
        <= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    )
    decay = jnp.where(tri, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (T,U)
    w = cb * decay * dt[None, :]
    y_intra = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (T,P)

    # inter-chunk: y_inter[t] = exp(cum_t) * C_t @ state^T
    h_prev = state_ref[...]  # (P,N)
    y_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, h_prev, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (T,P)
    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h = h*exp(cum_L) + sum_u exp(cum_L - cum_u) dt_u x_u B_u^T
    tail = jnp.exp(cum[-1] - cum) * dt  # (L,)
    xw = x * tail[:, None]  # (L,P)
    upd = jax.lax.dot_general(
        xw, Bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (P,N)
    state_ref[...] = h_prev * jnp.exp(cum[-1]) + upd

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        hT_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H) f32
    A: jnp.ndarray,  # (H,) f32 (negative)
    B: jnp.ndarray,  # (B, S, N)
    C: jnp.ndarray,  # (B, S, N)
    chunk: int = 256,
    initial_state: Optional[jnp.ndarray] = None,  # (B, H, P, N) f32
    interpret: bool = False,
):
    bt, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    if initial_state is None:
        initial_state = jnp.zeros((bt, h, p, n), jnp.float32)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    y, hT = pl.pallas_call(
        kernel,
        grid=(bt, h, nc),
        in_specs=[
            _smem_vec_spec(),
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b_, h_, c_: (b_, c_, h_)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c_: (b_, c_, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c_: (b_, c_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((bt, h, p, n), jnp.float32),
        ],
        scratch_shapes=[_vmem((p, n))],
        compiler_params=_tpu_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(_per_head(A, h), x, dt, B, C, initial_state)
    return y, hT


def _per_head(A, h):
    return A.astype(jnp.float32).reshape(h)


def _vmem(shape):
    import jax.experimental.pallas.tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)


def _smem_vec_spec():
    try:
        import jax.experimental.pallas.tpu as pltpu

        return pl.BlockSpec((1,), lambda b_, h_, c_: (h_,), memory_space=pltpu.SMEM)
    except Exception:
        return pl.BlockSpec((1,), lambda b_, h_, c_: (h_,))


def _tpu_params(semantics):
    try:
        import jax.experimental.pallas.tpu as pltpu

        return pltpu.CompilerParams(dimension_semantics=semantics)
    except Exception:
        return None
