"""Pure-jnp oracles for every kernel.  Naive, obviously-correct math used by
the per-kernel allclose sweeps and as the CPU execution path."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

__all__ = ["attention_ref", "decode_attention_ref", "ssd_scan_ref"]

_NEG = -1e30


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    sliding_window: Optional[int] = None,
) -> jnp.ndarray:
    """q (B,Sq,Hq,D), k/v (B,Sk,Hkv,D) -> (B,Sq,Hq,D); GQA by head grouping.

    Materializes the full score matrix — the oracle, not the fast path.
    """
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf) / jnp.sqrt(d).astype(jnp.float32)
    qpos = jnp.arange(sq)[:, None] + (sk - sq)  # align ends (prefill/causal)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if sliding_window is not None:
        mask &= kpos > qpos - sliding_window
    scores = jnp.where(mask[None, None, None], scores, _NEG)
    p = jnp.exp(scores - scores.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, dv).astype(q.dtype)


def decode_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    length,
    sliding_window: Optional[int] = None,
) -> jnp.ndarray:
    """One-token decode: q (B,1,Hq,D) vs ring-buffer cache k/v (B,Smax,Hkv,D).

    Valid cache slots are arange(Smax) < length (ring buffers pass
    length >= Smax once wrapped, making every slot valid — attention is
    order-invariant so slot order does not matter).  ``length`` may be a
    scalar (uniform batch) or a (B,) vector (ragged continuous batching).
    """
    b, sq, hq, d = q.shape
    smax, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    # f32 ACCUMULATION over bf16 operands (preferred_element_type), never a
    # wholesale astype(f32) of k/v — that materializes an f32 shadow of the
    # entire KV cache, which XLA then carries through the decode loop.
    scores = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32
    )
    scores = scores / jnp.sqrt(d).astype(jnp.float32)
    lim = jnp.broadcast_to(jnp.minimum(jnp.asarray(length), smax), (b,))
    valid = jnp.arange(smax)[None, :] < lim[:, None]  # (B, Smax)
    scores = jnp.where(valid[:, None, None, None, :], scores, _NEG)
    p = jnp.exp(scores - scores.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = jnp.einsum(
        "bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, sq, hq, dv).astype(q.dtype)


def quantize_kv(k: jnp.ndarray, axis: int = -1):
    """Per-(token, head) symmetric int8 quantization of a KV tensor.

    k (B,S,Hkv,D) -> (q int8 (B,S,Hkv,D), scale f32 (B,S,Hkv))."""
    amax = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=axis)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(
        jnp.round(k.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale


def decode_attention_q8_ref(
    q: jnp.ndarray,  # (B,1,Hq,D)
    k_q: jnp.ndarray,  # (B,Smax,Hkv,D) int8
    k_s: jnp.ndarray,  # (B,Smax,Hkv) f32
    v_q: jnp.ndarray,
    v_s: jnp.ndarray,
    length,
) -> jnp.ndarray:
    """int8-KV decode oracle: dequantize then run the fp oracle.  The Pallas
    kernel dequantizes per VMEM tile instead — HBM reads HALVE."""
    k = k_q.astype(jnp.float32) * k_s[..., None]
    v = v_q.astype(jnp.float32) * v_s[..., None]
    return decode_attention_ref(q, k, v, length)


def ssd_scan_ref(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    B: jnp.ndarray,
    C: jnp.ndarray,
    initial_state: Optional[jnp.ndarray] = None,
):
    """Mamba-2 SSD, naive sequential recurrence (the oracle).

    x (Bt,S,H,P)  dt (Bt,S,H)  A (H,) negative  B,C (Bt,S,N)
    state h (Bt,H,P,N):  h_t = exp(A*dt_t) h_{t-1} + dt_t * x_t B_t^T
                         y_t = h_t C_t
    Returns y (Bt,S,H,P), final state.
    """
    bt, s, h, p = x.shape
    n = B.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    h0 = (
        jnp.zeros((bt, h, p, n), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(hprev, t):
        decay = jnp.exp(Af[None, :] * dtf[:, t])  # (Bt,H)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dtf[:, t], xf[:, t], Bf[:, t])
        hnew = hprev * decay[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", hnew, Cf[:, t])
        return hnew, y

    import jax

    hT, ys = jax.lax.scan(step, h0, jnp.arange(s))
    y = jnp.moveaxis(ys, 0, 1)  # (Bt,S,H,P)
    return y.astype(x.dtype), hT
