"""Pallas TPU kernels for the perf-critical compute of the served workloads.

kernels:
  flash_attention  — train/prefill attention (GQA, causal, sliding window)
  decode_attention — flash-decoding, one token vs long KV (GQA head packing)
  ssd_scan         — chunked Mamba-2 SSD (MXU matmul formulation)

Each has a pure-jnp oracle in ``ref.py``; ``ops.py`` is the jit'd dispatch
layer the models call (pallas on TPU / interpret in tests, jnp elsewhere).
"""
from . import ops  # noqa: F401
