"""DeepSeek-V3 (671B MoE: MLA, 1 shared + 256 routed top-8, MTP).  [arXiv:2412.19437]

d_ff=2048 is the routed-expert hidden dim; the 3 leading dense layers use
the model's dense FFN width 18432.  MLA dims per the paper: q_lora 1536,
kv_lora 512, qk_nope 128, qk_rope 64, v_head 128.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,  # dense-layer FFN width
    moe_d_ff=2048,  # routed/shared expert hidden dim
    vocab_size=129280,
    attention="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    mlp="swiglu",
    norm="rmsnorm",
    n_experts=256,
    experts_per_token=8,
    n_shared_experts=1,
    n_dense_layers=3,
    mtp_depth=1,
)
