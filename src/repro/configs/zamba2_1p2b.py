"""Zamba2-1.2B (Mamba2 backbone + shared attention block).  [arXiv:2411.15242]

38 Mamba2 layers; ONE weight-shared attention+MLP block is applied every 6
Mamba2 layers (simplified from Zamba2's concat-and-project re-entry; noted
in DESIGN.md).  ssm_state=64 per the assignment.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    mlp="gelu",
    norm="rmsnorm",
    block_pattern=("mamba2",),
    ssm_state=64,
    ssm_heads=32,
    ssm_expand=2,
    ssm_chunk=256,
    shared_attn_every=6,
)
