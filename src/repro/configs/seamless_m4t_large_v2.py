"""SeamlessM4T-large-v2 text backbone (encoder-decoder).  [arXiv:2308.11596]

The speech/audio frontend is a STUB: ``input_specs()`` provides precomputed
frame embeddings (B, n_frames, d_model) consumed by the encoder; the decoder
is a standard transformer with cross-attention.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,  # decoder layers
    n_encoder_layers=24,
    enc_dec=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    head_dim=64,
    mlp="gelu",
    norm="layernorm",
    rope_mode="none",  # learned/sinusoidal positions; stub uses none
    frontend="audio",
    frontend_dim=1024,
    frontend_len=1024,  # precomputed speech frames per sample
)
