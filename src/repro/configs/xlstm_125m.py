"""xLSTM-125M (sLSTM + mLSTM blocks, d_ff=0: projection-factor FFNs inside
the blocks).  [arXiv:2405.04517]

Block ratio approximates the paper's mLSTM-heavy mixes: every 4th block is
an sLSTM, the rest are mLSTM.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=192,
    norm="layernorm",
    rope_mode="none",
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    ssm_chunk=256,
)
