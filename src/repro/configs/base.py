"""Architecture configuration schema + the assigned input-shape registry."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "reduced"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One model architecture, fully specifying the JAX model to build."""

    name: str
    family: str  # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default: d_model // n_heads

    # --- attention ---------------------------------------------------------
    attention: str = "gqa"  # gqa | mla
    sliding_window: Optional[int] = None
    rope_mode: str = "full"  # full | half (chatglm 2d-RoPE style) | none
    rope_theta: float = 1e4

    # --- MLA (deepseek) ----------------------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MLP / MoE ---------------------------------------------------------
    mlp: str = "swiglu"  # swiglu | relu2 | gelu
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    n_dense_layers: int = 0  # leading dense layers in an MoE stack (deepseek)
    moe_d_ff: int = 0  # expert hidden dim when != d_ff
    capacity_factor: float = 1.25

    # --- structure ---------------------------------------------------------
    block_pattern: Tuple[str, ...] = ("attn",)  # cycled over layers
    enc_dec: bool = False
    n_encoder_layers: int = 0
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = False
    mtp_depth: int = 0  # deepseek multi-token-prediction extra blocks

    # --- SSM / xLSTM -------------------------------------------------------
    ssm_state: int = 0  # mamba2 N
    ssm_heads: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 256
    shared_attn_every: int = 0  # zamba2: shared attn block period

    # --- modality frontend stubs -------------------------------------------
    frontend: Optional[str] = None  # vit | audio
    frontend_dim: int = 0  # raw patch/frame embedding dim
    frontend_len: int = 0  # patches/frames per sample

    # --- numerics ----------------------------------------------------------
    dtype: str = "bfloat16"
    sublayer_sharding: bool = True  # emit with_sharding_constraint hints

    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_recurrent(self) -> bool:
        return any(b in ("mlstm", "slstm", "mamba2") for b in self.block_pattern)

    @property
    def supports_long_decode(self) -> bool:
        """Sub-quadratic long-context decode (bounded or O(1) state)."""
        return self.is_recurrent or self.sliding_window is not None

    def block_at(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    def layer_groups(self) -> Tuple[Tuple[str, int], ...]:
        """Contiguous (block type, count) runs — each run is one lax.scan."""
        runs = []
        for i in range(self.n_layers):
            b = self.block_at(i)
            if i >= self.n_dense_layers and b == "attn" and self.n_experts:
                b = "moe"
            if runs and runs[-1][0] == b:
                runs[-1][1] += 1
            else:
                runs.append([b, 1])
        return tuple((b, n) for b, n in runs)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode
    microbatch: int = 0  # grad-accum microbatch (train); 0 = no accumulation


#: The assigned input-shape set (identical for all 10 LM-family archs).
SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train", microbatch=16),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    changes = dict(
        n_layers=min(cfg.n_layers, 2 * len(cfg.block_pattern)),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        head_dim=16,
        n_experts=min(cfg.n_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        n_dense_layers=min(cfg.n_dense_layers, 1),
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        q_lora_rank=32 if cfg.q_lora_rank else 0,
        kv_lora_rank=32 if cfg.kv_lora_rank else 0,
        qk_nope_head_dim=16 if cfg.qk_nope_head_dim else 0,
        qk_rope_head_dim=8 if cfg.qk_rope_head_dim else 0,
        v_head_dim=16 if cfg.v_head_dim else 0,
        ssm_state=min(cfg.ssm_state, 16),
        ssm_heads=min(cfg.ssm_heads, 4) if cfg.ssm_heads else 0,
        ssm_chunk=32,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else None,
        shared_attn_every=min(cfg.shared_attn_every, 2) if cfg.shared_attn_every else 0,
        frontend_dim=32 if cfg.frontend_dim else 0,
        frontend_len=min(cfg.frontend_len, 8) if cfg.frontend_len else 0,
        mtp_depth=cfg.mtp_depth,
        dtype="float32",
    )
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
