"""ChatGLM3-6B (GQA kv=2, half-rotary 2d RoPE).  [arXiv:2406.12793]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    head_dim=128,
    mlp="swiglu",
    norm="rmsnorm",
    rope_mode="half",  # ChatGLM applies rotary to half the head dims (2d RoPE)
)
