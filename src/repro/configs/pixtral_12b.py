"""Pixtral-12B (pixtral-ViT frontend stub + mistral-nemo-like backbone).
[hf:mistralai/Pixtral-12B-2409]

The ViT frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (B, n_patches, 1024) that the backbone projects
into d_model and splices over the leading token positions.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1e6,
    frontend="vit",
    frontend_dim=1024,  # pixtral ViT width
    frontend_len=256,  # patches per image (16x16 grid stub)
)
