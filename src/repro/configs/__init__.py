"""Config registry: ``get_config(name)`` / ``ARCHS`` / shape registry."""
from __future__ import annotations

from typing import Dict

from .base import SHAPES, ArchConfig, ShapeConfig, reduced  # noqa: F401
from .chatglm3_6b import CONFIG as chatglm3_6b
from .deepseek_v3_671b import CONFIG as deepseek_v3_671b
from .mistral_large_123b import CONFIG as mistral_large_123b
from .mixtral_8x7b import CONFIG as mixtral_8x7b
from .nemotron_4_340b import CONFIG as nemotron_4_340b
from .pixtral_12b import CONFIG as pixtral_12b
from .seamless_m4t_large_v2 import CONFIG as seamless_m4t_large_v2
from .smollm_135m import CONFIG as smollm_135m
from .xlstm_125m import CONFIG as xlstm_125m
from .zamba2_1p2b import CONFIG as zamba2_1p2b

ARCHS: Dict[str, ArchConfig] = {
    c.name: c
    for c in (
        mistral_large_123b,
        nemotron_4_340b,
        smollm_135m,
        chatglm3_6b,
        mixtral_8x7b,
        deepseek_v3_671b,
        pixtral_12b,
        seamless_m4t_large_v2,
        xlstm_125m,
        zamba2_1p2b,
    )
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; available: {sorted(ARCHS)}")
    return ARCHS[name]
