"""Span tracing: nested wall-time spans + point events over simulated time.

Two record kinds flow through a :class:`Tracer`:

* **spans** — wall-clock intervals with causal structure.  ``tracer.span()``
  is a context manager; spans opened while another span is active become its
  children (``parent_id``), and every span carries the ``trace_id`` of its
  root, so a whole engine verb (``compact`` -> ``plan`` -> ``score`` ->
  ``commit``) reconstructs as one tree from a flat JSONL dump.
* **events** — zero-duration (or explicitly-durationed) points on an
  *arbitrary* clock, used for simulated-time marks like migration windows
  and autoscale decisions where wall time is meaningless.

The default process-global tracer is a :class:`NoopTracer`: ``span()``
returns a shared singleton whose ``__enter__``/``__exit__``/``set`` do
nothing, so instrumentation left in hot paths costs one attribute lookup and
one call when telemetry is disabled.  Seeded simulations are byte-identical
with tracing on or off — spans observe, they never touch placement state.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Dict, List, Optional

__all__ = ["Span", "SpanEvent", "Tracer", "NoopTracer", "NOOP_SPAN"]


@dataclasses.dataclass
class SpanEvent:
    """A point (or explicitly-durationed) mark on a caller-supplied clock."""

    name: str
    time: float  # caller's clock — simulated seconds at the sim call sites
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    duration: float = 0.0

    def as_dict(self) -> Dict[str, Any]:
        return {
            "kind": "event",
            "name": self.name,
            "time": self.time,
            "duration": self.duration,
            "attrs": dict(self.attrs),
        }


class Span:
    """One wall-time interval in a trace tree.

    Used as a context manager (via :meth:`Tracer.span`); ``set(**attrs)``
    attaches attributes at any point while open or after close.
    """

    __slots__ = (
        "name", "span_id", "parent_id", "trace_id",
        "start_unix", "duration", "attrs", "_tracer", "_t0", "status",
    )

    def __init__(
        self,
        tracer: "Tracer",
        name: str,
        span_id: str,
        parent_id: Optional[str],
        trace_id: str,
        attrs: Optional[Dict[str, Any]] = None,
    ):
        self._tracer = tracer
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.trace_id = trace_id
        self.attrs: Dict[str, Any] = attrs or {}
        self.start_unix = time.time()
        self._t0 = time.perf_counter()
        self.duration = 0.0
        self.status = "ok"

    def set(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.duration = time.perf_counter() - self._t0
        if exc_type is not None:
            self.status = "error"
            self.attrs.setdefault("error", exc_type.__name__)
        self._tracer._finish(self)
        return False

    def as_dict(self) -> Dict[str, Any]:
        return {
            "kind": "span",
            "name": self.name,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "trace_id": self.trace_id,
            "start_unix": self.start_unix,
            "duration_s": self.duration,
            "status": self.status,
            "attrs": dict(self.attrs),
        }


class Tracer:
    """Collects finished spans and events; maintains the open-span stack."""

    enabled = True

    def __init__(self, max_records: int = 200_000):
        #: drop-oldest cap so unbounded runs cannot exhaust memory.
        self.max_records = max_records
        self.spans: List[Span] = []
        self.events: List[SpanEvent] = []
        self._stack: List[Span] = []
        self._ids = itertools.count(1)
        self.n_dropped = 0

    # -- recording ----------------------------------------------------------
    def span(self, name: str, **attrs: Any) -> Span:
        parent = self._stack[-1] if self._stack else None
        sid = f"s{next(self._ids)}"
        sp = Span(
            self,
            name,
            span_id=sid,
            parent_id=parent.span_id if parent else None,
            trace_id=parent.trace_id if parent else sid,
            attrs=attrs or None,
        )
        self._stack.append(sp)
        return sp

    def event(self, name: str, time: float, duration: float = 0.0,
              **attrs: Any) -> SpanEvent:
        ev = SpanEvent(name=name, time=time, duration=duration, attrs=attrs)
        if len(self.events) < self.max_records:
            self.events.append(ev)
        else:
            self.n_dropped += 1
        return ev

    def _finish(self, span: Span) -> None:
        # Pop to (and including) the finishing span: mis-nested exits close
        # abandoned children rather than corrupting the stack.
        while self._stack:
            top = self._stack.pop()
            if top is span:
                break
        if len(self.spans) < self.max_records:
            self.spans.append(span)
        else:
            self.n_dropped += 1

    # -- queries ------------------------------------------------------------
    @property
    def current(self) -> Optional[Span]:
        return self._stack[-1] if self._stack else None

    def find(self, name: str) -> List[Span]:
        return [s for s in self.spans if s.name == name]

    def children_of(self, span: Span) -> List[Span]:
        return [s for s in self.spans if s.parent_id == span.span_id]

    def records(self) -> List[Dict[str, Any]]:
        """All finished spans + events as JSONL-ready dicts."""
        return [s.as_dict() for s in self.spans] + [e.as_dict() for e in self.events]

    def clear(self) -> None:
        self.spans.clear()
        self.events.clear()
        self._stack.clear()
        self.n_dropped = 0


class _NoopSpan:
    """Shared do-nothing span: the disabled-telemetry fast path."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False

    def set(self, **attrs: Any) -> "_NoopSpan":
        return self


NOOP_SPAN = _NoopSpan()


class NoopTracer:
    """Default tracer: every operation is a constant-time no-op."""

    enabled = False
    spans: List[Span] = []
    events: List[SpanEvent] = []

    def span(self, name: str, **attrs: Any) -> _NoopSpan:
        return NOOP_SPAN

    def event(self, name: str, time: float, duration: float = 0.0,
              **attrs: Any) -> None:
        return None

    def records(self) -> List[Dict[str, Any]]:
        return []

    def find(self, name: str) -> List[Span]:
        return []

    def clear(self) -> None:
        pass
