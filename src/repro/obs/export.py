"""Exporters: Prometheus text exposition + JSONL span/event dumps.

* :func:`prometheus_text` renders a :class:`~repro.obs.metrics.MetricsRegistry`
  in the Prometheus text exposition format (version 0.0.4) — the thing a
  ``GET /metrics`` scrape returns.  Histograms emit cumulative ``_bucket``
  series with the standard ``le`` label plus ``_sum`` / ``_count``.
* :func:`write_jsonl` / :func:`iter_jsonl` dump and reload the tracer's
  span/event records, one strict-JSON object per line (non-finite floats are
  sanitized to ``null`` so any parser can read the file back).
"""
from __future__ import annotations

import json
import math
import os
import time
from typing import Any, Dict, Iterable, Iterator, List, TextIO, Union

from .metrics import Counter, Gauge, Histogram, MetricsRegistry

__all__ = [
    "prometheus_text",
    "sanitize_json",
    "write_jsonl",
    "iter_jsonl",
    "write_report",
]


def sanitize_json(obj: Any) -> Any:
    """Recursively replace non-finite floats with ``None`` (strict JSON has
    no NaN/Infinity) and stringify non-JSON scalar types."""
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, dict):
        return {str(k): sanitize_json(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [sanitize_json(v) for v in obj]
    if obj is None or isinstance(obj, (bool, int, str)):
        return obj
    return str(obj)


def write_report(
    path: Union[str, "os.PathLike[str]", None],
    report: Dict[str, Any],
    schema: str,
    merge: bool = True,
) -> bool:
    """Write a ``BENCH_*.json`` / ``CALIBRATION.json``-style report: strict
    JSON (non-finite floats sanitized to ``null``, ``allow_nan=False``),
    stamped with ``schema`` and ``generated_unix``.

    Every machine-readable artifact in the repo goes through this one
    writer so the :mod:`benchmarks.validate_bench` CI gate's strictness
    promise holds by construction.  With ``merge=True`` (default) the new
    sections are merged over an existing report of the same schema family
    (``"placement_bench/v1"`` merges onto any ``"placement_bench/*"``),
    so e.g. a ``--trace`` run and an ``--autoscale`` run can share one
    file.  Returns True when a file was written (``path`` falsy = no-op).
    """
    if not path:
        return False
    family = schema.split("/", 1)[0] + "/"
    merged: Dict[str, Any] = {}
    if merge and os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
            if isinstance(prev, dict) and str(prev.get("schema", "")).startswith(family):
                merged = prev
        except (OSError, ValueError):
            pass  # unreadable previous report: start fresh
    merged.update(report)
    merged["schema"] = schema
    merged["generated_unix"] = time.time()
    with open(path, "w") as f:
        json.dump(sanitize_json(merged), f, indent=2, sort_keys=True,
                  allow_nan=False)
    return True


def _fmt(v: float) -> str:
    if v != v:  # NaN
        return "NaN"
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _labels_with(inst, extra: Dict[str, str]) -> str:
    pairs = list(inst.labels) + sorted(extra.items())
    if not pairs:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in pairs) + "}"


def prometheus_text(registry: MetricsRegistry, prefix: str = "repro_") -> str:
    """Render every instrument in the Prometheus text exposition format."""
    lines: List[str] = []
    for name, insts in registry.families().items():
        full = prefix + name
        first = insts[0]
        if first.help:
            lines.append(f"# HELP {full} {first.help}")
        lines.append(f"# TYPE {full} {first.kind}")
        for inst in insts:
            if isinstance(inst, Histogram):
                for ub, cum in inst.cumulative_buckets():
                    lbl = _labels_with(inst, {"le": _fmt(ub)})
                    lines.append(f"{full}_bucket{lbl} {cum}")
                lines.append(f"{full}_sum{inst.label_str()} {_fmt(inst.sum)}")
                lines.append(f"{full}_count{inst.label_str()} {inst.count}")
            elif isinstance(inst, (Counter, Gauge)):
                lines.append(f"{full}{inst.label_str()} {_fmt(inst.value)}")
    return "\n".join(lines) + ("\n" if lines else "")


def write_jsonl(
    records: Iterable[Dict[str, Any]], dest: Union[str, "os.PathLike[str]", TextIO]
) -> int:
    """Write records one strict-JSON object per line; returns the count."""
    n = 0

    def _dump(f: TextIO) -> int:
        count = 0
        for rec in records:
            try:
                # fast path: most records are already finite + serializable
                line = json.dumps(rec, allow_nan=False, sort_keys=True)
            except (TypeError, ValueError):
                line = json.dumps(sanitize_json(rec), allow_nan=False,
                                  sort_keys=True)
            f.write(line + "\n")
            count += 1
        return count

    if isinstance(dest, (str, os.PathLike)):
        with open(dest, "w") as f:
            n = _dump(f)
    else:
        n = _dump(dest)
    return n


def iter_jsonl(
    src: Union[str, "os.PathLike[str]", TextIO]
) -> Iterator[Dict[str, Any]]:
    """Yield records back from a JSONL file or handle (strict parse)."""

    def _parse(f: TextIO) -> Iterator[Dict[str, Any]]:
        for line in f:
            line = line.strip()
            if line:
                yield json.loads(line)

    if isinstance(src, (str, os.PathLike)):
        with open(src) as f:
            yield from _parse(f)
    else:
        yield from _parse(src)
