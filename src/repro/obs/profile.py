"""Kernel calibration profiler: measure what each MIG slice can serve.

Closes the measure -> model -> plan loop (ROADMAP item 3).  The placement
stack plans against :class:`repro.core.perfmodel.PerfModel`, which until
this subsystem shipped was a hand-written whole-device rate table.  This
module runs the actual ``repro.kernels`` ops — flash attention (prefill),
decode attention (decode), and the SSD scan — across **MIG-profile-shaped
problem sizes** and derives measured prefill/decode service rates per
partition profile, producing:

* per-rep wall-time observations in the active :mod:`repro.obs` metrics
  registry (``kernel_wall_seconds{kernel,device,profile}`` histograms);
* a schema-validated ``CALIBRATION.json`` artifact
  (:data:`CALIBRATION_SCHEMA`) that ``PerfModel.from_calibration`` loads
  back into the planning stack, and that the CI regression gate
  (:mod:`benchmarks.validate_bench`) checks structurally.

Slice emulation
---------------
A profile with ``c`` of the device's compute slices and ``m`` of its
memory slices gets a problem scaled to its budget: the prefill batch
scales with the compute fraction (prefill is compute-bound), the decode
batch with the memory fraction (decode bandwidth travels with the memory
slices — the MISO observation).  On a host **without** real MIG
partitions (CPU CI, a whole GPU) the kernel still sees the full machine,
so measured per-token cost captures only the *shape* efficiency; the
slice's compute/memory fraction is then applied analytically
(``emulate=True``, recorded as ``emulated`` in the artifact).  On real
MIG hardware, run this same profiler inside each GPU instance with
``emulate=False`` and the fraction drops out of the measurement itself.

The sweep additionally fits an effective ``parallel_efficiency`` exponent
from the sub-whole-device measurements (``rate_p / rate_whole =
frac**e``): shape-dependent per-token overheads at small slices surface
as ``e < 1``, exactly the sublinear knob ``PerfModel`` already exposes.

Timing discipline: every measurement jits the op once, runs ``warmup``
discarded iterations (compile + cache effects), then times ``reps``
individual iterations with ``block_until_ready`` around each — the same
regimen as ``benchmarks/kernel_bench.py``, which shares these specs.
Inputs come from fixed seeds, so the measured *structure* (shapes, FLOPs,
bytes, tokens) is deterministic; only wall times vary by host.
"""
from __future__ import annotations

import dataclasses
import logging
import math
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from . import get_telemetry
from .host import host_snapshot

log = logging.getLogger("repro.obs.profile")

__all__ = [
    "CALIBRATION_SCHEMA",
    "PRESETS",
    "KernelTiming",
    "measure",
    "whole_device_specs",
    "run_calibration",
]

#: schema tag of the CALIBRATION.json artifact (validate_bench checks it).
CALIBRATION_SCHEMA = "calibration/v1"

#: problem-size presets: whole-device base shapes per kernel plus the
#: default timing discipline.  ``tiny`` is the CI smoke (seconds on one
#: CPU); ``full`` matches the historical kernel_bench shapes.
PRESETS: Dict[str, Dict[str, object]] = {
    "tiny": dict(
        flash=dict(b=2, s=256, hq=4, hkv=2, d=64),
        decode=dict(b=4, smax=256, hq=4, hkv=2, d=64),
        ssd=dict(b=2, s=256, h=2, p=16, n=8),
        reps=3, warmup=1,
    ),
    "small": dict(
        flash=dict(b=4, s=1024, hq=8, hkv=2, d=64),
        decode=dict(b=16, smax=2048, hq=8, hkv=2, d=64),
        ssd=dict(b=2, s=512, h=4, p=32, n=16),
        reps=5, warmup=2,
    ),
    "full": dict(
        flash=dict(b=8, s=2048, hq=8, hkv=2, d=64),
        decode=dict(b=32, smax=8192, hq=8, hkv=2, d=64),
        ssd=dict(b=4, s=1024, h=4, p=32, n=16),
        reps=10, warmup=3,
    ),
}

#: fitted parallel-efficiency samples are clamped here before averaging —
#: tiny-shape noise must not push the exponent out of PerfModel's (0, 1].
_EFF_CLAMP = (0.25, 1.0)


def _pct(sorted_vals: Sequence[float], q: float) -> float:
    """numpy-style linear-interpolation percentile of pre-sorted values."""
    if not sorted_vals:
        return float("nan")
    pos = (len(sorted_vals) - 1) * (q / 100.0)
    lo, hi = int(math.floor(pos)), int(math.ceil(pos))
    if lo == hi:
        return sorted_vals[lo]
    frac = pos - lo
    return sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac


@dataclasses.dataclass(frozen=True)
class KernelTiming:
    """Warm-up-disciplined wall times of one (kernel, shape) measurement."""

    wall_s: Tuple[float, ...]  # per-rep seconds, chronological

    @property
    def p50(self) -> float:
        return _pct(sorted(self.wall_s), 50.0)

    @property
    def p95(self) -> float:
        return _pct(sorted(self.wall_s), 95.0)

    def as_dict(self) -> Dict[str, float]:
        s = sorted(self.wall_s)
        return {
            "reps": len(s),
            "min": s[0],
            "mean": sum(s) / len(s),
            "p50": _pct(s, 50.0),
            "p95": _pct(s, 95.0),
        }


def measure(
    fn: Callable,
    *args,
    reps: int = 5,
    warmup: int = 2,
    labels: Optional[Dict[str, str]] = None,
) -> KernelTiming:
    """Time ``fn(*args)``: ``warmup`` discarded calls, then ``reps`` timed
    calls, each synchronized with ``jax.block_until_ready``.

    Each rep is observed into the active telemetry's
    ``kernel_wall_seconds`` histogram under ``labels`` (no-op when
    telemetry is disabled — same discipline as the rest of the stack).
    """
    import jax

    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn(*args))
    tel = get_telemetry()
    hist = tel.metrics.histogram(
        "kernel_wall_seconds", "per-rep kernel wall time", labels=labels or {}
    )
    walls: List[float] = []
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        dt = time.perf_counter() - t0
        walls.append(dt)
        hist.observe(dt)
    return KernelTiming(tuple(walls))


# ---------------------------------------------------------------------------
# kernel workload specs (shared with benchmarks/kernel_bench.py)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class _Workload:
    """One concrete (kernel, shape): inputs, analytics, token accounting."""

    kernel: str
    shape: str
    make: Callable[[], Tuple]  # () -> (jitted fn, args)
    tokens: int  # tokens processed per call (prefill: B*S; decode: B)
    flops: float
    bytes: float


def _flash_workload(b: int, s: int, hq: int, hkv: int, d: int) -> _Workload:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    def make():
        key = jax.random.key(0)
        q = jax.random.normal(key, (b, s, hq, d), jnp.float32)
        k = jax.random.normal(key, (b, s, hkv, d), jnp.float32)
        v = jax.random.normal(key, (b, s, hkv, d), jnp.float32)
        fn = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, causal=True))
        return fn, (q, k, v)

    flops = 4 * b * s * s * hq * d / 2  # causal halves the score matmul
    byts = 4.0 * (2 * b * s * hq * d + 2 * b * s * hkv * d)
    return _Workload("flash_attention", f"B{b}xS{s}xH{hq}/{hkv}xD{d}",
                     make, b * s, flops, byts)


def _decode_workload(b: int, smax: int, hq: int, hkv: int, d: int) -> _Workload:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    def make():
        key = jax.random.key(0)
        q = jax.random.normal(key, (b, 1, hq, d), jnp.float32)
        k = jax.random.normal(key, (b, smax, hkv, d), jnp.float32)
        v = jax.random.normal(key, (b, smax, hkv, d), jnp.float32)
        lens = jnp.full((b,), smax // 2, jnp.int32)
        fn = jax.jit(lambda q, k, v, l: ops.decode_attention(q, k, v, l))
        return fn, (q, k, v, lens)

    flops = 4.0 * b * smax * hq * d
    byts = 4.0 * (2 * b * hq * d + 2 * b * smax * hkv * d) + 4.0 * b
    return _Workload("decode_attention", f"B{b}xS{smax}ragged",
                     make, b, flops, byts)


def _ssd_workload(b: int, s: int, h: int, p: int, n: int) -> _Workload:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    def make():
        key = jax.random.key(0)
        x = jax.random.normal(key, (b, s, h, p), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(key, (b, s, h), jnp.float32))
        A = -jnp.ones((h,), jnp.float32)
        B_ = jax.random.normal(key, (b, s, n), jnp.float32)
        C = jax.random.normal(key, (b, s, n), jnp.float32)
        chunk = min(256, s)
        fn = jax.jit(lambda *a: ops.ssd_scan(*a, chunk=chunk))
        return fn, (x, dt, A, B_, C)

    flops = 2.0 * b * s * h * p * n * 2
    byts = 4.0 * (2 * b * s * h * p + b * s * h + 2 * b * s * n + b * h * p * n)
    return _Workload("ssd_scan", f"B{b}xS{s}xH{h}xP{p}xN{n}",
                     make, b * s, flops, byts)


def whole_device_specs(preset: str = "full") -> List[_Workload]:
    """The preset's whole-device workloads (kernel_bench runs exactly these)."""
    cfg = PRESETS[preset]
    return [
        _flash_workload(**cfg["flash"]),
        _decode_workload(**cfg["decode"]),
        _ssd_workload(**cfg["ssd"]),
    ]


def _scaled(base: int, frac: float) -> int:
    return max(1, round(base * frac))


# ---------------------------------------------------------------------------
# the profile sweep
# ---------------------------------------------------------------------------
def _sweep_profiles(device) -> List:
    """Profiles to measure: distinct (compute, memory) footprints, big->small
    (the ``+me`` variant duplicates its base profile's budget — skip it)."""
    seen = set()
    out = []
    for prof in device.profiles_sorted_desc():
        key = (prof.compute_slices, prof.memory_slices)
        if key in seen:
            continue
        seen.add(key)
        out.append(prof)
    return out


def _timing_row(wl: _Workload, device_name: str, prof, cfrac: float,
                mfrac: float, reps: int, warmup: int) -> Dict[str, object]:
    fn, args = wl.make()
    timing = measure(
        fn, *args, reps=reps, warmup=warmup,
        labels={"kernel": wl.kernel, "device": device_name, "profile": prof.name},
    )
    p50 = timing.p50
    return {
        "kernel": wl.kernel,
        "device": device_name,
        "profile_id": prof.profile_id,
        "profile": prof.name,
        "compute_frac": cfrac,
        "memory_frac": mfrac,
        "shape": wl.shape,
        "tokens": wl.tokens,
        "flops": wl.flops,
        "bytes": wl.bytes,
        "wall_s": timing.as_dict(),
        "tokens_per_s": wl.tokens / p50 if p50 > 0 else float("nan"),
        "achieved_gflops_per_s": wl.flops / p50 / 1e9 if p50 > 0 else float("nan"),
        "achieved_gbytes_per_s": wl.bytes / p50 / 1e9 if p50 > 0 else float("nan"),
    }


def _fit_efficiency(samples: List[Tuple[float, float]]) -> float:
    """Effective parallel-efficiency exponent from (frac, eff_ratio) pairs,
    where ``eff_ratio`` is the slice-shaped run's per-token rate over the
    whole-device per-token rate: ``rate_p/rate_whole = frac**e`` with the
    fraction applied analytically gives ``e = 1 + ln(eff)/ln(frac)``."""
    es = []
    for frac, eff in samples:
        if not (0.0 < frac < 1.0) or not (eff > 0.0) or not math.isfinite(eff):
            continue
        e = 1.0 + math.log(eff) / math.log(frac)
        es.append(min(max(e, _EFF_CLAMP[0]), _EFF_CLAMP[1]))
    if not es:
        return 1.0
    return sum(es) / len(es)


def profile_device(
    device,
    preset: str = "small",
    reps: Optional[int] = None,
    warmup: Optional[int] = None,
    emulate: bool = True,
) -> Tuple[Dict[str, object], List[Dict[str, object]]]:
    """Measure one device model across its profile ladder.

    Returns ``(device_entry, kernel_rows)``: the former is the
    ``devices[<name>]`` section of the calibration artifact (whole-device
    rates, per-profile rates, fitted ``parallel_efficiency``), the latter
    the raw per-(kernel, profile) measurement rows.
    """
    cfg = PRESETS[preset]
    reps = int(cfg["reps"] if reps is None else reps)
    warmup = int(cfg["warmup"] if warmup is None else warmup)
    flash, decode, ssd = cfg["flash"], cfg["decode"], cfg["ssd"]

    rows: List[Dict[str, object]] = []
    profiles_entry: Dict[str, Dict[str, object]] = {}
    whole: Dict[str, float] = {}
    eff_samples: List[Tuple[float, float]] = []
    whole_rate: Dict[str, float] = {}  # kernel -> whole-device tokens/s (raw)

    for prof in _sweep_profiles(device):
        cfrac = prof.compute_slices / device.n_gpu_slices
        mfrac = prof.memory_slices / device.n_memory_slices
        workloads = (
            _flash_workload(**{**flash, "b": _scaled(flash["b"], cfrac)}),
            _decode_workload(**{**decode, "b": _scaled(decode["b"], mfrac)}),
            _ssd_workload(**{**ssd, "b": _scaled(ssd["b"], cfrac)}),
        )
        log.info("profiling %s / %s (c=%d/%d m=%d/%d) ...",
                 device.name, prof.name, prof.compute_slices,
                 device.n_gpu_slices, prof.memory_slices,
                 device.n_memory_slices)
        by_kernel: Dict[str, Dict[str, object]] = {}
        for wl in workloads:
            row = _timing_row(wl, device.name, prof, cfrac, mfrac, reps, warmup)
            rows.append(row)
            by_kernel[wl.kernel] = row

        raw_prefill = float(by_kernel["flash_attention"]["tokens_per_s"])
        raw_decode = float(by_kernel["decode_attention"]["tokens_per_s"])
        # on non-MIG hosts the kernel saw the whole machine: apply the
        # slice's fraction analytically (see module docstring).
        prefill_tps = raw_prefill * (cfrac if emulate else 1.0)
        decode_tps = raw_decode * (mfrac if emulate else 1.0)
        is_whole = (prof.compute_slices == device.n_gpu_slices)
        if is_whole:
            whole = {
                "prefill_tokens_per_s": prefill_tps,
                "decode_tokens_per_s": decode_tps,
            }
            whole_rate = {"prefill": raw_prefill, "decode": raw_decode}
        else:
            if whole_rate.get("prefill"):
                eff_samples.append((cfrac, raw_prefill / whole_rate["prefill"]))
            if whole_rate.get("decode"):
                eff_samples.append((mfrac, raw_decode / whole_rate["decode"]))
        profiles_entry[str(prof.profile_id)] = {
            "name": prof.name,
            "compute_frac": cfrac,
            "memory_frac": mfrac,
            "prefill_tokens_per_s": prefill_tps,
            "decode_tokens_per_s": decode_tps,
        }

    entry = {
        "whole_device": whole,
        "parallel_efficiency": _fit_efficiency(eff_samples),
        "emulated": emulate,
        "profiles": profiles_entry,
    }
    return entry, rows


def run_calibration(
    devices: Optional[Sequence] = None,
    preset: str = "small",
    reps: Optional[int] = None,
    warmup: Optional[int] = None,
    emulate: bool = True,
    impl: Optional[str] = None,
) -> Dict[str, object]:
    """The full calibration sweep -> a ``CALIBRATION.json``-shaped dict.

    Write it with ``obs.write_report(path, report, CALIBRATION_SCHEMA)``
    (the :mod:`benchmarks.calibrate` driver does exactly that) and load it
    back with ``PerfModel.from_calibration(path)``.
    """
    from repro.core.profiles import A100_80GB
    from repro.kernels import ops

    if impl is not None:
        ops.set_impl(impl)
    devices = list(devices) if devices else [A100_80GB]
    host = host_snapshot()

    report: Dict[str, object] = {
        "config": {
            "preset": preset,
            "reps": reps if reps is not None else PRESETS[preset]["reps"],
            "warmup": warmup if warmup is not None else PRESETS[preset]["warmup"],
            "emulated": emulate,
            "impl": ops.get_impl(),
            "devices": [d.name for d in devices],
        },
        "host": host,
        "devices": {},
        "kernels": [],
    }
    for device in devices:
        entry, rows = profile_device(
            device, preset=preset, reps=reps, warmup=warmup, emulate=emulate
        )
        report["devices"][device.name] = entry
        report["kernels"].extend(rows)
    return report
