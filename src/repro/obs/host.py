"""Host-contention guard: is this machine quiet enough to trust timings?

Benchmark numbers taken on a loaded host are noise dressed as data — a
stale ``pytest`` from a previous session or a concurrent bench run steals
cycles and inflates every percentile.  The bench entrypoints
(``benchmarks/kernel_bench.py``, ``benchmarks/calibrate.py``,
``benchmarks/placement_bench.py``) call :func:`host_snapshot` before
timing anything, log a warning when the host looks contended, and record
the snapshot (including the ``contended`` flag) in their JSON reports so
downstream consumers — the :mod:`benchmarks.validate_bench` regression
gate in particular — can discount or reject polluted runs.

Detection is deliberately cheap and dependency-free:

* 1-minute load average vs. CPU count (``os.getloadavg``);
* a ``/proc`` scan for *other* processes whose command lines look like
  test or bench runs (``pytest``, ``benchmarks.*``, ``calibrate``).

Neither signal is perfect — the load average lags by design and ``/proc``
is Linux-only (elsewhere the scan degrades to "no competitors found") —
but together they catch the common failure mode: forgotten runs from a
previous session still burning CPU when a new measurement starts.
"""
from __future__ import annotations

import logging
import os
from typing import Dict, List, Optional, Sequence

__all__ = ["COMPETING_PATTERNS", "competing_processes", "host_snapshot"]

log = logging.getLogger("repro.obs.host")

#: command-line substrings that mark a process as a timing competitor.
COMPETING_PATTERNS: tuple = (
    "pytest",
    "benchmarks.kernel_bench",
    "benchmarks.placement_bench",
    "benchmarks.calibrate",
    "benchmarks.solver_scaling",
)

#: load1 / n_cpus above this fraction counts as contended even with no
#: recognizable competitor (something else is eating the machine).
_LOAD_FRACTION_THRESHOLD = 0.75


def competing_processes(
    patterns: Sequence[str] = COMPETING_PATTERNS,
    exclude_pids: Optional[Sequence[int]] = None,
) -> List[Dict[str, object]]:
    """Other live processes whose cmdline matches a bench/test pattern.

    The calling process (and any explicit ``exclude_pids``, e.g. parent
    test runners that legitimately wrap the bench) are skipped.  Returns
    ``[{"pid": int, "cmdline": str}, ...]``; empty on non-Linux hosts.
    """
    skip = {os.getpid()}
    skip.update(exclude_pids or ())
    # walking up the parent chain excludes the pytest that *launched* us
    # (a test invoking the bench in-process is not contention).
    try:
        pid = os.getppid()
        while pid > 1 and len(skip) < 32:
            skip.add(pid)
            with open(f"/proc/{pid}/stat") as f:
                pid = int(f.read().split()[3])
    except (OSError, ValueError, IndexError):
        pass

    out: List[Dict[str, object]] = []
    try:
        pids = [int(d) for d in os.listdir("/proc") if d.isdigit()]
    except OSError:
        return out
    for pid in pids:
        if pid in skip:
            continue
        try:
            with open(f"/proc/{pid}/cmdline", "rb") as f:
                cmd = f.read().replace(b"\0", b" ").decode(errors="replace").strip()
        except OSError:
            continue  # raced with process exit
        if cmd and any(p in cmd for p in patterns):
            out.append({"pid": pid, "cmdline": cmd[:200]})
    return out


def host_snapshot(warn: bool = True) -> Dict[str, object]:
    """Contention snapshot for a bench report's ``host`` section.

    Keys: ``load1`` (1-minute load average, None where unsupported),
    ``n_cpus``, ``competing`` (pid/cmdline rows), and the verdict
    ``contended`` — True when competitors exist or load1 exceeds
    75% of the CPU count.
    """
    try:
        load1 = float(os.getloadavg()[0])
    except (OSError, AttributeError):
        load1 = None
    n_cpus = os.cpu_count() or 1
    competing = competing_processes()
    contended = bool(competing) or (
        load1 is not None and load1 >= _LOAD_FRACTION_THRESHOLD * n_cpus
    )
    snap: Dict[str, object] = {
        "load1": load1,
        "n_cpus": n_cpus,
        "competing": competing,
        "contended": contended,
    }
    if warn and contended:
        who = ", ".join(str(c["pid"]) for c in competing) or "high load"
        log.warning(
            "host looks CONTENDED (load1=%s over %d cpu(s); %s) — timings "
            "in this report are suspect; report carries contended=true",
            f"{load1:.2f}" if load1 is not None else "?", n_cpus, who,
        )
    return snap
