"""Trace report: per-verb latency tables + migration/autoscale timelines.

Turns a JSONL span/event dump (``obs.write_jsonl(tracer.records(), path)``;
``placement_bench --telemetry`` writes one) into something an SRE can read:

    python -m repro.obs.report trace.jsonl
    python -m repro.obs.report trace.jsonl --html timeline.html

* **latency table** — one row per span name (engine verbs and their
  plan/score/commit children, plan execution steps, autoscale ticks):
  count, total seconds, p50/p95/p99.
* **timeline** — simulated-time lanes over the trace horizon: migration
  windows render as filled intervals, autoscale decisions as +/- marks,
  plan rejections and deferrals as points.  The HTML variant renders the
  same lanes as positioned blocks with hover tooltips.

Pure stdlib; numpy-free on purpose (the report must run anywhere the JSONL
landed, e.g. a laptop reading a CI artifact).
"""
from __future__ import annotations

import argparse
import math
from typing import Any, Dict, Iterable, List, Optional, Tuple

from .export import iter_jsonl

__all__ = [
    "load_records",
    "latency_table",
    "format_latency_table",
    "ascii_timeline",
    "html_timeline",
    "render_report",
    "main",
]

#: event names drawn as filled intervals (everything else is a point mark).
_INTERVAL_EVENTS = ("migration_window",)
#: point-mark glyphs per event name (default "*").
_MARKS = {
    "autoscale_up": "+",
    "autoscale_down": "-",
    "autoscale_resize": "~",
    "plan_rejected": "x",
    "verb_deferred": "d",
}


def _percentile(vals: List[float], q: float) -> float:
    """numpy.percentile (linear interpolation), stdlib-only."""
    if not vals:
        return float("nan")
    vals = sorted(vals)
    pos = (len(vals) - 1) * (q / 100.0)
    lo, hi = int(math.floor(pos)), int(math.ceil(pos))
    if lo == hi:
        return vals[lo]
    return vals[lo] * (1.0 - (pos - lo)) + vals[hi] * (pos - lo)


def load_records(path: str) -> Tuple[List[Dict], List[Dict]]:
    """(spans, events) from a JSONL dump, in file order."""
    spans: List[Dict] = []
    events: List[Dict] = []
    for rec in iter_jsonl(path):
        kind = rec.get("kind")
        if kind == "span":
            spans.append(rec)
        elif kind == "event":
            events.append(rec)
    return spans, events


# ---------------------------------------------------------------------------
# latency table
# ---------------------------------------------------------------------------
def latency_table(spans: Iterable[Dict]) -> List[Dict[str, Any]]:
    """Per span-name latency stats, ordered by total time descending."""
    by_name: Dict[str, List[float]] = {}
    for sp in spans:
        d = sp.get("duration_s")
        if d is not None:
            by_name.setdefault(sp["name"], []).append(float(d))
    rows = []
    for name, durs in by_name.items():
        rows.append({
            "name": name,
            "count": len(durs),
            "total_s": sum(durs),
            "p50_s": _percentile(durs, 50),
            "p95_s": _percentile(durs, 95),
            "p99_s": _percentile(durs, 99),
            "max_s": max(durs),
        })
    rows.sort(key=lambda r: -r["total_s"])
    return rows


def format_latency_table(rows: List[Dict[str, Any]]) -> str:
    if not rows:
        return "(no spans)"
    width = max(12, max(len(r["name"]) for r in rows) + 2)
    cols = ("count", "total_s", "p50_s", "p95_s", "p99_s", "max_s")
    out = ["span".ljust(width) + "".join(c.rjust(12) for c in cols)]
    for r in rows:
        line = r["name"].ljust(width) + f"{r['count']:12d}"
        for c in cols[1:]:
            line += f"{r[c]:12.5f}"
        out.append(line)
    return "\n".join(out)


# ---------------------------------------------------------------------------
# timelines
# ---------------------------------------------------------------------------
def _lanes(events: List[Dict]) -> Dict[str, List[Dict]]:
    lanes: Dict[str, List[Dict]] = {}
    for ev in events:
        lanes.setdefault(ev["name"], []).append(ev)
    return lanes


def _horizon(events: List[Dict]) -> float:
    hi = 0.0
    for ev in events:
        hi = max(hi, float(ev.get("time", 0.0)) + float(ev.get("duration", 0.0)))
    return hi


def ascii_timeline(events: List[Dict], width: int = 72,
                   horizon: Optional[float] = None) -> str:
    """One character lane per event name over simulated time."""
    if not events:
        return "(no events)"
    hi = horizon if horizon is not None else _horizon(events)
    hi = max(hi, 1e-9)
    scale = (width - 1) / hi
    lanes = _lanes(events)
    label_w = max(len(n) for n in lanes) + 2
    lines = [
        " " * label_w + f"0{'sim seconds'.center(width - 8)}{hi:7.1f}",
        " " * label_w + "|" + "-" * (width - 2) + "|",
    ]
    for name in sorted(lanes):
        row = [" "] * width
        for ev in lanes[name]:
            a = int(float(ev["time"]) * scale)
            if name in _INTERVAL_EVENTS and float(ev.get("duration", 0.0)) > 0:
                b = int((float(ev["time"]) + float(ev["duration"])) * scale)
                for i in range(max(a, 0), min(max(b, a + 1), width)):
                    row[i] = "#"
            elif 0 <= a < width:
                row[a] = _MARKS.get(name, "*")
        lines.append(name.ljust(label_w) + "".join(row))
    return "\n".join(lines)


_HTML_HEAD = """<!doctype html><meta charset="utf-8">
<title>repro.obs trace report</title>
<style>
 body { font: 13px/1.4 system-ui, sans-serif; margin: 24px; }
 table { border-collapse: collapse; margin-bottom: 24px; }
 th, td { padding: 2px 10px; text-align: right; border-bottom: 1px solid #ddd; }
 th:first-child, td:first-child { text-align: left; }
 .lane { position: relative; height: 18px; background: #f4f4f4;
         margin: 2px 0 2px 180px; }
 .lane-label { position: absolute; left: -180px; width: 172px;
               text-align: right; color: #555; }
 .iv { position: absolute; top: 2px; bottom: 2px; background: #4a7fb5;
       opacity: .8; min-width: 2px; }
 .pt { position: absolute; top: 4px; width: 3px; bottom: 6px;
       background: #b5564a; }
</style>
"""


def html_timeline(events: List[Dict], spans: List[Dict],
                  horizon: Optional[float] = None) -> str:
    """Self-contained HTML: the latency table + positioned timeline lanes."""
    rows = latency_table(spans)
    hi = max(horizon if horizon is not None else _horizon(events), 1e-9)
    parts = [_HTML_HEAD, "<h2>Per-span latency</h2><table>",
             "<tr><th>span</th><th>count</th><th>total&nbsp;s</th>"
             "<th>p50</th><th>p95</th><th>p99</th></tr>"]
    for r in rows:
        parts.append(
            f"<tr><td>{r['name']}</td><td>{r['count']}</td>"
            f"<td>{r['total_s']:.5f}</td><td>{r['p50_s']:.5f}</td>"
            f"<td>{r['p95_s']:.5f}</td><td>{r['p99_s']:.5f}</td></tr>"
        )
    parts.append("</table>")
    parts.append(f"<h2>Timeline (0 &ndash; {hi:.1f} sim s)</h2>")
    for name, evs in sorted(_lanes(events).items()):
        parts.append(f'<div class="lane"><span class="lane-label">{name}</span>')
        for ev in evs:
            left = 100.0 * float(ev["time"]) / hi
            attrs = ", ".join(f"{k}={v}" for k, v in (ev.get("attrs") or {}).items())
            title = f't={ev["time"]:.1f}s {attrs}'
            if name in _INTERVAL_EVENTS and float(ev.get("duration", 0.0)) > 0:
                w = 100.0 * float(ev["duration"]) / hi
                parts.append(
                    f'<div class="iv" title="{title}" '
                    f'style="left:{left:.2f}%;width:{w:.2f}%"></div>'
                )
            else:
                parts.append(
                    f'<div class="pt" title="{title}" '
                    f'style="left:{left:.2f}%"></div>'
                )
        parts.append("</div>")
    return "".join(parts)


def render_report(path: str, width: int = 72) -> str:
    """The full ASCII report for one JSONL dump."""
    spans, events = load_records(path)
    out = [
        f"trace: {path} — {len(spans)} spans, {len(events)} events",
        "",
        "== per-span latency (wall seconds) ==",
        format_latency_table(latency_table(spans)),
        "",
        "== simulated-time timeline ==",
        ascii_timeline(events, width=width),
    ]
    return "\n".join(out)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a repro.obs JSONL trace as latency tables "
        "and migration/autoscale timelines.",
    )
    ap.add_argument("trace", help="JSONL span/event dump")
    ap.add_argument("--width", type=int, default=72,
                    help="ASCII timeline width in characters")
    ap.add_argument("--html", default=None, metavar="PATH",
                    help="also write a self-contained HTML report")
    args = ap.parse_args(argv)
    print(render_report(args.trace, width=args.width))
    if args.html:
        spans, events = load_records(args.trace)
        with open(args.html, "w") as f:
            f.write(html_timeline(events, spans))
        print(f"\nwrote {args.html}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
