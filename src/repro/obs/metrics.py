"""Metrics registry: counters / gauges / histograms with ring-buffer series.

Instruments are created through a :class:`MetricsRegistry` and addressed by
``(name, labels)`` — the Prometheus data model, minus the dependency:

    reg = MetricsRegistry()
    reg.counter("plans_committed_total", "committed plans").inc()
    reg.histogram("planner_latency_seconds", "verb latency",
                  labels={"verb": "compact"}).observe(0.012)
    reg.gauge("gpus_used", "fleet occupancy").set(34, t=sim_now)

Every instrument keeps a fixed-capacity **ring buffer** of ``(t, value)``
points (drop-oldest), so long online simulations retain a bounded recent
time series per metric — the continuous fragmentation/utilization signals
MISO-style repartitioning presumes.  ``t`` defaults to wall time but call
sites on the simulators pass the simulated clock.

Histograms additionally keep Prometheus-style cumulative bucket counts plus
a bounded reservoir of raw observations; ``percentile()`` matches
``numpy.percentile`` (linear interpolation) on the retained reservoir —
property-tested in ``tests/test_obs.py``.

The registry is deterministic: no randomness, insertion-ordered iteration,
and nothing here ever touches placement state.
"""
from __future__ import annotations

import math
import time
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

__all__ = [
    "TimeSeries",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NoopMetricsRegistry",
    "DEFAULT_BUCKETS",
]

#: default histogram buckets (seconds-flavored: micro-latency to minutes).
DEFAULT_BUCKETS: Tuple[float, ...] = (
    1e-4, 5e-4, 1e-3, 5e-3, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0, 60.0,
)

LabelSet = Tuple[Tuple[str, str], ...]


def _labelset(labels: Optional[Mapping[str, object]]) -> LabelSet:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class TimeSeries:
    """Fixed-capacity ring buffer of (t, value) points (drop-oldest)."""

    __slots__ = ("capacity", "_t", "_v", "_head", "_n")

    def __init__(self, capacity: int = 1024):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._t: List[float] = [0.0] * capacity
        self._v: List[float] = [0.0] * capacity
        self._head = 0  # next write position
        self._n = 0

    def append(self, t: float, v: float) -> None:
        self._t[self._head] = t
        self._v[self._head] = v
        self._head = (self._head + 1) % self.capacity
        if self._n < self.capacity:
            self._n += 1

    def __len__(self) -> int:
        return self._n

    def points(self) -> List[Tuple[float, float]]:
        """Chronological (t, value) pairs currently retained."""
        if self._n < self.capacity:
            idx: Iterable[int] = range(self._n)
        else:
            idx = (
                (self._head + i) % self.capacity for i in range(self.capacity)
            )
        return [(self._t[i], self._v[i]) for i in idx]

    def values(self) -> List[float]:
        return [v for _, v in self.points()]


class _Instrument:
    """Shared base: identity + ring-buffer series."""

    kind = "untyped"

    def __init__(self, name: str, help_: str, labels: LabelSet,
                 series_capacity: int = 1024):
        self.name = name
        self.help = help_
        self.labels = labels
        self.series = TimeSeries(series_capacity)

    def _now(self, t: Optional[float]) -> float:
        return time.time() if t is None else t

    def label_str(self) -> str:
        if not self.labels:
            return ""
        inner = ",".join(f'{k}="{v}"' for k, v in self.labels)
        return "{" + inner + "}"


class Counter(_Instrument):
    """Monotonic cumulative count; the series records the running total."""

    kind = "counter"

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.value = 0.0

    def inc(self, amount: float = 1.0, t: Optional[float] = None) -> None:
        if amount < 0:
            raise ValueError("counters only go up; use a Gauge")
        self.value += amount
        self.series.append(self._now(t), self.value)


class Gauge(_Instrument):
    """A value that goes up and down; the series records each set/add."""

    kind = "gauge"

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.value = 0.0

    def set(self, value: float, t: Optional[float] = None) -> None:
        self.value = float(value)
        self.series.append(self._now(t), self.value)

    def add(self, amount: float, t: Optional[float] = None) -> None:
        self.set(self.value + amount, t=t)


class Histogram(_Instrument):
    """Prometheus-style cumulative buckets + a bounded raw reservoir.

    The reservoir (same ring-buffer discipline as the series) backs
    :meth:`percentile`; with fewer observations than the reservoir capacity
    the percentiles are exact.
    """

    kind = "histogram"

    def __init__(self, name: str, help_: str, labels: LabelSet,
                 series_capacity: int = 1024,
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help_, labels, series_capacity)
        self.buckets = tuple(sorted(buckets))
        self.bucket_counts = [0] * (len(self.buckets) + 1)  # +Inf last
        self.count = 0
        self.sum = 0.0
        self.reservoir = TimeSeries(series_capacity)

    def observe(self, value: float, t: Optional[float] = None) -> None:
        value = float(value)
        self.count += 1
        self.sum += value
        for i, ub in enumerate(self.buckets):
            if value <= ub:
                self.bucket_counts[i] += 1
                break
        else:
            self.bucket_counts[-1] += 1
        now = self._now(t)
        self.series.append(now, value)
        self.reservoir.append(now, value)

    def cumulative_buckets(self) -> List[Tuple[float, int]]:
        """(upper_bound, cumulative count) pairs, +Inf last."""
        out: List[Tuple[float, int]] = []
        running = 0
        for ub, c in zip(self.buckets, self.bucket_counts):
            running += c
            out.append((ub, running))
        out.append((math.inf, running + self.bucket_counts[-1]))
        return out

    def percentile(self, q: float) -> float:
        """q-th percentile (0..100) of the retained reservoir, linear
        interpolation — matches ``numpy.percentile`` defaults."""
        vals = sorted(self.reservoir.values())
        if not vals:
            return float("nan")
        if not 0.0 <= q <= 100.0:
            raise ValueError("q must be in [0, 100]")
        pos = (len(vals) - 1) * (q / 100.0)
        lo = int(math.floor(pos))
        hi = int(math.ceil(pos))
        if lo == hi:
            return vals[lo]
        frac = pos - lo
        return vals[lo] * (1.0 - frac) + vals[hi] * frac

    def percentiles(self, qs: Sequence[float] = (50, 95, 99)) -> Dict[str, float]:
        return {f"p{q:g}": self.percentile(q) for q in qs}


class MetricsRegistry:
    """Creates and holds instruments keyed by (name, labels).

    Repeat calls with the same identity return the same instrument, so call
    sites never need to cache handles (though hot paths may).
    """

    enabled = True

    def __init__(self, series_capacity: int = 1024):
        self.series_capacity = series_capacity
        self._instruments: Dict[Tuple[str, LabelSet], _Instrument] = {}
        self._helps: Dict[str, str] = {}

    def _get(self, cls, name: str, help_: str,
             labels: Optional[Mapping[str, object]], **kwargs):
        key = (name, _labelset(labels))
        inst = self._instruments.get(key)
        if inst is None:
            inst = cls(name, help_ or self._helps.get(name, ""), key[1],
                       series_capacity=self.series_capacity, **kwargs)
            self._instruments[key] = inst
            self._helps.setdefault(name, inst.help)
        elif not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} already registered as {inst.kind}"
            )
        return inst

    def counter(self, name: str, help_: str = "",
                labels: Optional[Mapping[str, object]] = None) -> Counter:
        return self._get(Counter, name, help_, labels)

    def gauge(self, name: str, help_: str = "",
              labels: Optional[Mapping[str, object]] = None) -> Gauge:
        return self._get(Gauge, name, help_, labels)

    def histogram(self, name: str, help_: str = "",
                  labels: Optional[Mapping[str, object]] = None,
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help_, labels, buckets=buckets)

    # -- queries ------------------------------------------------------------
    def instruments(self) -> List[_Instrument]:
        return list(self._instruments.values())

    def families(self) -> Dict[str, List[_Instrument]]:
        """name -> instruments (one per label set), insertion-ordered."""
        fams: Dict[str, List[_Instrument]] = {}
        for inst in self._instruments.values():
            fams.setdefault(inst.name, []).append(inst)
        return fams

    def get(self, name: str,
            labels: Optional[Mapping[str, object]] = None) -> Optional[_Instrument]:
        return self._instruments.get((name, _labelset(labels)))

    def clear(self) -> None:
        self._instruments.clear()
        self._helps.clear()


class _NoopInstrument:
    """Accepts every instrument method and does nothing."""

    __slots__ = ()
    value = 0.0
    count = 0
    sum = 0.0

    def inc(self, amount: float = 1.0, t: Optional[float] = None) -> None:
        pass

    def set(self, value: float, t: Optional[float] = None) -> None:
        pass

    def add(self, amount: float, t: Optional[float] = None) -> None:
        pass

    def observe(self, value: float, t: Optional[float] = None) -> None:
        pass

    def percentile(self, q: float) -> float:
        return float("nan")

    def percentiles(self, qs: Sequence[float] = (50, 95, 99)) -> Dict[str, float]:
        return {}


_NOOP_INSTRUMENT = _NoopInstrument()


class NoopMetricsRegistry:
    """Default registry: constant-time no-ops, nothing retained."""

    enabled = False

    def counter(self, name: str, help_: str = "",
                labels: Optional[Mapping[str, object]] = None) -> _NoopInstrument:
        return _NOOP_INSTRUMENT

    def gauge(self, name: str, help_: str = "",
              labels: Optional[Mapping[str, object]] = None) -> _NoopInstrument:
        return _NOOP_INSTRUMENT

    def histogram(self, name: str, help_: str = "",
                  labels: Optional[Mapping[str, object]] = None,
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> _NoopInstrument:
        return _NOOP_INSTRUMENT

    def instruments(self) -> List[_Instrument]:
        return []

    def families(self) -> Dict[str, List[_Instrument]]:
        return {}

    def get(self, name: str,
            labels: Optional[Mapping[str, object]] = None) -> None:
        return None

    def clear(self) -> None:
        pass
