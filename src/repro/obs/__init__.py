"""repro.obs — zero-dependency fleet telemetry (spans, metrics, exporters).

The control plane (engine verbs, online/demand simulators, serving cluster,
placement fabric) is instrumented against a process-global
:class:`Telemetry` handle.  The default handle is a **no-op**: seeded
simulations stay byte-identical and the instrumentation costs one global
read plus one no-op call per site.  Opt in explicitly:

    from repro import obs

    tel = obs.enable()                  # install a live Telemetry
    ... run simulations / engine verbs ...
    print(obs.prometheus_text(tel.metrics))          # scrape-format dump
    obs.write_jsonl(tel.tracer.records(), "trace.jsonl")
    obs.disable()                       # restore the no-op default

Render a JSONL trace afterwards:

    python -m repro.obs.report trace.jsonl            # latency table + timeline
    python -m repro.obs.report trace.jsonl --html t.html

Layers (see the submodules for detail):

* :mod:`repro.obs.trace`   — ``Tracer`` / ``Span``: nested wall-time spans
  with causal parent ids, plus simulated-time point events.
* :mod:`repro.obs.metrics` — ``MetricsRegistry``: counters / gauges /
  histograms with fixed-capacity ring-buffer time series and
  numpy-compatible percentile math.
* :mod:`repro.obs.export`  — Prometheus text exposition and strict-JSON
  JSONL span/event dumps.
* :mod:`repro.obs.report`  — per-verb latency tables and an ASCII/HTML
  timeline of migration windows and autoscale decisions.
* :mod:`repro.obs.host`    — host-contention guard for bench entrypoints
  (stale ``pytest``/bench processes, load average) -> ``contended`` flag.
* :mod:`repro.obs.profile` — kernel calibration profiler: measures the
  ``repro.kernels`` ops under MIG-profile-shaped budgets and builds the
  ``CALIBRATION.json`` artifact ``PerfModel.from_calibration`` consumes.
  (Imported lazily — ``repro.obs`` itself stays importable without JAX.)
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Iterator, Optional, Union

from .export import (
    iter_jsonl,
    prometheus_text,
    sanitize_json,
    write_jsonl,
    write_report,
)
from .host import host_snapshot
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NoopMetricsRegistry,
    TimeSeries,
)
from .trace import NoopTracer, Span, SpanEvent, Tracer

__all__ = [
    "Telemetry",
    "get_telemetry",
    "set_telemetry",
    "enable",
    "disable",
    "enabled",
    "Tracer",
    "NoopTracer",
    "Span",
    "SpanEvent",
    "MetricsRegistry",
    "NoopMetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "TimeSeries",
    "prometheus_text",
    "write_jsonl",
    "iter_jsonl",
    "sanitize_json",
    "write_report",
    "host_snapshot",
]


@dataclasses.dataclass
class Telemetry:
    """One tracer + one metrics registry behind a single on/off switch.

    ``enabled`` is the hot-path guard: instrumented code may skip computing
    expensive attributes (fleet fragmentation, byte totals) when False.
    """

    tracer: Union[Tracer, NoopTracer]
    metrics: Union[MetricsRegistry, NoopMetricsRegistry]
    enabled: bool = True

    @classmethod
    def live(cls, max_records: int = 200_000,
             series_capacity: int = 1024) -> "Telemetry":
        return cls(
            tracer=Tracer(max_records=max_records),
            metrics=MetricsRegistry(series_capacity=series_capacity),
            enabled=True,
        )

    @classmethod
    def noop(cls) -> "Telemetry":
        return cls(tracer=NoopTracer(), metrics=NoopMetricsRegistry(),
                   enabled=False)


_NOOP = Telemetry.noop()
_ACTIVE: Telemetry = _NOOP


def get_telemetry() -> Telemetry:
    """The process-global handle every instrumentation site reads."""
    return _ACTIVE


def set_telemetry(tel: Optional[Telemetry]) -> Telemetry:
    """Install ``tel`` (None restores the no-op default); returns it."""
    global _ACTIVE
    _ACTIVE = tel if tel is not None else _NOOP
    return _ACTIVE


def enable(max_records: int = 200_000, series_capacity: int = 1024) -> Telemetry:
    """Install and return a fresh live Telemetry."""
    return set_telemetry(
        Telemetry.live(max_records=max_records, series_capacity=series_capacity)
    )


def disable() -> None:
    """Restore the no-op default (recorded data on the old handle survives)."""
    set_telemetry(None)


@contextlib.contextmanager
def enabled(tel: Optional[Telemetry] = None) -> Iterator[Telemetry]:
    """Scoped enablement: install ``tel`` (or a fresh live handle) for the
    ``with`` body, then restore whatever was active before."""
    prev = get_telemetry()
    active = set_telemetry(tel if tel is not None else Telemetry.live())
    try:
        yield active
    finally:
        set_telemetry(prev)
