"""Deterministic synthetic data pipeline.

``get_batch(step)`` is a pure function of (config, step), which makes the
pipeline trivially resumable after a failure (fault tolerance by
construction) and shardable: every host computes the same global batch and
``jax.device_put`` with a batch-sharded NamedSharding splits it.  The token
stream has learnable structure (a noisy modular-affine sequence), so small
models show decreasing loss within a few hundred steps.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend: Optional[str] = None  # vit | audio
    frontend_len: int = 0
    frontend_dim: int = 0
    dtype: str = "bfloat16"


def get_batch(cfg: DataConfig, step: int) -> Dict[str, jnp.ndarray]:
    """Global batch for ``step`` (numpy-computed, deterministic)."""
    rng = np.random.default_rng(cfg.seed * 1_000_003 + step)
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    start = rng.integers(0, v, size=(b, 1))
    stride = rng.integers(1, 7, size=(b, 1))
    seq = (start + stride * np.arange(s)[None, :]) % v
    noise_mask = rng.random((b, s)) < 0.05
    noise = rng.integers(0, v, size=(b, s))
    tokens = np.where(noise_mask, noise, seq).astype(np.int32)
    batch: Dict[str, jnp.ndarray] = {"tokens": jnp.asarray(tokens)}
    if cfg.frontend == "vit":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.frontend_len, cfg.frontend_dim)) * 0.1,
            dtype=jnp.dtype(cfg.dtype),
        )
    elif cfg.frontend == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.frontend_len, cfg.frontend_dim)) * 0.1,
            dtype=jnp.dtype(cfg.dtype),
        )
    return batch


def shard_batch(batch, mesh, batch_axes=("pod", "data")):
    """Place a host-global batch onto the mesh, batch-dim sharded."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    axes = tuple(a for a in batch_axes if a in mesh.axis_names)

    def put(x):
        spec = P(axes, *([None] * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, batch)
