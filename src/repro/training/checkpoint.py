"""Fault-tolerant checkpointing: atomic, resumable, elastic.

* atomic     — snapshots are written to ``<dir>/tmp-<step>`` and renamed to
               ``<dir>/step-<step>`` only when complete; a crashed save can
               never corrupt the latest good checkpoint.
* resumable  — ``latest_step``/``restore`` let launch/train.py auto-resume
               after process failure; the data pipeline is a pure function
               of step, so resume is exact.
* elastic    — ``restore`` takes target shardings: a checkpoint written on
               N devices restores onto any M-device mesh (leaves are stored
               as host numpy and re-placed with jax.device_put).
* async      — ``save(..., blocking=False)`` snapshots to host memory
               synchronously and writes to disk on a background thread.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_SEP = "/"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def _unflatten(template: Any, flat: Dict[str, np.ndarray]) -> Any:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl in paths:
        key = _SEP.join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"{key}: shape {arr.shape} != expected {tmpl.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ---- write -------------------------------------------------------------
    def save(self, step: int, params: Any, opt_state: Any, blocking: bool = True):
        flat = {"params" + _SEP + k: v for k, v in _flatten(params).items()}
        flat.update({"opt" + _SEP + k: v for k, v in _flatten(opt_state).items()})
        self.wait()
        if blocking:
            self._write(step, flat)
        else:
            self._thread = threading.Thread(target=self._write, args=(step, flat))
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: Dict[str, np.ndarray]):
        tmp = os.path.join(self.dir, f"tmp-{step}")
        final = os.path.join(self.dir, f"step-{step:09d}")
        if os.path.exists(final):
            return  # idempotent: this step was already published atomically
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "state.npz"), **flat)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "n_leaves": len(flat)}, f)
        os.replace(tmp, final)  # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step-{s:09d}"), ignore_errors=True)

    # ---- read ---------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step-"):
                out.append(int(name.split("-")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        step: int,
        params_template: Any,
        opt_template: Any,
        shardings: Optional[Tuple[Any, Any]] = None,
    ) -> Tuple[Any, Any]:
        """Restore onto the CURRENT mesh: pass (param_shardings, opt_shardings)
        to re-place leaves elastically (device counts may differ from the
        writer's)."""
        path = os.path.join(self.dir, f"step-{step:09d}", "state.npz")
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        pf = {k[len("params") + 1 :]: v for k, v in flat.items() if k.startswith("params" + _SEP)}
        of = {k[len("opt") + 1 :]: v for k, v in flat.items() if k.startswith("opt" + _SEP)}
        params = _unflatten(params_template, pf)
        opt_state = _unflatten(opt_template, of)

        def place(tree, shards, template):
            if shards is None:
                return jax.tree.map(
                    lambda a, t: jax.numpy.asarray(a, dtype=t.dtype), tree, template
                )
            return jax.tree.map(
                lambda a, t, s: jax.device_put(
                    np.asarray(a, dtype=t.dtype), s
                ),
                tree,
                template,
                shards,
            )

        ps, os_ = (shardings if shardings else (None, None))
        return place(params, ps, params_template), place(opt_state, os_, opt_template)
