"""Optimizers: AdamW with configurable moment precision, including
int8-QUANTIZED moments (per-row block scales) — the gradient-compression
trick that lets the 340B/671B archs fit v5e HBM when fully sharded.

Pure functional pytrees; no optax dependency.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"  # float32 | bfloat16 | int8
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1


# ---------------------------------------------------------------------------
# int8 block quantization (per leading-row scale)
# ---------------------------------------------------------------------------
def _q8(x: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Symmetric int8 quantization with one f32 scale per row (axis 0 kept)."""
    flat = x.reshape(x.shape[0], -1) if x.ndim > 1 else x.reshape(1, -1)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0 + 1e-20
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return {"q": q.reshape(x.shape), "scale": scale.astype(jnp.float32)}


def _dq8(packed: Dict[str, jnp.ndarray], shape) -> jnp.ndarray:
    q = packed["q"].astype(jnp.float32)
    flat = q.reshape(q.shape[0], -1) if q.ndim > 1 else q.reshape(1, -1)
    return (flat * packed["scale"]).reshape(shape)


def _encode_moment(x: jnp.ndarray, dtype: str):
    if dtype == "int8":
        return _q8(x)
    return x.astype(jnp.dtype(dtype))


def _decode_moment(m, shape, dtype: str) -> jnp.ndarray:
    if dtype == "int8":
        return _dq8(m, shape)
    return m.astype(jnp.float32)


def _is_moment_leaf(node) -> bool:
    return isinstance(node, dict) and set(node) == {"q", "scale"}


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def init(params: Params, cfg: AdamWConfig) -> Params:
    def zero_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _encode_moment(z, cfg.moment_dtype)

    return {
        "m": jax.tree.map(zero_like, params),
        "v": jax.tree.map(zero_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(step: jnp.ndarray, cfg: AdamWConfig) -> jnp.ndarray:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step.astype(jnp.float32) - cfg.warmup_steps)
        / max(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def apply(
    params: Params, grads: Params, state: Params, cfg: AdamWConfig
) -> Tuple[Params, Params, Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0
    lr = schedule(step, cfg)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.flatten(grads)[0]
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])

    new_p, new_m, new_v = [], [], []
    for p, g, m_enc, v_enc in zip(flat_p, flat_g, flat_m, flat_v):
        g = g.astype(jnp.float32) * clip
        m = _decode_moment(m_enc, p.shape, cfg.moment_dtype)
        v = _decode_moment(v_enc, p.shape, cfg.moment_dtype)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # decay matrices only
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(_encode_moment(m, cfg.moment_dtype))
        new_v.append(_encode_moment(v, cfg.moment_dtype))

    params2 = jax.tree.unflatten(treedef, new_p)
    state2 = {
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
        "step": step,
    }
    return params2, state2, {"grad_norm": gnorm, "lr": lr}
