"""Train-step factory: gradient accumulation (lax.scan over microbatches),
block rematerialization, sharded-gradient hints, AdamW update.

The returned step function is pure (params, opt_state, batch) -> (params,
opt_state, metrics) and is meant to be ``jax.jit``-ed with NamedSharding
in/out specs by the launcher (see launch/train.py and launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..models import transformer
from ..models.model_zoo import ModelBundle
from . import optimizer as opt

Params = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatch: int = 0  # global microbatch size; 0 = single shot
    remat: bool = True
    accum_dtype: str = "float32"


def make_train_step(
    mb: ModelBundle, opt_cfg: opt.AdamWConfig, train_cfg: TrainConfig
) -> Callable:
    if train_cfg.remat:
        transformer.set_remat("block")

    def loss_fn(params, batch):
        loss, metrics = mb.loss_fn(params, batch)
        return loss, metrics

    def train_step(params, opt_state, batch):
        bsz = batch["tokens"].shape[0]
        n_micro = 1
        if train_cfg.microbatch:
            # Each microbatch must stay shardable over the FULL data-parallel
            # degree, or SPMD involuntarily rematerializes (all-gathers) every
            # accumulation step — round the microbatch size up to a multiple
            # of dp that divides the global batch.
            dp = _dp_degree()
            mbsz = max(train_cfg.microbatch, dp)
            mbsz = -(-mbsz // dp) * dp
            while bsz % mbsz and mbsz < bsz:
                mbsz += dp
            n_micro = max(1, bsz // mbsz)
        if n_micro > 1:
            mbsz = bsz // n_micro

            def split(x):
                y = x.reshape((n_micro, mbsz) + x.shape[1:])
                return _constrain_micro(y)

            micro_batches = jax.tree.map(split, batch)
            acc_dt = jnp.dtype(train_cfg.accum_dtype)

            def micro(carry, mbatch):
                gacc, lacc = carry
                (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mbatch
                )
                gacc = jax.tree.map(lambda a, g: a + g.astype(acc_dt), gacc, grads)
                return (gacc, lacc + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            (gsum, lsum), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32)), micro_batches
            )
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

        grads = _constrain_like(grads, params)
        params2, opt_state2, om = opt.apply(params, grads, opt_state, opt_cfg)
        return params2, opt_state2, {"loss": loss, **om}

    return train_step


def _dp_degree() -> int:
    """Total data-parallel shards (pod x data) of the ambient mesh."""
    from ..distribution import sharding

    ctx = sharding.current()
    if ctx is None:
        return 1
    mesh = ctx["mesh"]
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def _constrain_micro(y):
    """Pin (n_micro, mbsz, ...) microbatch stacks: batch dim over data axes."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..distribution import sharding

    ctx = sharding.current()
    if ctx is None:
        return y
    mesh = ctx["mesh"]
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not daxes or y.shape[1] % _dp_degree():
        return y
    spec = P(None, daxes if len(daxes) > 1 else daxes[0])
    return jax.lax.with_sharding_constraint(
        y, NamedSharding(mesh, P(*spec, *([None] * (y.ndim - 2))))
    )


def _constrain_like(grads: Params, params: Params) -> Params:
    """Pin gradient shardings to the parameter shardings (ZeRO hint: with
    fsdp rules this makes XLA emit reduce-scatter instead of all-reduce)."""
    from ..distribution import sharding

    ctx = sharding.current()
    if ctx is None:
        return grads
    specs = sharding.param_specs(params, ctx["mesh"], ctx["fsdp"])
    from jax.sharding import NamedSharding

    return jax.tree.map(
        lambda g, s: jax.lax.with_sharding_constraint(
            g, NamedSharding(ctx["mesh"], s)
        ),
        grads,
        specs,
    )
