"""TPU adaptation of the MIG device model (DESIGN.md Sec 2).

A v5e pod (16x16 torus) is partitioned into contiguous row-blocks.  The
analogy to MIG is structural:

  * slice       -> one pod row (16 chips, 16 GB HBM each => 256 GB / row)
  * profile     -> row-block height in {16, 8, 4, 2, 1}
  * allowed idx -> aligned start rows (start % height == 0) so the block is a
                   contiguous sub-torus whose ICI wrap links remain usable —
                   the same "only certain indices" constraint MIG imposes
  * preference  -> descending start row (buddy-allocator discipline: keeps
                   low-index space contiguous for large future blocks, the
                   paper's availability objective 3)

Differences from MIG, as required by the hardware (DESIGN.md): HBM is uniform
per chip, so there is no extra-memory slice (``extra_memory=False``) and
compute/memory slices are always 1:1 — the asymmetric-profile wastage terms
are exercised only by the faithful MIG instantiation.  Wastage on TPU is
fragmentation, which the availability objective captures.
"""
from __future__ import annotations

from typing import Tuple

from .profiles import DeviceModel, Profile

__all__ = ["TPU_V5E_POD", "profile_for_chips"]


def _aligned(height: int, n_rows: int = 16) -> Tuple[int, ...]:
    return tuple(sorted(range(0, n_rows, height), reverse=True))


_TPU_PROFILES = (
    Profile(0, 0, "16x16.4096gb", 16, 16, (0,)),
    Profile(1, 1, "8x16.2048gb", 8, 8, _aligned(8)),
    Profile(2, 2, "4x16.1024gb", 4, 4, _aligned(4)),
    Profile(3, 3, "2x16.512gb", 2, 2, _aligned(2)),
    Profile(4, 4, "1x16.256gb", 1, 1, _aligned(1)),
)

TPU_V5E_POD = DeviceModel(
    name="TPUv5e-16x16-pod",
    n_gpu_slices=16,  # rows
    n_memory_slices=16,
    mem_per_slice_gb=256,  # 16 chips x 16 GB HBM
    profiles=_TPU_PROFILES,
    extra_memory=False,
    max_media_extensions=0,
)


def profile_for_chips(hbm_bytes_needed: int, device: DeviceModel = TPU_V5E_POD) -> Profile:
    """Smallest row-block profile whose HBM fits the requirement."""
    for prof in sorted(device.profiles, key=lambda p: p.memory_slices):
        if prof.memory_slices * device.mem_per_slice_gb * (1 << 30) >= hbm_bytes_needed:
            return prof
    return device.profiles_sorted_desc()[0]  # full pod
