"""Seeded fault injection for the online simulators (ROADMAP item 5a).

A production MIG fleet loses capacity involuntarily: GPUs die, single
memory slices go bad (row-remapping exhaustion), nodes get drained for
kernel upgrades, and maintenance windows take whole hosts away.  This
module turns those incidents into a deterministic, replayable event
stream that ``OnlineSimulator`` / ``DemandSimulator`` merge with their
arrival traffic:

  * ``FaultSpec``     — one fault *class*: kind + Poisson rate and/or
                        explicit times, targets hit per event, and an
                        optional auto-repair duration (MTTR)
  * ``FaultEvent``    — one concrete incident (or its paired ``repair``)
                        aimed at a specific GPU
  * ``FaultInjector`` — expands specs into a sorted event schedule

Determinism contract (mirrors ``traffic.generate_requests``): the
injector derives one ``SeedSequence`` substream per spec, so adding,
removing, or re-parameterizing one fault spec never perturbs the events
drawn for the others — and the injector never touches the arrival
streams' RNGs at all, so a run with ``FaultInjector([])`` is
byte-identical to a run with no injector.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from .state import ClusterState

__all__ = [
    "FAULT_KINDS",
    "FaultSpec",
    "FaultEvent",
    "FaultInjector",
]

#: injectable incident kinds ("repair" events are derived, not injected).
FAULT_KINDS = (
    "gpu_failure",
    "slice_failure",
    "node_drain",
    "maintenance_window",
)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One class of fault to inject over a trace.

    Events fire at every time in ``at`` plus a Poisson process of
    ``rate`` events/second over the horizon; each event hits ``count``
    distinct GPUs drawn (without replacement) from ``gids`` (default:
    the whole fleet).  ``duration`` > 0 schedules a paired ``repair``
    event (the incident's MTTR — drains and maintenance windows end,
    hardware gets swapped); 0 means the target stays down for the rest
    of the trace.
    """

    kind: str
    rate: float = 0.0
    at: Tuple[float, ...] = ()
    count: int = 1
    duration: float = 0.0
    gids: Tuple[str, ...] = ()
    name: str = ""

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"fault kind must be one of {FAULT_KINDS}, got {self.kind!r}"
            )
        if self.rate < 0 or self.duration < 0 or self.count < 1:
            raise ValueError(f"invalid fault spec: {self}")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One concrete incident (or its auto-repair) aimed at a GPU."""

    time: float
    kind: str  # one of FAULT_KINDS, or "repair"
    gid: str
    #: failed memory position for ``slice_failure`` (-1 otherwise).
    index: int = -1
    #: MTTR carried on the incident (0 = permanent; repairs carry 0).
    duration: float = 0.0
    #: originating spec name (diagnostics / telemetry labels).
    spec: str = ""


class FaultInjector:
    """Expands ``FaultSpec``s into a deterministic ``FaultEvent`` schedule.

    Per-spec ``SeedSequence`` substreams (same pattern as
    ``traffic.generate_requests``) keep specs independent: spec *i*'s
    times and targets depend only on ``(seed, i)``.
    """

    def __init__(self, specs: Sequence[FaultSpec], seed: int = 0):
        self.specs = list(specs)
        self.seed = seed

    def schedule(self, fleet: ClusterState, horizon: float) -> List[FaultEvent]:
        """All fault + repair events over ``[0, horizon)`` for ``fleet``.

        Repairs are paired at schedule time (incident time + duration)
        and may land past the horizon — the simulators still apply them
        (health is restored) but clamp any accounting to the horizon.
        """
        if not self.specs:
            return []
        events: List[FaultEvent] = []
        streams = np.random.SeedSequence(self.seed).spawn(len(self.specs))
        for spec, stream in zip(self.specs, streams):
            rng = np.random.default_rng(stream)
            pool = [
                g for g in (sorted(spec.gids) or fleet.ordered_gids())
                if g in fleet.gpus
            ]
            times = [float(t) for t in spec.at if 0.0 <= t < horizon]
            if spec.rate > 0.0:
                t = 0.0
                while True:
                    t += float(rng.exponential(1.0 / spec.rate))
                    if t >= horizon:
                        break
                    times.append(t)
            label = spec.name or spec.kind
            for t in sorted(times):
                if not pool:
                    break
                k = min(spec.count, len(pool))
                picks = sorted(
                    int(i) for i in rng.choice(len(pool), size=k, replace=False)
                )
                for j in picks:
                    gid = pool[j]
                    index = -1
                    if spec.kind == "slice_failure":
                        index = int(rng.integers(
                            0, fleet.gpus[gid].device.n_memory_slices
                        ))
                    events.append(FaultEvent(
                        time=t, kind=spec.kind, gid=gid, index=index,
                        duration=spec.duration, spec=label,
                    ))
                    if spec.duration > 0.0:
                        events.append(FaultEvent(
                            time=t + spec.duration, kind="repair", gid=gid,
                            index=index, spec=label,
                        ))
        events.sort(key=lambda e: (e.time, e.kind, e.gid))
        return events
