"""MIG partition profiles and device slice geometry (paper Table 1, Sec 2.1/3.2).

Geometry model
--------------
A MIG-enabled GPU exposes ``n_gpu_slices`` positional *GPU slices* (A100/H100:
7, indexes 0..6) and ``n_memory_slices`` *memory positions* (A100/H100: 8,
positions 0..7).  GPU slice ``i`` owns memory position ``i``; the extra memory
position (m7) is physically attached to GPU slice 6 and is only usable by a
partition that includes the last slice (paper constraint 3.2.3).

A partition of profile ``p`` placed at index ``k`` covers memory positions
``[k, k + p.memory_slices)`` and GPU slices ``[k, min(k + p.memory_slices,
n_gpu_slices))``.  This single rule reproduces the paper's Table 1 exactly:

* ``3g.40gb`` (profile 9) at index 4 covers memory {4,5,6,7} and GPU slices
  {4,5,6}: 3 compute slices, no waste.  At index 0 it covers GPU slices
  {0,1,2,3} but provides only 3 compute slices -> 1 compute slice wasted.
* ``1g.20gb`` (profile 15) at index 6 covers memory {6,7} and GPU slice {6}:
  no waste; anywhere else it blocks 2 GPU slices for 1 compute -> 1 wasted.
* ``1g.10gb`` (profile 19) at index 6 covers memory {6} only, stranding m7
  -> 1 memory slice wasted (Table 3 note).

The model is deliberately abstract (``DeviceModel``) so it can be
instantiated for the paper's A100/H100 MIG geometry *and* for the TPU
pod-partition adaptation (``tpu_profiles.py``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

__all__ = [
    "Profile",
    "DeviceModel",
    "A100_80GB",
    "H100_96GB",
    "PROFILE_BY_ID",
]


@dataclasses.dataclass(frozen=True, order=True)
class Profile:
    """A MIG partition profile (one row of paper Table 1)."""

    sort_key: int = dataclasses.field(repr=False)  # sorts big->small like Table 1
    profile_id: int
    name: str
    compute_slices: int
    memory_slices: int
    #: preference-ordered allowed start indexes (paper Table 1, last column).
    allowed_indexes: Tuple[int, ...]
    #: media-extension profile (at most one per GPU); third bin-pack dimension.
    media_extensions: int = 0

    @property
    def gpu_slices(self) -> int:
        """Positional footprint as listed in Table 1 (placement at index 0)."""
        return min(self.memory_slices, 7)

    def span(self, index: int, n_gpu_slices: int = 7) -> Tuple[range, range]:
        """(memory positions, GPU slices) covered when placed at ``index``."""
        mem = range(index, index + self.memory_slices)
        gpu = range(index, min(index + self.memory_slices, n_gpu_slices))
        return mem, gpu

    def compute_waste_at(self, index: int, n_gpu_slices: int = 7) -> int:
        """Blocked-but-unusable compute slices for a placement at ``index``."""
        _, gpu = self.span(index, n_gpu_slices)
        return len(gpu) - self.compute_slices


def _mk_profiles(mem_per_slice_gb: int) -> Tuple[Profile, ...]:
    """Table 1 for A100/H100-class GPUs (7 GPU slices / 8 memory slices)."""
    m = mem_per_slice_gb
    return (
        Profile(0, 0, f"7g.{8 * m}gb", 7, 8, (0,)),
        Profile(1, 5, f"4g.{4 * m}gb", 4, 4, (0,)),
        Profile(2, 9, f"3g.{4 * m}gb", 3, 4, (4, 0)),
        Profile(3, 14, f"2g.{2 * m}gb", 2, 2, (4, 0, 2)),
        Profile(4, 15, f"1g.{2 * m}gb", 1, 2, (6, 4, 0, 2)),
        Profile(5, 19, f"1g.{m}gb", 1, 1, (6, 4, 5, 0, 1, 2, 3)),
        Profile(6, 20, f"1g.{m}gb+me", 1, 1, (6, 4, 5, 0, 1, 2, 3), media_extensions=1),
    )


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """Abstract partitionable accelerator (a 'bin' in the paper's sense)."""

    name: str
    n_gpu_slices: int  # C_g: total compute slices (A100: 7)
    n_memory_slices: int  # memory positions (A100: 8)
    mem_per_slice_gb: int  # S_g
    profiles: Tuple[Profile, ...]
    #: whether an extra memory position exists beyond the GPU slices (m7).
    extra_memory: bool = True
    max_media_extensions: int = 1

    @property
    def total_memory_gb(self) -> int:  # M_g
        return self.n_memory_slices * self.mem_per_slice_gb

    @functools.cached_property
    def by_id(self) -> Dict[int, Profile]:
        # cached_property writes through the instance __dict__, which frozen
        # dataclasses permit; profile() sits on every placement hot path.
        return {p.profile_id: p for p in self.profiles}

    def profile(self, profile_id: int) -> Profile:
        return self.by_id[profile_id]

    def profiles_sorted_desc(self) -> Tuple[Profile, ...]:
        """Profiles sorted by descending size (= ascending profile id, Table 1)."""
        return tuple(sorted(self.profiles, key=lambda p: p.sort_key))

    def fits(self, counts: Dict[int, int]) -> bool:
        """Pure bin-packing feasibility across resource dimensions (Assump. 1)."""
        c = sum(self.profile(i).compute_slices * n for i, n in counts.items())
        mem = sum(self.profile(i).memory_slices * n for i, n in counts.items())
        me = sum(self.profile(i).media_extensions * n for i, n in counts.items())
        return (
            c <= self.n_gpu_slices
            and mem <= self.n_memory_slices
            and me <= self.max_media_extensions
        )


A100_80GB = DeviceModel(
    name="A100-80GB",
    n_gpu_slices=7,
    n_memory_slices=8,
    mem_per_slice_gb=10,
    profiles=_mk_profiles(10),
)

H100_96GB = DeviceModel(
    name="H100-96GB",
    n_gpu_slices=7,
    n_memory_slices=8,
    mem_per_slice_gb=12,
    profiles=_mk_profiles(12),
)

#: Convenience: A100 profile lookup (the paper's running example).
PROFILE_BY_ID: Dict[int, Profile] = A100_80GB.by_id
