"""Request-level traffic generation: the demand axis of the online problem.

The online simulator (``core/events.py``) historically consumed *workload*
traces — replicas arriving with fixed lifetimes.  Nothing derived how many
replicas a model actually needs.  This module supplies the missing input:
seeded, deterministic streams of individual inference requests
``(timestamp, model, prompt_len, decode_len)`` per served model, which the
perf model (``core/perfmodel.py``) and autoscaler (``core/autoscaler.py``)
convert into replica targets.

Arrival processes are inhomogeneous Poisson, sampled by Lewis-Shedler
thinning against the pattern's peak rate, so every pattern family shares one
code path and one determinism guarantee: the same ``(spec, seed, horizon)``
triple always yields a byte-identical trace.

Patterns (MISO/Saraha-style time-varying demand):
  * ``ConstantRate``  — plain Poisson at ``rps``
  * ``DiurnalRate``   — sinusoidal day/night swing around a base rate
  * ``FlashCrowd``    — base rate plus a multiplicative spike window
                        (breaking-news burst; the autoscaler's hard case)
  * ``replay_rows``   — explicit (time, prompt, decode) rows, e.g. from a
                        production trace dump
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

__all__ = [
    "RequestArrival",
    "RequestTrace",
    "RequestShape",
    "ArrivalPattern",
    "ConstantRate",
    "DiurnalRate",
    "FlashCrowd",
    "ModelTraffic",
    "generate_requests",
    "replay_rows",
]

#: request-size assumption before any request of a model has been observed.
DEFAULT_REQUEST_LENS = (512, 128)


@dataclasses.dataclass
class RequestShape:
    """Running mean request shape of one model (both demand loops share it:
    ``DemandSimulator`` in simulation, ``ClusterServer`` over live engines)."""

    n: int = 0
    prompt_sum: int = 0
    decode_sum: int = 0

    def add(self, prompt_len: int, decode_len: int) -> None:
        self.n += 1
        self.prompt_sum += prompt_len
        self.decode_sum += decode_len

    def means(self) -> Tuple[int, int]:
        """(mean prompt, mean decode) tokens; defaults until observed."""
        if self.n == 0:
            return DEFAULT_REQUEST_LENS
        return (
            max(1, self.prompt_sum // self.n),
            max(1, self.decode_sum // self.n),
        )


@dataclasses.dataclass(frozen=True)
class RequestArrival:
    """One inference request hitting the fleet."""

    time: float
    model: str
    prompt_len: int  # prefill tokens
    decode_len: int  # output tokens to generate
    rid: str = ""


@dataclasses.dataclass
class RequestTrace:
    """Time-sorted request stream over ``[0, horizon)``."""

    requests: List[RequestArrival]
    horizon: float

    def __post_init__(self) -> None:
        self.requests.sort(key=lambda r: (r.time, r.model, r.rid))

    @property
    def n_requests(self) -> int:
        return len(self.requests)

    def models(self) -> Tuple[str, ...]:
        return tuple(sorted({r.model for r in self.requests}))

    def offered_rps(self, model: str, t0: float, t1: float) -> float:
        """Mean arrival rate of ``model`` over ``[t0, t1)``."""
        n = sum(1 for r in self.requests if r.model == model and t0 <= r.time < t1)
        return n / max(t1 - t0, 1e-9)

    def total_tokens(self) -> int:
        return sum(r.prompt_len + r.decode_len for r in self.requests)


# ---------------------------------------------------------------------------
# arrival-rate patterns
# ---------------------------------------------------------------------------
class ArrivalPattern:
    """Time-varying arrival rate lambda(t); must bound its own peak."""

    def rate(self, t: float) -> float:
        raise NotImplementedError

    @property
    def peak_rate(self) -> float:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ConstantRate(ArrivalPattern):
    rps: float

    def rate(self, t: float) -> float:
        return self.rps

    @property
    def peak_rate(self) -> float:
        return self.rps


@dataclasses.dataclass(frozen=True)
class DiurnalRate(ArrivalPattern):
    """``base * (1 + amplitude*sin(2*pi*(t+phase)/period))``, floored at 0.

    One ``period`` is a simulated "day"; different models get different
    ``phase`` values to de-synchronize their peaks (the fleet-level win of
    demand-driven sizing: phase-shifted models share the same GPUs).
    """

    base_rps: float
    amplitude: float = 0.8
    period: float = 200.0
    phase: float = 0.0

    def rate(self, t: float) -> float:
        s = math.sin(2.0 * math.pi * (t + self.phase) / self.period)
        return max(0.0, self.base_rps * (1.0 + self.amplitude * s))

    @property
    def peak_rate(self) -> float:
        return self.base_rps * (1.0 + abs(self.amplitude))


@dataclasses.dataclass(frozen=True)
class FlashCrowd(ArrivalPattern):
    """Steady ``base_rps`` with a ``multiplier``-x spike on
    ``[flash_at, flash_at + flash_duration)`` — the scale-up stress case."""

    base_rps: float
    flash_at: float
    flash_duration: float
    multiplier: float = 5.0

    def rate(self, t: float) -> float:
        if self.flash_at <= t < self.flash_at + self.flash_duration:
            return self.base_rps * self.multiplier
        return self.base_rps

    @property
    def peak_rate(self) -> float:
        return self.base_rps * max(self.multiplier, 1.0)


# ---------------------------------------------------------------------------
# per-model traffic specs -> request streams
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelTraffic:
    """Traffic shape of one served model.

    Request sizes are lognormal around the configured means (clamped to
    >= 1 token) — the long right tail is what stresses TTFT at high load.
    """

    model: str
    pattern: ArrivalPattern
    mean_prompt_len: int = 512
    mean_decode_len: int = 128
    len_sigma: float = 0.5  # lognormal shape for both length draws

    def _draw_len(self, rng: np.random.Generator, mean: int) -> int:
        mu = math.log(max(mean, 1)) - 0.5 * self.len_sigma**2
        return max(1, int(rng.lognormal(mu, self.len_sigma)))


def _thinned_arrivals(
    spec: ModelTraffic, rng: np.random.Generator, horizon: float, tag: int
) -> Iterable[RequestArrival]:
    """Lewis-Shedler thinning of the pattern's inhomogeneous Poisson."""
    lam_max = spec.pattern.peak_rate
    if lam_max <= 0.0:
        return
    t = 0.0
    i = 0
    while True:
        t += float(rng.exponential(1.0 / lam_max))
        if t >= horizon:
            return
        if float(rng.random()) * lam_max > spec.pattern.rate(t):
            continue  # thinned: instantaneous rate below the envelope
        yield RequestArrival(
            time=t,
            model=spec.model,
            prompt_len=spec._draw_len(rng, spec.mean_prompt_len),
            decode_len=spec._draw_len(rng, spec.mean_decode_len),
            rid=f"{spec.model}/q{tag}.{i}",
        )
        i += 1


def generate_requests(
    specs: Sequence[ModelTraffic], seed: int, horizon: float
) -> RequestTrace:
    """Seeded request trace for all ``specs`` over ``[0, horizon)``.

    Each spec draws from its own independent substream (SeedSequence spawn
    keyed by position), so adding a model to the end of ``specs`` never
    perturbs the other models' streams.
    """
    root = np.random.SeedSequence(seed)
    streams = root.spawn(len(specs))
    requests: List[RequestArrival] = []
    for i, spec in enumerate(specs):
        rng = np.random.default_rng(streams[i])
        requests.extend(_thinned_arrivals(spec, rng, horizon, tag=i))
    return RequestTrace(requests=requests, horizon=horizon)


def replay_rows(
    model_rows: Dict[str, Sequence[Tuple[float, int, int]]], horizon: float
) -> RequestTrace:
    """Trace replay: explicit ``(time, prompt_len, decode_len)`` rows per
    model (e.g. parsed from a production log)."""
    requests: List[RequestArrival] = []
    for model, rows in sorted(model_rows.items()):
        for i, (t, plen, dlen) in enumerate(rows):
            if not 0.0 <= t < horizon:
                raise ValueError(
                    f"{model} row {i}: time {t} outside [0, {horizon})"
                )
            requests.append(
                RequestArrival(
                    time=float(t),
                    model=model,
                    prompt_len=int(plen),
                    decode_len=int(dlen),
                    rid=f"{model}/q{i}",
                )
            )
    return RequestTrace(requests=requests, horizon=horizon)
