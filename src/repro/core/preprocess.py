"""Algorithm 1: preprocessing partially-partitioned GPUs into free partitions.

For every GPU ``g`` with immovable pre-existing workloads, compute ``P_g`` —
the set of *largest feasible unallocated partitions* that can be (re-)
partitioned to host new workloads.  Reproduces the paper's Algorithm 1:

    for each slice index k in order:
        if k is not partitioned:
            for profiles big -> small:
                if a type-i partition can be created at index k: place it
                hypothetically and add (c_i, m_i) to P_g

Paper example (Fig. 7): g1 = {1g.10gb@0, 1g.10gb@5, 1g.10gb@6} yields
``P_g1 = [1g.10gb@1, 2g.20gb@2, 1g.10gb@4]``; g2 = {1g.20gb@6} yields
``P_g2 = [4g.40gb@0, 2g.20gb@4]`` and, merged, ``{6g.60gb}``.

Each output partition keeps its concrete memory-position span so that the
indexing step can verify real placements inside it.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

from .profiles import DeviceModel, Profile
from .state import GPUState

__all__ = ["FreePartition", "determine_free_partitions", "merge_partitions"]


@dataclasses.dataclass(frozen=True)
class FreePartition:
    """An unallocated feasible partition on a partially-used GPU (one bin)."""

    pid: str  # unique id, e.g. "gpu3/p0"
    gid: str  # owning GPU
    span: Tuple[int, ...]  # memory positions covered
    compute_capacity: int  # usable compute slices within the span
    memory_capacity: int  # memory slices within the span
    merged: bool = False

    @property
    def start(self) -> int:
        return self.span[0]

    def contains_span(self, mem: range) -> bool:
        return set(mem) <= set(self.span)

    def admits(self, profile: Profile, device: DeviceModel) -> bool:
        """Can one partition of ``profile`` be created inside this span?"""
        if profile.compute_slices > self.compute_capacity:
            return False
        if profile.memory_slices > self.memory_capacity:
            return False
        for idx in profile.allowed_indexes:
            mem, _ = profile.span(idx, device.n_gpu_slices)
            if self.contains_span(mem):
                return True
        return False


def determine_free_partitions(gpu: GPUState, prefix: str = "") -> List[FreePartition]:
    """Algorithm 1 — ``P_g`` for one partially-partitioned GPU."""
    device = gpu.device
    hypo = gpu.clone()
    out: List[FreePartition] = []
    profiles = [p for p in device.profiles_sorted_desc() if not p.media_extensions]
    for k in range(device.n_gpu_slices):
        if hypo.memory_occupancy()[k] is not None:
            continue
        for prof in profiles:  # big -> small (sorted list of profile ids)
            if hypo.can_place_at(prof, k):
                hypo.place(f"_hypo{k}", prof.profile_id, k)
                mem, gpus = prof.span(k, device.n_gpu_slices)
                out.append(
                    FreePartition(
                        pid=f"{prefix}{gpu.gid}/p{len(out)}",
                        gid=gpu.gid,
                        span=tuple(mem),
                        compute_capacity=len(gpus),
                        memory_capacity=len(mem),
                    )
                )
                break
    # Trailing free memory position (m7) with free slice 6 is covered by the
    # k=6 iteration (profiles that extend into m7).  A stranded m7 (slice 6
    # occupied, m7 free) is unusable and yields no partition.
    return out


def merge_partitions(
    parts: List[FreePartition], device: DeviceModel
) -> List[FreePartition]:
    """Merge memory-contiguous free partitions of one GPU into bigger bins.

    The merged set reduces MIP variable count (paper Sec 4).  Merged bins may
    admit index-infeasible contents; callers must verify with the indexing
    step and fall back to the unmerged set on failure.
    """
    by_gpu: dict = {}
    for p in parts:
        by_gpu.setdefault(p.gid, []).append(p)
    merged: List[FreePartition] = []
    for gid, plist in by_gpu.items():
        plist = sorted(plist, key=lambda p: p.start)
        run: List[FreePartition] = []
        for p in plist:
            if run and run[-1].span[-1] + 1 == p.start:
                run.append(p)
            else:
                merged.extend(_fuse(run, gid))
                run = [p]
        merged.extend(_fuse(run, gid))
    return merged


def _fuse(run: List[FreePartition], gid: str) -> List[FreePartition]:
    if not run:
        return []
    if len(run) == 1:
        return list(run)
    span: Tuple[int, ...] = tuple(
        pos for p in run for pos in p.span
    )
    return [
        FreePartition(
            pid=f"{gid}/m{run[0].start}",
            gid=gid,
            span=span,
            compute_capacity=sum(p.compute_capacity for p in run),
            memory_capacity=sum(p.memory_capacity for p in run),
            merged=True,
        )
    ]
