"""Event-driven online placement simulation (beyond-paper).

The paper's three use cases are snapshots of one *online* problem: replicas
arrive, depart, and burst over time while the scheduler periodically
compacts the fleet.  This module simulates that problem over timestamped
traces and heterogeneous fleets (e.g. MIG A100s next to TPU pods), driving
any ``PlacementEngine`` policy:

  * ``Event``          — arrival (possibly a burst of several workloads),
                         departure, or a compaction trigger
  * ``generate_trace`` — seeded Poisson arrivals with exponential lifetimes
                         and occasional bursts, routed across device kinds
                         in proportion to fleet capacity
  * ``OnlineSimulator``— replays a trace through an engine and integrates
                         time-averaged fleet metrics.  Compactions run
                         through the engine's plan/score/commit control
                         plane: a rejected plan is a transactional rollback
                         (no clone-and-restore), a committed plan opens a
                         *migration window* over simulated time — its
                         wave-parallel copies and disruptive drains occupy
                         ``duration_seconds``, during which further
                         compaction triggers are deferred — and its bytes
                         moved / downtime accrue into ``TraceStats``.

Time-averaged metrics follow the ROADMAP's scale axis: what matters online
is not one snapshot's GPU count but the integral of GPUs-used (energy /
cost) and wastage over the trace horizon — now alongside the paper's real
constraint, disruption-minutes spent migrating.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .engine import PlacementEngine
from .fleetgen import FleetSpec, build_fleet  # noqa: F401  (re-exported API)
from .migration import CommitPolicy
from .profiles import DeviceModel
from .state import ClusterState, Workload

__all__ = [
    "Event",
    "Trace",
    "FleetSpec",
    "build_fleet",
    "generate_trace",
    "TraceStats",
    "OnlineSimulator",
]

#: default per-device profile pools for random arrivals (same spirit as
#: simulator._DEFAULT_PROFILE_POOL: skip the trivially-whole-device profile).
_ARRIVAL_POOLS: Dict[str, Tuple[int, ...]] = {
    "A100-80GB": (5, 9, 14, 15, 19),
    "H100-96GB": (5, 9, 14, 15, 19),
    "TPUv5e-16x16-pod": (1, 2, 3, 4),
}


def _pool_for(device: DeviceModel) -> Tuple[int, ...]:
    if device.name in _ARRIVAL_POOLS:
        return _ARRIVAL_POOLS[device.name]
    return tuple(
        p.profile_id for p in device.profiles_sorted_desc()[1:]
    ) or (device.profiles[0].profile_id,)


@dataclasses.dataclass(frozen=True)
class Event:
    """One timestamped trace event."""

    time: float
    kind: str  # "arrival" | "departure" | "compact"
    workloads: Tuple[Workload, ...] = ()  # arrivals; len > 1 == burst
    wids: Tuple[str, ...] = ()  # departures


@dataclasses.dataclass
class Trace:
    events: List[Event]
    horizon: float

    def __post_init__(self) -> None:
        self.events.sort(key=lambda e: (e.time, e.kind))

    @property
    def n_arrivals(self) -> int:
        return sum(len(e.workloads) for e in self.events if e.kind == "arrival")


def generate_trace(
    seed: int,
    fleet: ClusterState,
    horizon: float = 200.0,
    arrival_rate: float = 1.0,
    mean_lifetime: float = 40.0,
    burst_prob: float = 0.1,
    burst_size: Tuple[int, int] = (3, 6),
) -> Trace:
    """Seeded online trace over ``fleet``.

    Arrivals are Poisson(``arrival_rate``); each arrival is a single
    workload, or with ``burst_prob`` a burst of several (a model scaling out
    under load).  Lifetimes are exponential with ``mean_lifetime``; deaths
    past the horizon are dropped (the replica outlives the trace).  Each
    workload targets a device kind with probability proportional to that
    kind's share of fleet memory slices.
    """
    rng = np.random.default_rng(seed)
    kinds: Dict[str, DeviceModel] = {}
    weights: Dict[str, float] = {}
    for gpu in fleet.gpus.values():
        kinds[gpu.device.name] = gpu.device
        weights[gpu.device.name] = (
            weights.get(gpu.device.name, 0.0) + gpu.device.n_memory_slices
        )
    names = sorted(kinds)
    probs = np.array([weights[n] for n in names], dtype=float)
    probs /= probs.sum()

    events: List[Event] = []
    t = 0.0
    wi = 0
    while True:
        t += float(rng.exponential(1.0 / arrival_rate))
        if t >= horizon:
            break
        n = 1
        if float(rng.random()) < burst_prob:
            n = int(rng.integers(burst_size[0], burst_size[1] + 1))
        ws: List[Workload] = []
        for _ in range(n):
            kind = names[int(rng.choice(len(names), p=probs))]
            pool = _pool_for(kinds[kind])
            pid = int(pool[int(rng.choice(len(pool)))])
            w = Workload(wid=f"t{wi}", profile_id=pid, device_kind=kind)
            wi += 1
            ws.append(w)
            death = t + float(rng.exponential(mean_lifetime))
            if death < horizon:
                events.append(Event(time=death, kind="departure", wids=(w.wid,)))
        events.append(Event(time=t, kind="arrival", workloads=tuple(ws)))
    return Trace(events=events, horizon=horizon)


@dataclasses.dataclass
class TraceStats:
    """Time-averaged fleet metrics over one trace replay."""

    policy: str
    horizon: float
    time_avg_gpus_used: float
    time_avg_compute_waste: float
    time_avg_memory_waste: float
    time_avg_mem_occupancy: float  # used / total memory slices, whole fleet
    peak_gpus_used: int
    n_arrived: int = 0
    n_placed: int = 0
    n_rejected: int = 0
    n_departed: int = 0
    n_migrations: int = 0
    n_compactions: int = 0
    n_compactions_skipped: int = 0  # compaction plan rejected by CommitPolicy
    n_compactions_deferred: int = 0  # trigger fell inside a migration window
    n_reconfigures: int = 0
    n_reconfigures_deferred: int = 0
    n_plans_rejected: int = 0  # all rejected plans (compact + reconfigure)
    bytes_moved: float = 0.0
    disruption_seconds: float = 0.0  # summed per-replica unavailability
    migration_window_seconds: float = 0.0  # wall-clock spent migrating
    engine_seconds: float = 0.0

    @property
    def disruption_minutes(self) -> float:
        return self.disruption_seconds / 60.0

    def as_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d["disruption_minutes"] = self.disruption_minutes
        return d


class OnlineSimulator:
    """Replays a trace through a PlacementEngine over a live ClusterState."""

    def __init__(
        self,
        state: ClusterState,
        engine: PlacementEngine,
        compact_every: Optional[float] = None,
        migration_budget: Optional[int] = None,
        reconfigure_every: Optional[float] = None,
    ):
        self.state = state
        self.engine = engine
        self.compact_every = compact_every
        #: periodic maintenance repack (paper Sec 2.3.3) — the expensive
        #: verb the CommitPolicy exists to keep honest online.
        self.reconfigure_every = reconfigure_every
        #: max migrations allowed per compaction (legacy knob) — folded into
        #: a simulator-local CommitPolicy override (applied only around this
        #: simulator's verb calls, never mutating the shared engine), so an
        #: over-budget plan is a transactional rollback, not clone-and-restore.
        self.migration_budget = migration_budget
        self._commit_override: Optional[CommitPolicy] = None
        if migration_budget is not None:
            cp = engine.commit_policy
            if cp.mode == "always":
                cp = CommitPolicy(
                    mode="budgeted",
                    move_budget=migration_budget,
                    downtime_budget_seconds=None,
                )
            else:
                cp = dataclasses.replace(cp, move_budget=migration_budget)
            self._commit_override = cp
        #: end of the currently-open migration window (simulated clock).
        self._busy_until = 0.0

    # -- metric integration over time --------------------------------------
    def _sample(self) -> Tuple[int, int, int, float]:
        used = self.state.used_gpus()
        cmp_waste = sum(g.compute_waste() for g in used)
        mem_waste = sum(g.memory_waste() for g in used)
        total_mem = sum(g.device.n_memory_slices for g in self.state.gpus.values())
        used_mem = sum(g.used_memory_slices() for g in used)
        return len(used), cmp_waste, mem_waste, used_mem / max(total_mem, 1)

    def _events_with_compactions(self, trace: Trace):
        """Merge the trace with periodic compact/reconfigure triggers."""
        periodic = [
            (period, kind)
            for period, kind in (
                (self.compact_every, "compact"),
                (self.reconfigure_every, "reconfigure"),
            )
            if period
        ]
        if not periodic:
            yield from trace.events
            return
        pending = sorted((period, period, kind) for period, kind in periodic)

        def _due(until: float):
            while pending and pending[0][0] <= until:
                t, period, kind = pending.pop(0)
                yield Event(time=t, kind=kind)
                nxt = (t + period, period, kind)
                lo = 0
                while lo < len(pending) and pending[lo][0] <= nxt[0]:
                    lo += 1
                pending.insert(lo, nxt)

        for ev in trace.events:
            yield from _due(ev.time)
            yield ev
        while pending and pending[0][0] < trace.horizon:
            yield from _due(pending[0][0])

    def run(self, trace: Trace) -> TraceStats:
        stats = TraceStats(
            policy=self.engine.policy_name,
            horizon=trace.horizon,
            time_avg_gpus_used=0.0,
            time_avg_compute_waste=0.0,
            time_avg_memory_waste=0.0,
            time_avg_mem_occupancy=0.0,
            peak_gpus_used=0,
        )
        acc = np.zeros(4)  # integrals of the _sample() tuple
        t_prev = 0.0
        for ev in self._events_with_compactions(trace):
            sample = self._sample()
            acc += np.array(sample) * (ev.time - t_prev)
            stats.peak_gpus_used = max(stats.peak_gpus_used, sample[0])
            t_prev = ev.time
            if ev.kind == "arrival":
                self._handle_arrival(ev, stats)
            elif ev.kind == "departure":
                self._handle_departure(ev, stats)
            elif ev.kind in ("compact", "reconfigure"):
                self._handle_plan_verb(ev.kind, stats, ev.time)
            else:  # pragma: no cover
                raise ValueError(f"unknown event kind {ev.kind!r}")
        sample = self._sample()
        acc += np.array(sample) * (trace.horizon - t_prev)
        stats.peak_gpus_used = max(stats.peak_gpus_used, sample[0])
        h = max(trace.horizon, 1e-9)
        (
            stats.time_avg_gpus_used,
            stats.time_avg_compute_waste,
            stats.time_avg_memory_waste,
            stats.time_avg_mem_occupancy,
        ) = (acc / h).tolist()
        return stats

    def _handle_arrival(self, ev: Event, stats: TraceStats) -> None:
        stats.n_arrived += len(ev.workloads)
        res = self.engine.deploy(self.state, list(ev.workloads))
        stats.engine_seconds += res.seconds
        rejected = {w.wid for w in res.pending}
        stats.n_rejected += len(rejected)
        stats.n_placed += len(ev.workloads) - len(rejected)
        # Rejected replicas leave the system (no admission queue — the online
        # analogue of the paper's "pending" metric).
        for wid in rejected:
            self.state.workloads.pop(wid, None)

    def _handle_departure(self, ev: Event, stats: TraceStats) -> None:
        for wid in ev.wids:
            gid = self.state.gpu_of(wid)
            if gid is not None:
                self.state.gpus[gid].remove(wid)
                stats.n_departed += 1
            self.state.workloads.pop(wid, None)

    def _handle_plan_verb(self, verb: str, stats: TraceStats, now: float) -> None:
        if verb not in self.engine.policy.supports:
            return
        if now < self._busy_until:
            # A previous plan's waves/drains still occupy the fleet.
            if verb == "compact":
                stats.n_compactions_deferred += 1
            else:
                stats.n_reconfigures_deferred += 1
            return
        saved = self.engine.commit_policy
        if self._commit_override is not None:
            self.engine.commit_policy = self._commit_override
        try:
            res = getattr(self.engine, verb)(self.state)
        finally:
            self.engine.commit_policy = saved
        stats.engine_seconds += res.seconds
        if not res.committed:
            # Plan rejected by the CommitPolicy -> transactional rollback
            # already restored the layout; nothing moved.
            if verb == "compact":
                stats.n_compactions_skipped += 1
            stats.n_plans_rejected += 1
            return
        if verb == "compact":
            stats.n_compactions += 1
        else:
            stats.n_reconfigures += 1
        # Baseline reconfigure replays may fail to re-place a workload
        # (measured Sec-5.2.3 behavior): it leaves the system, like a
        # rejected arrival.
        for w in res.pending:
            self.state.workloads.pop(w.wid, None)
            stats.n_rejected += 1
        stats.n_migrations += res.plan.n_migrations if res.plan else 0
        if res.cost is not None and res.cost.n_moves:
            stats.bytes_moved += res.cost.total_bytes
            stats.disruption_seconds += res.cost.downtime_seconds
            stats.migration_window_seconds += res.cost.duration_seconds
            self._busy_until = now + res.cost.duration_seconds
