"""Event-driven online placement simulation (beyond-paper).

The paper's three use cases are snapshots of one *online* problem: replicas
arrive, depart, and burst over time while the scheduler periodically
compacts the fleet.  This module simulates that problem over timestamped
traces and heterogeneous fleets (e.g. MIG A100s next to TPU pods), driving
any ``PlacementEngine`` policy:

  * ``Event``          — arrival (possibly a burst of several workloads),
                         departure, or a compaction trigger
  * ``generate_trace`` — seeded Poisson arrivals with exponential lifetimes
                         and occasional bursts, routed across device kinds
                         in proportion to fleet capacity
  * ``OnlineSimulator``— replays a trace through an engine, enforcing an
                         optional per-compaction migration budget (over
                         budget -> the compaction is rolled back), and
                         integrates time-averaged fleet metrics

Time-averaged metrics follow the ROADMAP's scale axis: what matters online
is not one snapshot's GPU count but the integral of GPUs-used (energy /
cost) and wastage over the trace horizon.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .engine import PlacementEngine
from .fleetgen import FleetSpec, build_fleet  # noqa: F401  (re-exported API)
from .profiles import DeviceModel
from .state import ClusterState, Workload

__all__ = [
    "Event",
    "Trace",
    "FleetSpec",
    "build_fleet",
    "generate_trace",
    "TraceStats",
    "OnlineSimulator",
]

#: default per-device profile pools for random arrivals (same spirit as
#: simulator._DEFAULT_PROFILE_POOL: skip the trivially-whole-device profile).
_ARRIVAL_POOLS: Dict[str, Tuple[int, ...]] = {
    "A100-80GB": (5, 9, 14, 15, 19),
    "H100-96GB": (5, 9, 14, 15, 19),
    "TPUv5e-16x16-pod": (1, 2, 3, 4),
}


def _pool_for(device: DeviceModel) -> Tuple[int, ...]:
    if device.name in _ARRIVAL_POOLS:
        return _ARRIVAL_POOLS[device.name]
    return tuple(
        p.profile_id for p in device.profiles_sorted_desc()[1:]
    ) or (device.profiles[0].profile_id,)


@dataclasses.dataclass(frozen=True)
class Event:
    """One timestamped trace event."""

    time: float
    kind: str  # "arrival" | "departure" | "compact"
    workloads: Tuple[Workload, ...] = ()  # arrivals; len > 1 == burst
    wids: Tuple[str, ...] = ()  # departures


@dataclasses.dataclass
class Trace:
    events: List[Event]
    horizon: float

    def __post_init__(self) -> None:
        self.events.sort(key=lambda e: (e.time, e.kind))

    @property
    def n_arrivals(self) -> int:
        return sum(len(e.workloads) for e in self.events if e.kind == "arrival")


def generate_trace(
    seed: int,
    fleet: ClusterState,
    horizon: float = 200.0,
    arrival_rate: float = 1.0,
    mean_lifetime: float = 40.0,
    burst_prob: float = 0.1,
    burst_size: Tuple[int, int] = (3, 6),
) -> Trace:
    """Seeded online trace over ``fleet``.

    Arrivals are Poisson(``arrival_rate``); each arrival is a single
    workload, or with ``burst_prob`` a burst of several (a model scaling out
    under load).  Lifetimes are exponential with ``mean_lifetime``; deaths
    past the horizon are dropped (the replica outlives the trace).  Each
    workload targets a device kind with probability proportional to that
    kind's share of fleet memory slices.
    """
    rng = np.random.default_rng(seed)
    kinds: Dict[str, DeviceModel] = {}
    weights: Dict[str, float] = {}
    for gpu in fleet.gpus.values():
        kinds[gpu.device.name] = gpu.device
        weights[gpu.device.name] = (
            weights.get(gpu.device.name, 0.0) + gpu.device.n_memory_slices
        )
    names = sorted(kinds)
    probs = np.array([weights[n] for n in names], dtype=float)
    probs /= probs.sum()

    events: List[Event] = []
    t = 0.0
    wi = 0
    while True:
        t += float(rng.exponential(1.0 / arrival_rate))
        if t >= horizon:
            break
        n = 1
        if float(rng.random()) < burst_prob:
            n = int(rng.integers(burst_size[0], burst_size[1] + 1))
        ws: List[Workload] = []
        for _ in range(n):
            kind = names[int(rng.choice(len(names), p=probs))]
            pool = _pool_for(kinds[kind])
            pid = int(pool[int(rng.choice(len(pool)))])
            w = Workload(wid=f"t{wi}", profile_id=pid, device_kind=kind)
            wi += 1
            ws.append(w)
            death = t + float(rng.exponential(mean_lifetime))
            if death < horizon:
                events.append(Event(time=death, kind="departure", wids=(w.wid,)))
        events.append(Event(time=t, kind="arrival", workloads=tuple(ws)))
    return Trace(events=events, horizon=horizon)


@dataclasses.dataclass
class TraceStats:
    """Time-averaged fleet metrics over one trace replay."""

    policy: str
    horizon: float
    time_avg_gpus_used: float
    time_avg_compute_waste: float
    time_avg_memory_waste: float
    time_avg_mem_occupancy: float  # used / total memory slices, whole fleet
    peak_gpus_used: int
    n_arrived: int = 0
    n_placed: int = 0
    n_rejected: int = 0
    n_departed: int = 0
    n_migrations: int = 0
    n_compactions: int = 0
    n_compactions_skipped: int = 0  # migration budget exceeded
    engine_seconds: float = 0.0

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def _placement_map(state: ClusterState) -> Dict[str, Tuple[str, int]]:
    return {
        p.wid: (gid, p.index)
        for gid, g in state.gpus.items()
        for p in g.placements
    }


class OnlineSimulator:
    """Replays a trace through a PlacementEngine over a live ClusterState."""

    def __init__(
        self,
        state: ClusterState,
        engine: PlacementEngine,
        compact_every: Optional[float] = None,
        migration_budget: Optional[int] = None,
    ):
        self.state = state
        self.engine = engine
        self.compact_every = compact_every
        #: max migrations allowed per compaction; an over-budget compaction
        #: is rolled back wholesale (the cluster keeps its layout).
        self.migration_budget = migration_budget

    # -- metric integration over time --------------------------------------
    def _sample(self) -> Tuple[int, int, int, float]:
        used = self.state.used_gpus()
        cmp_waste = sum(g.compute_waste() for g in used)
        mem_waste = sum(g.memory_waste() for g in used)
        total_mem = sum(g.device.n_memory_slices for g in self.state.gpus.values())
        used_mem = sum(g.used_memory_slices() for g in used)
        return len(used), cmp_waste, mem_waste, used_mem / max(total_mem, 1)

    def _events_with_compactions(self, trace: Trace):
        if not self.compact_every:
            yield from trace.events
            return
        next_c = self.compact_every
        for ev in trace.events:
            while next_c <= ev.time:
                yield Event(time=next_c, kind="compact")
                next_c += self.compact_every
            yield ev
        while next_c < trace.horizon:
            yield Event(time=next_c, kind="compact")
            next_c += self.compact_every

    def run(self, trace: Trace) -> TraceStats:
        stats = TraceStats(
            policy=self.engine.policy_name,
            horizon=trace.horizon,
            time_avg_gpus_used=0.0,
            time_avg_compute_waste=0.0,
            time_avg_memory_waste=0.0,
            time_avg_mem_occupancy=0.0,
            peak_gpus_used=0,
        )
        acc = np.zeros(4)  # integrals of the _sample() tuple
        t_prev = 0.0
        for ev in self._events_with_compactions(trace):
            sample = self._sample()
            acc += np.array(sample) * (ev.time - t_prev)
            stats.peak_gpus_used = max(stats.peak_gpus_used, sample[0])
            t_prev = ev.time
            if ev.kind == "arrival":
                self._handle_arrival(ev, stats)
            elif ev.kind == "departure":
                self._handle_departure(ev, stats)
            elif ev.kind == "compact":
                self._handle_compact(stats)
            else:  # pragma: no cover
                raise ValueError(f"unknown event kind {ev.kind!r}")
        sample = self._sample()
        acc += np.array(sample) * (trace.horizon - t_prev)
        stats.peak_gpus_used = max(stats.peak_gpus_used, sample[0])
        h = max(trace.horizon, 1e-9)
        (
            stats.time_avg_gpus_used,
            stats.time_avg_compute_waste,
            stats.time_avg_memory_waste,
            stats.time_avg_mem_occupancy,
        ) = (acc / h).tolist()
        return stats

    def _handle_arrival(self, ev: Event, stats: TraceStats) -> None:
        stats.n_arrived += len(ev.workloads)
        res = self.engine.deploy(self.state, list(ev.workloads))
        stats.engine_seconds += res.seconds
        rejected = {w.wid for w in res.pending}
        stats.n_rejected += len(rejected)
        stats.n_placed += len(ev.workloads) - len(rejected)
        # Rejected replicas leave the system (no admission queue — the online
        # analogue of the paper's "pending" metric).
        for wid in rejected:
            self.state.workloads.pop(wid, None)

    def _handle_departure(self, ev: Event, stats: TraceStats) -> None:
        for wid in ev.wids:
            gid = self.state.gpu_of(wid)
            if gid is not None:
                self.state.gpus[gid].remove(wid)
                stats.n_departed += 1
            self.state.workloads.pop(wid, None)

    def _handle_compact(self, stats: TraceStats) -> None:
        if "compact" not in self.engine.policy.supports:
            return
        before = _placement_map(self.state)
        # Policies may replace GPUState objects wholesale (MIP adoption),
        # which the op journal cannot undo — snapshot for budget rollback.
        snapshot = self.state.clone() if self.migration_budget is not None else None
        res = self.engine.compact(self.state)
        stats.engine_seconds += res.seconds
        after = _placement_map(self.state)
        moved = sum(
            1 for wid, spot in after.items() if before.get(wid) != spot
        )
        if self.migration_budget is not None and moved > self.migration_budget:
            self.state.gpus = snapshot.gpus
            self.state.workloads = snapshot.workloads
            stats.n_compactions_skipped += 1
            return
        stats.n_compactions += 1
        stats.n_migrations += moved
