"""Event-driven online placement simulation (beyond-paper).

The paper's three use cases are snapshots of one *online* problem: replicas
arrive, depart, and burst over time while the scheduler periodically
compacts the fleet.  This module simulates that problem over timestamped
traces and heterogeneous fleets (e.g. MIG A100s next to TPU pods), driving
any ``PlacementEngine`` policy:

  * ``Event``          — arrival (possibly a burst of several workloads),
                         departure, or a compaction trigger
  * ``generate_trace`` — seeded Poisson arrivals with exponential lifetimes
                         and occasional bursts, routed across device kinds
                         in proportion to fleet capacity
  * ``OnlineSimulator``— replays a trace through an engine and integrates
                         time-averaged fleet metrics.  Compactions run
                         through the engine's plan/score/commit control
                         plane: a rejected plan is a transactional rollback
                         (no clone-and-restore), a committed plan opens a
                         *migration window* over simulated time — its
                         wave-parallel copies and disruptive drains occupy
                         ``duration_seconds``, during which further
                         compaction triggers are deferred — and its bytes
                         moved / downtime accrue into ``TraceStats``.

Time-averaged metrics follow the ROADMAP's scale axis: what matters online
is not one snapshot's GPU count but the integral of GPUs-used (energy /
cost) and wastage over the trace horizon — now alongside the paper's real
constraint, disruption-minutes spent migrating.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import itertools
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import get_telemetry
from .autoscaler import SLO, Autoscaler, ModelLoad
from .engine import PlacementEngine
from .faults import FAULT_KINDS, FaultEvent, FaultInjector
from .fleetgen import FleetSpec, build_fleet  # noqa: F401  (re-exported API)
from .migration import CommitPolicy
from .perfmodel import PerfModel
from .profiles import DeviceModel
from .state import ClusterState, Workload
from .traffic import RequestArrival, RequestShape, RequestTrace

__all__ = [
    "Event",
    "Trace",
    "FleetSpec",
    "build_fleet",
    "generate_trace",
    "TraceStats",
    "OnlineSimulator",
    "ModelServiceSpec",
    "DemandSimulator",
]

#: event kinds the fault-injection path dispatches on (incidents + repairs).
_FAULT_EVENT_KINDS = frozenset(FAULT_KINDS) | {"repair"}

#: FaultEvent.kind -> GPU health mark applied on impact.
_HEALTH_FOR = {
    "gpu_failure": "failed",
    "slice_failure": "degraded",
    "node_drain": "draining",
    "maintenance_window": "maintenance",
}

#: FaultEvent.kind -> TraceStats counter bumped on impact.
_FAULT_COUNTERS = {
    "gpu_failure": "n_gpu_failures",
    "slice_failure": "n_slice_failures",
    "node_drain": "n_node_drains",
    "maintenance_window": "n_maintenance_windows",
}


@dataclasses.dataclass
class _Incident:
    """One fault's eviction set, tracked until recovery completes."""

    t0: float
    remaining: set
    done_at: float = 0.0
    recorded: bool = False

#: default per-device profile pools for random arrivals (same spirit as
#: simulator._DEFAULT_PROFILE_POOL: skip the trivially-whole-device profile).
_ARRIVAL_POOLS: Dict[str, Tuple[int, ...]] = {
    "A100-80GB": (5, 9, 14, 15, 19),
    "H100-96GB": (5, 9, 14, 15, 19),
    "TPUv5e-16x16-pod": (1, 2, 3, 4),
}


def _pool_for(device: DeviceModel) -> Tuple[int, ...]:
    if device.name in _ARRIVAL_POOLS:
        return _ARRIVAL_POOLS[device.name]
    return tuple(
        p.profile_id for p in device.profiles_sorted_desc()[1:]
    ) or (device.profiles[0].profile_id,)


@dataclasses.dataclass(frozen=True)
class Event:
    """One timestamped trace event."""

    time: float
    kind: str  # "arrival" | "departure" | "compact"
    workloads: Tuple[Workload, ...] = ()  # arrivals; len > 1 == burst
    wids: Tuple[str, ...] = ()  # departures


@dataclasses.dataclass
class Trace:
    events: List[Event]
    horizon: float

    def __post_init__(self) -> None:
        self.events.sort(key=lambda e: (e.time, e.kind))

    @property
    def n_arrivals(self) -> int:
        return sum(len(e.workloads) for e in self.events if e.kind == "arrival")


def generate_trace(
    seed: int,
    fleet: ClusterState,
    horizon: float = 200.0,
    arrival_rate: float = 1.0,
    mean_lifetime: float = 40.0,
    burst_prob: float = 0.1,
    burst_size: Tuple[int, int] = (3, 6),
) -> Trace:
    """Seeded online trace over ``fleet``.

    Arrivals are Poisson(``arrival_rate``); each arrival is a single
    workload, or with ``burst_prob`` a burst of several (a model scaling out
    under load).  Lifetimes are exponential with ``mean_lifetime``; deaths
    past the horizon are dropped (the replica outlives the trace).  Each
    workload targets a device kind with probability proportional to that
    kind's share of fleet memory slices.
    """
    rng = np.random.default_rng(seed)
    kinds: Dict[str, DeviceModel] = {}
    weights: Dict[str, float] = {}
    for gpu in fleet.gpus.values():
        kinds[gpu.device.name] = gpu.device
        weights[gpu.device.name] = (
            weights.get(gpu.device.name, 0.0) + gpu.device.n_memory_slices
        )
    names = sorted(kinds)
    probs = np.array([weights[n] for n in names], dtype=float)
    probs /= probs.sum()

    events: List[Event] = []
    t = 0.0
    wi = 0
    while True:
        t += float(rng.exponential(1.0 / arrival_rate))
        if t >= horizon:
            break
        n = 1
        if float(rng.random()) < burst_prob:
            n = int(rng.integers(burst_size[0], burst_size[1] + 1))
        ws: List[Workload] = []
        for _ in range(n):
            kind = names[int(rng.choice(len(names), p=probs))]
            pool = _pool_for(kinds[kind])
            pid = int(pool[int(rng.choice(len(pool)))])
            w = Workload(wid=f"t{wi}", profile_id=pid, device_kind=kind)
            wi += 1
            ws.append(w)
            death = t + float(rng.exponential(mean_lifetime))
            if death < horizon:
                events.append(Event(time=death, kind="departure", wids=(w.wid,)))
        events.append(Event(time=t, kind="arrival", workloads=tuple(ws)))
    return Trace(events=events, horizon=horizon)


@dataclasses.dataclass
class TraceStats:
    """Time-averaged fleet metrics over one trace replay."""

    policy: str
    horizon: float
    time_avg_gpus_used: float
    time_avg_compute_waste: float
    time_avg_memory_waste: float
    time_avg_mem_occupancy: float  # used / total memory slices, whole fleet
    peak_gpus_used: int
    n_arrived: int = 0
    n_placed: int = 0
    n_rejected: int = 0
    n_departed: int = 0
    n_migrations: int = 0
    n_compactions: int = 0
    n_compactions_skipped: int = 0  # compaction plan rejected by CommitPolicy
    n_compactions_deferred: int = 0  # trigger fell inside a migration window
    n_reconfigures: int = 0
    n_reconfigures_deferred: int = 0
    n_plans_rejected: int = 0  # all rejected plans (compact + reconfigure)
    #: rejected plans by the CommitPolicy's deciding term (e.g.
    #: ``net-benefit``, ``moves``, ``downtime``) — the structured "why"
    #: behind ``n_plans_rejected``.
    plan_rejections: Dict[str, int] = dataclasses.field(default_factory=dict)
    #: most recent rejection's human-readable reason ("" if none).
    last_rejection_reason: str = ""
    bytes_moved: float = 0.0
    disruption_seconds: float = 0.0  # summed per-replica unavailability
    migration_window_seconds: float = 0.0  # wall-clock spent migrating
    engine_seconds: float = 0.0
    # -- demand-driven accounting (DemandSimulator only) --------------------
    n_requests: int = 0
    n_completed: int = 0
    n_unserved: int = 0  # still queued when the simulation ended
    n_autoscale_ticks: int = 0
    n_scale_ups: int = 0  # replicas added by the autoscaler
    n_scale_downs: int = 0  # replicas retired by the autoscaler
    n_resizes: int = 0  # replicas re-deployed at a different profile
    n_deploy_rejected: int = 0  # scale-up replicas the engine could not place
    time_avg_queue_depth: float = 0.0
    peak_queue_depth: int = 0
    ttft_p50: float = 0.0
    ttft_p95: float = 0.0
    ttft_p99: float = 0.0
    tpot_p50: float = 0.0
    tpot_p95: float = 0.0
    tpot_p99: float = 0.0
    #: fraction of ALL arrived requests meeting their model's SLO (a request
    #: never served counts as a miss — undersized fleets can't hide).
    slo_attainment: float = 1.0
    slo_attainment_by_model: Dict[str, float] = dataclasses.field(
        default_factory=dict
    )
    # -- fault injection & recovery (faults= on either simulator) -----------
    n_gpu_failures: int = 0
    n_slice_failures: int = 0
    n_node_drains: int = 0
    n_maintenance_windows: int = 0
    n_repairs: int = 0
    n_fault_noops: int = 0  # fault/repair aimed at an already-down/up target
    n_fault_evictions: int = 0  # replicas evicted by faults
    n_fault_recovered: int = 0  # evicted replicas re-placed by the engine
    n_recovery_pending: int = 0  # still waiting for capacity at horizon
    n_ghost_departures: int = 0  # departures of already-evicted workloads
    n_emergency_commits: int = 0  # escalated verbs committed during recovery
    recovery_seconds_total: float = 0.0  # summed time-to-full-recovery
    recovery_seconds_max: float = 0.0  # slowest incident's recovery time
    capacity_lost_gpu_seconds: float = 0.0  # integral of down GPU-equivalents
    # -- demand-layer fault damage (DemandSimulator only) --------------------
    n_requeued_requests: int = 0  # in-flight requests requeued by evictions
    n_shed_requests: int = 0  # best-effort arrivals shed during brownout
    brownout_seconds: float = 0.0  # wall-clock with recovery pending

    @property
    def disruption_minutes(self) -> float:
        return self.disruption_seconds / 60.0

    def as_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d["disruption_minutes"] = self.disruption_minutes
        return d


class OnlineSimulator:
    """Replays a trace through a PlacementEngine over a live ClusterState."""

    def __init__(
        self,
        state: ClusterState,
        engine: PlacementEngine,
        compact_every: Optional[float] = None,
        migration_budget: Optional[int] = None,
        reconfigure_every: Optional[float] = None,
        faults: Optional[FaultInjector] = None,
    ):
        self.state = state
        self.engine = engine
        self.compact_every = compact_every
        #: seeded fault injector (None = no faults; the clean path draws no
        #: extra RNG samples and replays byte-identically to pre-fault code).
        self.faults = faults
        self._recovery_queue: List[Workload] = []
        self._fault_evicted: set = set()
        self._incidents: List[_Incident] = []
        #: integral bookkeeping for capacity_lost_gpu_seconds.
        self._lost_units = 0.0  # GPU-equivalents currently down
        self._lost_mark = 0.0  # last accrual time (clamped to horizon)
        self._unit_frac: Dict[str, float] = {}  # gid -> its lost fraction
        self._horizon = 0.0
        #: periodic maintenance repack (paper Sec 2.3.3) — the expensive
        #: verb the CommitPolicy exists to keep honest online.
        self.reconfigure_every = reconfigure_every
        #: max migrations allowed per compaction (legacy knob) — folded into
        #: a simulator-local CommitPolicy override (applied only around this
        #: simulator's verb calls, never mutating the shared engine), so an
        #: over-budget plan is a transactional rollback, not clone-and-restore.
        self.migration_budget = migration_budget
        self._commit_override: Optional[CommitPolicy] = None
        if migration_budget is not None:
            cp = engine.commit_policy
            if cp.mode == "always":
                cp = CommitPolicy(
                    mode="budgeted",
                    move_budget=migration_budget,
                    downtime_budget_seconds=None,
                )
            else:
                cp = dataclasses.replace(cp, move_budget=migration_budget)
            self._commit_override = cp
        #: end of the currently-open migration window (simulated clock).
        self._busy_until = 0.0
        #: cached (registry, gauges) for the per-event fleet-health gauges
        #: — registry lookups are label-canonicalizing dict probes, too
        #: slow for the hot event loop.
        self._gauge_cache: Optional[tuple] = None

    # -- metric integration over time --------------------------------------
    def _sample(self) -> Tuple[int, int, int, float]:
        used = self.state.used_gpus()
        cmp_waste = sum(g.compute_waste() for g in used)
        mem_waste = sum(g.memory_waste() for g in used)
        total_mem = sum(g.device.n_memory_slices for g in self.state.gpus.values())
        used_mem = sum(g.used_memory_slices() for g in used)
        return len(used), cmp_waste, mem_waste, used_mem / max(total_mem, 1)

    def _events_with_compactions(self, trace: Trace):
        """Merge the trace with periodic compact/reconfigure triggers."""
        periodic = [
            (period, kind)
            for period, kind in (
                (self.compact_every, "compact"),
                (self.reconfigure_every, "reconfigure"),
            )
            if period
        ]
        if not periodic:
            yield from trace.events
            return
        pending = sorted((period, period, kind) for period, kind in periodic)

        def _due(until: float):
            while pending and pending[0][0] <= until:
                t, period, kind = pending.pop(0)
                yield Event(time=t, kind=kind)
                nxt = (t + period, period, kind)
                lo = 0
                while lo < len(pending) and pending[lo][0] <= nxt[0]:
                    lo += 1
                pending.insert(lo, nxt)

        for ev in trace.events:
            yield from _due(ev.time)
            yield ev
        while pending and pending[0][0] < trace.horizon:
            yield from _due(pending[0][0])

    def run(self, trace: Trace) -> TraceStats:
        stats = TraceStats(
            policy=self.engine.policy_name,
            horizon=trace.horizon,
            time_avg_gpus_used=0.0,
            time_avg_compute_waste=0.0,
            time_avg_memory_waste=0.0,
            time_avg_mem_occupancy=0.0,
            peak_gpus_used=0,
        )
        acc = np.zeros(4)  # integrals of the _sample() tuple
        t_prev = 0.0
        tel = get_telemetry()
        last_t = 0.0  # when the fleet last changed (gauge timestamps)
        self._horizon = trace.horizon
        events = self._events_with_compactions(trace)
        if self.faults is not None:
            events = heapq.merge(
                events,
                self.faults.schedule(self.state, trace.horizon),
                key=lambda e: e.time,
            )
        for ev in events:
            sample = self._sample()
            if tel.enabled:
                # The pre-event sample describes the fleet since the LAST
                # event — record it there, reusing the scan the
                # time-averaged stats already paid for.
                self._record_sample_gauges(tel, last_t, sample)
            last_t = ev.time
            # Integration is clamped to [0, horizon]: an event past the
            # horizon still mutates state (the replica really departs) but
            # contributes no weight, so the final partial interval is counted
            # exactly once for every time-averaged counter.
            t_now = min(ev.time, trace.horizon)
            if t_now > t_prev:
                acc += np.array(sample) * (t_now - t_prev)
                t_prev = t_now
            stats.peak_gpus_used = max(stats.peak_gpus_used, sample[0])
            if ev.kind == "arrival":
                self._handle_arrival(ev, stats)
            elif ev.kind == "departure":
                self._handle_departure(ev, stats)
            elif ev.kind in ("compact", "reconfigure"):
                self._handle_plan_verb(ev.kind, stats, ev.time)
            elif ev.kind in _FAULT_EVENT_KINDS:
                self._handle_fault(ev, stats, ev.time)
            else:  # pragma: no cover
                raise ValueError(f"unknown event kind {ev.kind!r}")
        if self.faults is not None:
            self._finalize_faults(stats, trace.horizon)
        sample = self._sample()
        if tel.enabled:
            self._record_sample_gauges(tel, trace.horizon, sample)
        acc += np.array(sample) * max(trace.horizon - t_prev, 0.0)
        stats.peak_gpus_used = max(stats.peak_gpus_used, sample[0])
        h = max(trace.horizon, 1e-9)
        (
            stats.time_avg_gpus_used,
            stats.time_avg_compute_waste,
            stats.time_avg_memory_waste,
            stats.time_avg_mem_occupancy,
        ) = (acc / h).tolist()
        return stats

    def _handle_arrival(self, ev: Event, stats: TraceStats) -> None:
        stats.n_arrived += len(ev.workloads)
        batch = list(ev.workloads)
        if self.faults is not None and batch:
            # A whole device kind can be down mid-incident; arrivals routed
            # to it are rejections, not routing errors.
            kinds = {
                g.device.name for g in self.state.gpus.values() if g.schedulable
            }
            routable = [
                w for w in batch if not w.device_kind or w.device_kind in kinds
            ]
            stats.n_rejected += len(batch) - len(routable)
            batch = routable
            if not batch:
                return
        res = self.engine.deploy(self.state, batch)
        stats.engine_seconds += res.seconds
        rejected = {w.wid for w in res.pending}
        stats.n_rejected += len(rejected)
        stats.n_placed += len(batch) - len(rejected)
        # Rejected replicas leave the system (no admission queue — the online
        # analogue of the paper's "pending" metric).
        for wid in rejected:
            self.state.workloads.pop(wid, None)

    def _handle_departure(self, ev: Event, stats: TraceStats) -> None:
        for wid in ev.wids:
            if wid in self._fault_evicted:
                # Ghost departure: a fault already evicted this workload.
                # Its lifetime ends here either way — stop trying to recover
                # it, bump the counter, and touch no occupancy caches.
                self._ghost_departure(wid, stats)
                continue
            gid = self.state.gpu_of(wid)
            if gid is not None:
                self.state.gpus[gid].remove(wid)
                stats.n_departed += 1
                self._fleet_changed()
            self.state.workloads.pop(wid, None)
        if self._recovery_queue:
            # Departures free capacity: retry pending recoveries.
            self._recover(ev.time, stats)

    def _handle_plan_verb(self, verb: str, stats: TraceStats, now: float) -> None:
        if verb not in self.engine.policy.supports:
            return
        tel = get_telemetry()
        if now < self._busy_until:
            # A previous plan's waves/drains still occupy the fleet.
            if verb == "compact":
                stats.n_compactions_deferred += 1
            else:
                stats.n_reconfigures_deferred += 1
            tel.tracer.event("verb_deferred", time=now, verb=verb,
                             busy_until=self._busy_until)
            return
        saved = self.engine.commit_policy
        if self._commit_override is not None:
            self.engine.commit_policy = self._commit_override
        try:
            res = getattr(self.engine, verb)(self.state)
        finally:
            self.engine.commit_policy = saved
        stats.engine_seconds += res.seconds
        if not res.committed:
            # Plan rejected by the CommitPolicy -> transactional rollback
            # already restored the layout; nothing moved.
            if verb == "compact":
                stats.n_compactions_skipped += 1
            stats.n_plans_rejected += 1
            term = res.decision.term or "unknown"
            stats.plan_rejections[term] = stats.plan_rejections.get(term, 0) + 1
            stats.last_rejection_reason = res.decision.reason
            tel.tracer.event("plan_rejected", time=now, verb=verb, term=term,
                             reason=res.decision.reason,
                             shortfall=res.decision.shortfall)
            return
        if verb == "compact":
            stats.n_compactions += 1
        else:
            stats.n_reconfigures += 1
        # Baseline reconfigure replays may fail to re-place a workload
        # (measured Sec-5.2.3 behavior): it leaves the system, like a
        # rejected arrival.
        for w in res.pending:
            self.state.workloads.pop(w.wid, None)
            stats.n_rejected += 1
        stats.n_migrations += res.plan.n_migrations if res.plan else 0
        if res.cost is not None and res.cost.n_moves:
            stats.bytes_moved += res.cost.total_bytes
            stats.disruption_seconds += res.cost.downtime_seconds
            stats.migration_window_seconds += res.cost.duration_seconds
            self._busy_until = now + res.cost.duration_seconds
            if tel.enabled:
                tel.tracer.event(
                    "migration_window", time=now,
                    duration=res.cost.duration_seconds, verb=verb,
                    n_moves=res.plan.n_migrations if res.plan else 0,
                    total_bytes=res.cost.total_bytes,
                    downtime_seconds=res.cost.downtime_seconds,
                )
                tel.metrics.counter(
                    "bytes_moved_total", "bytes moved by committed plans",
                ).inc(float(res.cost.total_bytes), t=now)
        if tel.enabled:
            self._record_fleet_gauges(tel, now)
        if self._recovery_queue:
            # A committed repack may have made room: retry pending recoveries.
            self._recover(now, stats)

    # -- fault injection & recovery -----------------------------------------
    def _fleet_changed(self) -> None:
        """Placement-mutation hook (DemandSimulator dirties its cache)."""

    def _handle_fault(self, ev: FaultEvent, stats: TraceStats, now: float) -> None:
        tel = get_telemetry()
        gpu = self.state.gpus.get(ev.gid)
        if gpu is None:
            stats.n_fault_noops += 1
            return
        if ev.kind == "repair":
            if gpu.health == "healthy":
                stats.n_fault_noops += 1  # duplicate/stale repair
                return
            self._accrue_lost(stats, now)
            self._lost_units -= self._unit_frac.pop(ev.gid, 0.0)
            self.state.set_health(ev.gid, "healthy")
            stats.n_repairs += 1
            tel.tracer.event("repair", time=now, gid=ev.gid, spec=ev.spec)
            self._recover(now, stats)
            self._update_brownout(now, stats)
            return
        if gpu.health != "healthy":
            # Overlapping fault on an already-down target: no-op with a
            # counter bump (its capacity loss is already accounted).
            stats.n_fault_noops += 1
            return
        self._accrue_lost(stats, now)
        victims = list(gpu.placements)
        frac = 1.0
        if ev.kind == "slice_failure":
            # Only the placement covering the dead memory position dies; the
            # GPU is quarantined (degraded) but survivors keep serving.
            occ = gpu.memory_occupancy()
            idx = ev.index % gpu.device.n_memory_slices
            dead_wid = occ[idx]
            victims = [pl for pl in victims if pl.wid == dead_wid]
            frac = 1.0 / gpu.device.n_memory_slices
        self._unit_frac[ev.gid] = frac
        self._lost_units += frac
        self.state.set_health(ev.gid, _HEALTH_FOR[ev.kind])
        counter = _FAULT_COUNTERS[ev.kind]
        setattr(stats, counter, getattr(stats, counter) + 1)
        tel.tracer.event("fault", time=now, kind=ev.kind, gid=ev.gid,
                         n_evicted=len(victims), spec=ev.spec)
        if tel.enabled:
            tel.metrics.counter(
                "failures_total", "injected fault events by kind",
                labels={"kind": ev.kind},
            ).inc(t=now)
        evicted: List[Workload] = []
        for pl in victims:
            w = self.state.workloads.get(pl.wid)
            self.state.remove(pl.wid, ev.gid)
            self.state.forget_workload(pl.wid)
            if w is not None:
                evicted.append(w)
        self._fleet_changed()
        if evicted:
            stats.n_fault_evictions += len(evicted)
            self._fault_evicted.update(w.wid for w in evicted)
            self._incidents.append(
                _Incident(t0=now, remaining={w.wid for w in evicted})
            )
            self._on_fault_evicted(evicted, now, stats)
            self._recovery_queue.extend(evicted)
        self._recover(now, stats)
        self._update_brownout(now, stats)

    def _recover(self, now: float, stats: TraceStats) -> None:
        """Re-place evicted replicas through the engine (CommitPolicy-gated
        deploy; escalated emergency verbs if the free space cannot host them)."""
        if not self._recovery_queue:
            return
        healthy_kinds = {
            g.device.name for g in self.state.gpus.values() if g.schedulable
        }
        if not healthy_kinds:
            return  # nothing to place on; retried at the next repair
        batch = [
            w for w in self._recovery_queue
            if not w.device_kind or w.device_kind in healthy_kinds
        ]
        if not batch:
            return
        tel = get_telemetry()
        with tel.tracer.span("recover") as sp:
            res = self.engine.deploy(self.state, batch)
            stats.engine_seconds += res.seconds
            pending = {w.wid for w in res.pending}
            for wid in pending:
                self.state.workloads.pop(wid, None)  # stays queued, unregistered
            if pending:
                pending = self._escalate_recovery(batch, pending, now, stats)
            placed = [w for w in batch if w.wid not in pending]
            placed_wids = {w.wid for w in placed}
            self._recovery_queue = [
                w for w in self._recovery_queue if w.wid not in placed_wids
            ]
            self._fleet_changed()
            ready = self._on_recovered(placed, now, stats)
            for w in placed:
                self._complete_recovery(w.wid, ready.get(w.wid, now), stats)
            if tel.enabled:
                sp.set(sim_time=now, n_placed=len(placed),
                       n_pending=len(pending))
        self._update_brownout(now, stats)

    def _escalate_recovery(
        self, batch: List[Workload], pending: set, now: float, stats: TraceStats
    ) -> set:
        """Free space can't host the evicted replicas: swap in the commit
        policy's emergency tier, make room with compact/reconfigure, retry."""
        esc = self.engine.commit_policy.escalate()
        if esc is None:
            return pending  # emergency tier disabled ("gated")
        tel = get_telemetry()
        saved = self.engine.commit_policy
        self.engine.commit_policy = esc
        try:
            for verb in ("compact", "reconfigure"):
                if not pending:
                    break
                if verb not in self.engine.policy.supports:
                    continue
                res = getattr(self.engine, verb)(self.state)
                stats.engine_seconds += res.seconds
                if not res.committed:
                    continue
                stats.n_emergency_commits += 1
                tel.tracer.event("emergency_commit", time=now, verb=verb)
                # Emergency repacks pay real disruption: account it exactly
                # like a committed periodic plan verb.
                for w in res.pending:
                    self.state.workloads.pop(w.wid, None)
                    stats.n_rejected += 1
                stats.n_migrations += res.plan.n_migrations if res.plan else 0
                if res.cost is not None and res.cost.n_moves:
                    stats.bytes_moved += res.cost.total_bytes
                    stats.disruption_seconds += res.cost.downtime_seconds
                    stats.migration_window_seconds += res.cost.duration_seconds
                    self._busy_until = max(
                        self._busy_until, now + res.cost.duration_seconds
                    )
                self._sweep_ghosts(now, stats)
                retry = [w for w in batch if w.wid in pending]
                r2 = self.engine.deploy(self.state, retry)
                stats.engine_seconds += r2.seconds
                pending = {w.wid for w in r2.pending}
                for wid in pending:
                    self.state.workloads.pop(wid, None)
        finally:
            self.engine.commit_policy = saved
        return pending

    def _complete_recovery(self, wid: str, at: float, stats: TraceStats) -> None:
        """Mark one evicted replica re-placed; close its incident when the
        last one lands (recovery-time-to-full-capacity accounting)."""
        self._fault_evicted.discard(wid)
        stats.n_fault_recovered += 1
        for inc in self._incidents:
            if wid in inc.remaining:
                inc.remaining.discard(wid)
                inc.done_at = max(inc.done_at, at)
                if not inc.remaining and not inc.recorded:
                    inc.recorded = True
                    dt = max(inc.done_at - inc.t0, 0.0)
                    stats.recovery_seconds_total += dt
                    stats.recovery_seconds_max = max(
                        stats.recovery_seconds_max, dt
                    )
                    tel = get_telemetry()
                    if tel.enabled:
                        tel.metrics.histogram(
                            "recovery_seconds",
                            "fault to full re-placement of its evictions",
                        ).observe(dt)
                        tel.tracer.event("recovered", time=at, t0=inc.t0,
                                         seconds=dt)
                break

    def _ghost_departure(self, wid: str, stats: TraceStats) -> None:
        stats.n_ghost_departures += 1
        self._fault_evicted.discard(wid)
        self._recovery_queue = [
            w for w in self._recovery_queue if w.wid != wid
        ]
        for inc in self._incidents:
            # The workload's lifetime ended before recovery: it no longer
            # holds its incident open (no recovery time is recorded for
            # incidents fully resolved by departures).
            inc.remaining.discard(wid)

    def _on_fault_evicted(
        self, evicted: List[Workload], now: float, stats: TraceStats
    ) -> None:
        """Hook: demand layer requeues the evictions' in-flight requests."""

    def _on_recovered(
        self, placed: List[Workload], now: float, stats: TraceStats
    ) -> Dict[str, float]:
        """Hook: demand layer re-creates replicas; returns wid -> ready-at
        (cold-restore delay).  Base: placements serve immediately."""
        return {}

    def _sweep_ghosts(self, now: float, stats: TraceStats) -> None:
        """Hook: demand layer drops replicas evicted by emergency verbs."""

    def _update_brownout(self, now: float, stats: TraceStats) -> None:
        """Hook: demand layer accrues brownout (recovery-pending) time."""

    def _accrue_lost(self, stats: TraceStats, now: float) -> None:
        t = min(now, self._horizon)
        if t > self._lost_mark:
            stats.capacity_lost_gpu_seconds += (
                self._lost_units * (t - self._lost_mark)
            )
            self._lost_mark = t

    def _finalize_faults(self, stats: TraceStats, horizon: float) -> None:
        self._accrue_lost(stats, horizon)
        stats.n_recovery_pending = len(self._fault_evicted)

    def _record_sample_gauges(self, tel, t: float, sample) -> None:
        """Fleet-health time series on the simulated clock, fed from the
        run loop's own per-event :meth:`_sample` — telemetry piggybacks on
        the scan the time-averaged stats already pay for (zero extra
        fleet scans when enabled)."""
        m = tel.metrics
        if self._gauge_cache is None or self._gauge_cache[0] is not m:
            self._gauge_cache = (m, (
                m.gauge("gpus_used", "GPUs hosting at least one workload"),
                m.gauge("compute_waste_slices",
                        "blocked-but-unusable compute slices"),
                m.gauge("memory_waste_slices", "wasted memory slices"),
                m.gauge("mem_occupancy", "used / total fleet memory slices"),
            ))
        g_used, g_cw, g_mw, g_occ = self._gauge_cache[1]
        used, cmp_waste, mem_waste, occupancy = sample
        g_used.set(used, t=t)
        g_cw.set(cmp_waste, t=t)
        g_mw.set(mem_waste, t=t)
        g_occ.set(occupancy, t=t)

    def _record_fleet_gauges(self, tel, now: float) -> None:
        """Gauges that need their own fleet scan (fragmentation) — recorded
        only after the rare plan verbs, not on every arrival/departure."""
        used = self.state.used_gpus()
        tel.metrics.gauge(
            "fragmentation", "mean free-slice fragmentation (Ting et al.)"
        ).set(
            sum(g.fragmentation() for g in used) / len(used) if used else 0.0,
            t=now,
        )


# ---------------------------------------------------------------------------
# demand-driven simulation: requests -> queues -> autoscaler -> engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelServiceSpec:
    """How one served model's replicas are sized and judged online."""

    model: str
    profile_id: int  # default replica partition profile
    device_kind: str = ""  # routing on mixed fleets (Workload.device_kind)
    #: optional right-sizing candidates (profile ids, any order).  When set,
    #: scale-ups pick the smallest profile whose capacity covers the
    #: per-replica load, and steady-state ticks may *resize* (make-before-
    #: break redeploy) one mismatched replica — MISO-style dynamic slicing.
    profile_ladder: Tuple[int, ...] = ()
    #: replicas deployed at t=0 (static baselines set this and no autoscaler).
    initial_replicas: int = 0
    slo: SLO = SLO()
    #: best-effort tier: shed this model's arrivals first (brownout) while
    #: post-failure capacity cannot host the evicted replicas.
    best_effort: bool = False


@dataclasses.dataclass
class _Replica:
    """Runtime state of one autoscaler-managed replica (single-server FIFO)."""

    wid: str
    model: str
    profile_id: int
    device: DeviceModel
    current: Optional[RequestArrival] = None
    busy_until: float = 0.0
    draining: bool = False  # no new requests; removed at next completion


#: sentinel occupying ``_Replica.current`` while a fault-recovered replica
#: cold-restores (weights transfer + resume); cleared by its "warmup" event.
_RESTORING = object()


class DemandSimulator(OnlineSimulator):
    """Closes the loop from request traffic to placement.

    Replays a ``RequestTrace`` as a discrete-event simulation: requests
    queue per model, live replicas serve them (service times from the
    ``PerfModel`` for each replica's actual partition profile), and every
    ``autoscale_every`` seconds the ``Autoscaler`` turns the observed
    offered load / queue depths / SLO attainment into replica targets that
    are applied through the ``PlacementEngine`` — deploys admit, retires
    drain, and any periodic compact/reconfigure still rides the engine's
    plan/score/commit control plane (``CommitPolicy`` gates migrations).

    Each replica serves one request at a time (a G/G/c queue per model);
    TTFT is queue wait + prefill, TPOT the profile's decode pace.  After the
    horizon no new requests arrive and no control ticks fire, but in-flight
    queues drain to completion so every served request is accounted;
    time-averaged metrics integrate over ``[0, horizon]`` only.
    """

    def __init__(
        self,
        state: ClusterState,
        engine: PlacementEngine,
        specs: Sequence[ModelServiceSpec],
        autoscaler: Optional[Autoscaler] = None,
        perf: Optional[PerfModel] = None,
        autoscale_every: float = 5.0,
        compact_every: Optional[float] = None,
        reconfigure_every: Optional[float] = None,
        migration_budget: Optional[int] = None,
        faults: Optional[FaultInjector] = None,
    ):
        super().__init__(
            state,
            engine,
            compact_every=compact_every,
            migration_budget=migration_budget,
            reconfigure_every=reconfigure_every,
            faults=faults,
        )
        #: brownout engages while fault recovery is pending (see
        #: ``_update_brownout``): best-effort models' arrivals are shed.
        self._brownout_since: Optional[float] = None
        self.specs: Dict[str, ModelServiceSpec] = {s.model: s for s in specs}
        self.autoscaler = autoscaler
        self.perf = perf or PerfModel()
        self.autoscale_every = autoscale_every
        self._wid_counter = itertools.count()
        self._reps: Dict[str, Dict[str, _Replica]] = {
            m: {} for m in self.specs
        }
        self._queues: Dict[str, Deque[RequestArrival]] = {
            m: collections.deque() for m in self.specs
        }
        #: per-model counters over the current control window.
        self._win: Dict[str, Dict[str, float]] = {
            m: self._fresh_window() for m in self.specs
        }
        #: running request shapes (capacity estimation; defaults until seen).
        self._shapes: Dict[str, RequestShape] = {
            m: RequestShape() for m in self.specs
        }
        self._arrived: Dict[str, int] = {m: 0 for m in self.specs}
        self._hits: Dict[str, int] = {m: 0 for m in self.specs}
        self._ttfts: List[float] = []
        self._tpots: List[float] = []
        self._last_tick = 0.0
        #: live event heap + tie-break counter (bound for real in run()).
        self._heap: List[Tuple[float, int, str, object]] = []
        self._seq = itertools.count()
        #: fleet metrics only change on placement mutations; request/complete
        #: events reuse the cached sample (O(1) vs O(fleet) per event).
        self._fleet_dirty = True
        self._fleet_cache: Tuple[int, int, int, float] = (0, 0, 0, 0.0)

    def _fleet_sample(self) -> Tuple[int, int, int, float]:
        if self._fleet_dirty:
            self._fleet_cache = self._sample()
            self._fleet_dirty = False
        return self._fleet_cache

    @staticmethod
    def _fresh_window() -> Dict[str, float]:
        return {"arrived": 0, "completed": 0, "hits": 0}

    # -- helpers ------------------------------------------------------------
    def _device_for(self, kind: str) -> DeviceModel:
        for gpu in self.state.gpus.values():
            if not kind or gpu.device.name == kind:
                return gpu.device
        raise ValueError(f"no device of kind {kind!r} in the fleet")

    def _mean_lens(self, model: str) -> Tuple[int, int]:
        return self._shapes[model].means()

    def _total_queue_depth(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def _live_replicas(self, model: str) -> List[_Replica]:
        return [r for r in self._reps[model].values() if not r.draining]

    def _target_utilization(self) -> float:
        if self.autoscaler is not None:
            return self.autoscaler.config.target_utilization
        return 0.7

    def _choose_profile(
        self, spec: ModelServiceSpec, offered_rps: float, target: int
    ) -> int:
        """Right-size: smallest ladder profile covering per-replica load."""
        if not spec.profile_ladder:
            return spec.profile_id
        device = self._device_for(spec.device_kind)
        mean_p, mean_d = self._mean_lens(spec.model)
        per_rep = offered_rps / max(target, 1)
        rho = self._target_utilization()
        ladder = sorted(
            spec.profile_ladder,
            key=lambda pid: self.perf.capacity_rps(device, pid, mean_p, mean_d),
        )
        for pid in ladder:
            if self.perf.capacity_rps(device, pid, mean_p, mean_d) * rho >= per_rep:
                return pid
        return ladder[-1]  # even the biggest slice is short: take it

    # -- replica lifecycle --------------------------------------------------
    def _deploy_replicas(
        self, model: str, n: int, profile_id: int, stats: TraceStats
    ) -> List[_Replica]:
        spec = self.specs[model]
        news = [
            Workload(
                wid=f"{model}#a{next(self._wid_counter)}",
                profile_id=profile_id,
                model=model,
                device_kind=spec.device_kind,
            )
            for _ in range(n)
        ]
        res = self.engine.deploy(self.state, news)
        self._fleet_dirty = True
        stats.engine_seconds += res.seconds
        rejected = {w.wid for w in res.pending}
        stats.n_deploy_rejected += len(rejected)
        for wid in rejected:
            self.state.workloads.pop(wid, None)
        placed: List[_Replica] = []
        for w in news:
            if w.wid in rejected:
                continue
            gid = self.state.gpu_of(w.wid)
            rep = _Replica(
                wid=w.wid,
                model=model,
                profile_id=profile_id,
                device=self.state.gpus[gid].device,
            )
            self._reps[model][w.wid] = rep
            placed.append(rep)
        return placed

    def _remove_replica(self, rep: _Replica) -> None:
        self._fleet_dirty = True
        gid = self.state.gpu_of(rep.wid)
        if gid is not None:
            self.state.remove(rep.wid, gid)
        self.state.workloads.pop(rep.wid, None)
        self._reps[rep.model].pop(rep.wid, None)

    def _retire_replicas(self, model: str, n: int, stats: TraceStats) -> None:
        """Idle replicas go now; busy ones drain (removed at completion)."""
        victims = sorted(
            self._live_replicas(model),
            key=lambda r: (r.current is not None, r.wid),
        )[:n]
        for rep in victims:
            stats.n_scale_downs += 1
            if rep.current is None:
                self._remove_replica(rep)
            else:
                rep.draining = True

    # -- request flow -------------------------------------------------------
    def _dispatch(self, model: str, now: float, heap, seq) -> None:
        q = self._queues[model]
        if not q:
            return
        free = sorted(
            (r for r in self._reps[model].values()
             if r.current is None and not r.draining),
            key=lambda r: r.wid,
        )
        for rep in free:
            if not q:
                break
            req = q.popleft()
            prefill_s, decode_s = self.perf.service_seconds(
                rep.device, rep.profile_id, req.prompt_len, req.decode_len
            )
            ttft = (now - req.time) + prefill_s
            tpot = self.perf.tpot_seconds(rep.device, rep.profile_id)
            rep.current = req
            rep.busy_until = now + prefill_s + decode_s
            heapq.heappush(
                heap,
                (rep.busy_until, next(seq), "complete",
                 (rep.wid, model, req, ttft, tpot)),
            )

    def _handle_request(self, req: RequestArrival, now: float,
                        stats: TraceStats, heap, seq) -> None:
        stats.n_requests += 1
        self._arrived[req.model] += 1
        self._shapes[req.model].add(req.prompt_len, req.decode_len)
        self._win[req.model]["arrived"] += 1
        if self._brownout_since is not None and self.specs[req.model].best_effort:
            # Brownout: post-failure capacity can't host the evicted
            # replicas yet — shed best-effort arrivals (they count as
            # arrived-and-missed, so SLO attainment takes the damage).
            stats.n_shed_requests += 1
            return
        self._queues[req.model].append(req)
        self._dispatch(req.model, now, heap, seq)

    def _handle_complete(self, payload, now: float, stats: TraceStats,
                         heap, seq) -> None:
        wid, model, req, ttft, tpot = payload
        rep = self._reps[model].get(wid)
        if rep is None or rep.current is not req:
            return  # stale: the replica was evicted and the request requeued
        rep.current = None
        stats.n_completed += 1
        self._ttfts.append(ttft)
        self._tpots.append(tpot)
        slo = self.specs[model].slo
        hit = ttft <= slo.ttft_seconds and tpot <= slo.tpot_seconds
        self._win[model]["completed"] += 1
        self._win[model]["hits"] += hit
        self._hits[model] += hit
        if rep.draining:
            self._remove_replica(rep)
        else:
            self._dispatch(model, now, heap, seq)

    # -- control tick -------------------------------------------------------
    def _observations(self, interval: float) -> List[ModelLoad]:
        obs: List[ModelLoad] = []
        for model in sorted(self.specs):
            spec = self.specs[model]
            win = self._win[model]
            mean_p, mean_d = self._mean_lens(model)
            live = self._live_replicas(model)
            if live:
                cap = float(np.mean([
                    self.perf.capacity_rps(r.device, r.profile_id, mean_p, mean_d)
                    for r in live
                ]))
            else:
                cap = self.perf.capacity_rps(
                    self._device_for(spec.device_kind), spec.profile_id,
                    mean_p, mean_d,
                )
            if win["completed"]:
                att = win["hits"] / win["completed"]
            else:
                # Nothing finished this window: healthy if nothing waits.
                att = 1.0 if not self._queues[model] else 0.0
            obs.append(ModelLoad(
                model=model,
                offered_rps=win["arrived"] / max(interval, 1e-9),
                capacity_rps=cap,
                replicas=len(live),
                queue_depth=len(self._queues[model]),
                slo_attainment=att,
                slo=spec.slo,
            ))
        return obs

    def _maybe_resize(self, model: str, obs: ModelLoad, now: float,
                      stats: TraceStats, heap, seq) -> None:
        """Make-before-break conversion of ONE mismatched replica per tick."""
        spec = self.specs[model]
        if not spec.profile_ladder or self.autoscaler is None:
            return
        live = self._live_replicas(model)
        if not live:
            return
        want = self._choose_profile(spec, obs.offered_rps, len(live))
        victim = next(
            (r for r in sorted(live, key=lambda r: r.wid)
             if r.profile_id != want and r.current is None),
            None,
        )
        if victim is None:
            return
        if not self._deploy_replicas(model, 1, want, stats):
            return  # replacement did not fit: keep the old slice
        self._remove_replica(victim)
        stats.n_resizes += 1
        self._dispatch(model, now, heap, seq)

    def _autoscale_tick(self, now: float, stats: TraceStats, heap, seq) -> None:
        stats.n_autoscale_ticks += 1
        interval = now - self._last_tick
        self._last_tick = now
        tel = get_telemetry()
        with tel.tracer.span("autoscale_tick") as sp:
            obs_list = self._observations(interval)
            if tel.enabled:
                for obs in obs_list:
                    lbl = {"model": obs.model}
                    tel.metrics.gauge(
                        "queue_depth", "requests waiting per model",
                        labels=lbl,
                    ).set(obs.queue_depth, t=now)
                    tel.metrics.gauge(
                        "slo_attainment", "window SLO attainment per model",
                        labels=lbl,
                    ).set(obs.slo_attainment, t=now)
                    tel.metrics.gauge(
                        "offered_rps", "offered load per model", labels=lbl,
                    ).set(obs.offered_rps, t=now)
                    tel.metrics.gauge(
                        "replicas", "live replicas per model", labels=lbl,
                    ).set(obs.replicas, t=now)
            n_ups = n_downs = 0
            if self.autoscaler is not None:
                for dec, obs in zip(self.autoscaler.tick(now, obs_list), obs_list):
                    spec = self.specs[dec.model]
                    if dec.delta > 0:
                        pid = self._choose_profile(spec, obs.offered_rps, dec.target)
                        placed = self._deploy_replicas(
                            dec.model, dec.delta, pid, stats
                        )
                        stats.n_scale_ups += len(placed)
                        n_ups += len(placed)
                        tel.tracer.event(
                            "autoscale_up", time=now, model=dec.model,
                            delta=dec.delta, placed=len(placed),
                            target=dec.target, profile_id=pid,
                        )
                        self._dispatch(dec.model, now, heap, seq)
                    elif dec.delta < 0:
                        self._retire_replicas(dec.model, -dec.delta, stats)
                        n_downs += -dec.delta
                        tel.tracer.event(
                            "autoscale_down", time=now, model=dec.model,
                            delta=dec.delta, target=dec.target,
                        )
                    else:
                        before_resizes = stats.n_resizes
                        self._maybe_resize(dec.model, obs, now, stats, heap, seq)
                        if stats.n_resizes > before_resizes:
                            tel.tracer.event(
                                "autoscale_resize", time=now, model=dec.model,
                            )
            if tel.enabled:
                sp.set(sim_time=now, n_scale_ups=n_ups, n_scale_downs=n_downs)
                self._record_sample_gauges(tel, now, self._fleet_sample())
                self._record_fleet_gauges(tel, now)
        if self._recovery_queue:
            self._recover(now, stats)
        for model in self._win:
            self._win[model] = self._fresh_window()

    def _handle_plan_verb(self, verb: str, stats: TraceStats, now: float) -> None:
        """Plan verbs may evict replicas (baseline reconfigure replays):
        requeue their in-flight request and forget the ghost."""
        super()._handle_plan_verb(verb, stats, now)
        self._fleet_dirty = True
        self._sweep_ghosts(now, stats)

    # -- fault hooks (demand layer) ------------------------------------------
    def _fleet_changed(self) -> None:
        self._fleet_dirty = True

    def _sweep_ghosts(self, now: float, stats: TraceStats) -> None:
        """Drop replica objects whose workload left the state (plan-verb or
        emergency-verb evictions); requeue their in-flight request."""
        for model, reps in self._reps.items():
            requeued = False
            for wid in [w for w in reps if w not in self.state.workloads]:
                rep = reps.pop(wid)
                if rep.current is not None and rep.current is not _RESTORING:
                    self._queues[model].appendleft(rep.current)
                    stats.n_requeued_requests += 1
                    requeued = True
            if requeued:
                self._dispatch(model, now, self._heap, self._seq)

    def _on_fault_evicted(
        self, evicted: List[Workload], now: float, stats: TraceStats
    ) -> None:
        """A fault killed these replicas: requeue their in-flight requests at
        the FRONT of their model's queue (they have waited longest)."""
        for w in evicted:
            reps = self._reps.get(w.model)
            if reps is None:
                continue
            rep = reps.pop(w.wid, None)
            if (
                rep is not None
                and rep.current is not None
                and rep.current is not _RESTORING
            ):
                self._queues[w.model].appendleft(rep.current)
                stats.n_requeued_requests += 1

    def _recovery_ready_at(self, w: Workload, now: float) -> float:
        """Cold-restore completion: weights stream back over the migration
        cost model's link, then the replica resumes cold."""
        gid = self.state.gpu_of(w.wid)
        device = (
            self.state.gpus[gid].device if gid is not None
            else self._device_for(w.device_kind)
        )
        cm = self.engine.cost_model
        per = cm.bytes_per_memory_slice
        if per is None:
            gb = getattr(device, "mem_per_slice_gb", None)
            per = (int(gb) << 30) if gb else (10 << 30)
        n_bytes = device.profile(w.profile_id).memory_slices * per
        return now + cm.transfer_seconds(n_bytes) + cm.resume_seconds

    def _on_recovered(
        self, placed: List[Workload], now: float, stats: TraceStats
    ) -> Dict[str, float]:
        """Re-create replica objects for re-placed workloads.  Each restores
        cold (a "warmup" event frees it); its incident closes at ready-time,
        so recovery_seconds measures time to SERVING capacity, not placement."""
        ready: Dict[str, float] = {}
        for w in placed:
            if w.model not in self._reps:
                continue
            gid = self.state.gpu_of(w.wid)
            if gid is None:
                continue
            at = self._recovery_ready_at(w, now)
            ready[w.wid] = at
            rep = _Replica(
                wid=w.wid,
                model=w.model,
                profile_id=w.profile_id,
                device=self.state.gpus[gid].device,
            )
            if at > now:
                rep.current = _RESTORING  # type: ignore[assignment]
                rep.busy_until = at
                heapq.heappush(
                    self._heap, (at, next(self._seq), "warmup", (w.wid, w.model))
                )
            self._reps[w.model][w.wid] = rep
        return ready

    def _handle_warmup(self, payload, now: float, stats: TraceStats,
                       heap, seq) -> None:
        wid, model = payload
        rep = self._reps[model].get(wid)
        if rep is None or rep.current is not _RESTORING:
            return  # evicted again (or retired) while restoring
        rep.current = None
        if rep.draining:
            self._remove_replica(rep)
        else:
            self._dispatch(model, now, heap, seq)

    def _update_brownout(self, now: float, stats: TraceStats) -> None:
        active = bool(self._fault_evicted)
        if active and self._brownout_since is None:
            self._brownout_since = now
        elif not active and self._brownout_since is not None:
            t0 = min(self._brownout_since, self._horizon)
            t1 = min(now, self._horizon)
            stats.brownout_seconds += max(t1 - t0, 0.0)
            self._brownout_since = None

    def _finalize_faults(self, stats: TraceStats, horizon: float) -> None:
        super()._finalize_faults(stats, horizon)
        if self._brownout_since is not None:
            stats.brownout_seconds += max(
                horizon - min(self._brownout_since, horizon), 0.0
            )
            self._brownout_since = None

    # -- main loop ----------------------------------------------------------
    def run(self, traffic: RequestTrace) -> TraceStats:  # type: ignore[override]
        unknown = set(r.model for r in traffic.requests) - set(self.specs)
        if unknown:
            raise ValueError(f"traffic for unknown models: {sorted(unknown)}")
        stats = TraceStats(
            policy=self.engine.policy_name,
            horizon=traffic.horizon,
            time_avg_gpus_used=0.0,
            time_avg_compute_waste=0.0,
            time_avg_memory_waste=0.0,
            time_avg_mem_occupancy=0.0,
            peak_gpus_used=0,
        )
        horizon = traffic.horizon
        seq = self._seq = itertools.count()
        heap: List[Tuple[float, int, str, object]] = [
            (r.time, next(seq), "request", r) for r in traffic.requests
        ]
        heapq.heapify(heap)
        self._heap = heap  # plan-verb eviction hook re-dispatches through it
        self._horizon = horizon
        if self.faults is not None:
            for fe in self.faults.schedule(self.state, horizon):
                heapq.heappush(heap, (fe.time, next(seq), "fault", fe))
        periods = {"compact": self.compact_every,
                   "reconfigure": self.reconfigure_every}
        for kind, period in periods.items():
            if period and kind in self.engine.policy.supports:
                heapq.heappush(heap, (period, next(seq), kind, None))
        if self.autoscaler is not None and self.autoscale_every:
            heapq.heappush(
                heap, (self.autoscale_every, next(seq), "autoscale", None)
            )
        for model in sorted(self.specs):
            spec = self.specs[model]
            if spec.initial_replicas:
                self._deploy_replicas(
                    model, spec.initial_replicas, spec.profile_id, stats
                )
        acc = np.zeros(5)  # fleet sample (4) + total queue depth
        t_prev = 0.0
        while heap:
            t, _, kind, payload = heapq.heappop(heap)
            qdepth = self._total_queue_depth()
            sample = self._fleet_sample() + (qdepth,)
            t_now = min(t, horizon)
            if t_now > t_prev:
                acc += np.array(sample) * (t_now - t_prev)
                t_prev = t_now
            stats.peak_gpus_used = max(stats.peak_gpus_used, sample[0])
            stats.peak_queue_depth = max(stats.peak_queue_depth, qdepth)
            if kind == "request":
                self._handle_request(payload, t, stats, heap, seq)
            elif kind == "complete":
                self._handle_complete(payload, t, stats, heap, seq)
            elif kind == "autoscale":
                if t < horizon:
                    self._autoscale_tick(t, stats, heap, seq)
                    nxt = t + self.autoscale_every
                    if nxt < horizon:
                        heapq.heappush(heap, (nxt, next(seq), kind, None))
            elif kind in ("compact", "reconfigure"):
                if t < horizon:
                    self._handle_plan_verb(kind, stats, t)
                    nxt = t + periods[kind]
                    if nxt < horizon:
                        heapq.heappush(heap, (nxt, next(seq), kind, None))
            elif kind == "fault":
                self._handle_fault(payload, stats, t)
            elif kind == "warmup":
                self._handle_warmup(payload, t, stats, heap, seq)
            else:  # pragma: no cover
                raise ValueError(f"unknown demand event kind {kind!r}")
        if self.faults is not None:
            self._finalize_faults(stats, horizon)
        sample = self._fleet_sample() + (self._total_queue_depth(),)
        acc += np.array(sample) * max(horizon - t_prev, 0.0)
        stats.peak_gpus_used = max(stats.peak_gpus_used, sample[0])
        stats.peak_queue_depth = max(stats.peak_queue_depth, sample[4])
        h = max(horizon, 1e-9)
        (
            stats.time_avg_gpus_used,
            stats.time_avg_compute_waste,
            stats.time_avg_memory_waste,
            stats.time_avg_mem_occupancy,
            stats.time_avg_queue_depth,
        ) = (acc / h).tolist()
        stats.n_unserved = self._total_queue_depth()
        for model in sorted(self.specs):
            arrived = self._arrived[model]
            stats.slo_attainment_by_model[model] = (
                self._hits[model] / arrived if arrived else 1.0
            )
        total_arrived = sum(self._arrived.values())
        stats.slo_attainment = (
            sum(self._hits.values()) / total_arrived if total_arrived else 1.0
        )
        if self._ttfts:
            stats.ttft_p50, stats.ttft_p95, stats.ttft_p99 = [
                float(v) for v in np.percentile(self._ttfts, [50, 95, 99])
            ]
            stats.tpot_p50, stats.tpot_p95, stats.tpot_p99 = [
                float(v) for v in np.percentile(self._tpots, [50, 95, 99])
            ]
        return stats
