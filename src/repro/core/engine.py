"""PlacementEngine: every placement approach behind one interface.

The paper evaluates five approaches (first-fit, load-balanced, the Sec-4.2
rule-based heuristic, the WPM MIP, and the beyond-paper pattern solver)
across three use cases (initial deployment, compaction, reconfiguration).
The seed codebase dispatched to them ad hoc from three different layers;
this module is now the single entry point:

    engine = PlacementEngine("rule_based")
    engine.deploy(state, new_workloads)   # Sec 2.3.1
    engine.compact(state)                 # Sec 2.3.2
    engine.reconfigure(state)             # Sec 2.3.3

All verbs mutate ``state`` in place (MIP/pattern results are adopted into
the passed state) and return an ``EngineResult``.  Heterogeneous fleets —
GPUs with different ``DeviceModel``s in one ``ClusterState`` — are handled
here: the engine partitions the cluster by device model, routes each
workload to its compatible group (``Workload.device_kind``), and runs the
policy per group, so the policy implementations stay single-device.

Baseline compaction/reconfiguration replays (paper Sec 5.2.2/5.2.3) used to
live in the benchmark harness; they are policy methods now, built on the
transactional state instead of whole-cluster clones.

Fleet-scale deployments route through the vectorized fabric
(``core/fabric.py``): with ``fabric="auto"`` (default), first_fit /
load_balanced / rule_based deploys on fleets of >= ``FABRIC_AUTO_MIN_GPUS``
GPUs use the JAX-batched feasibility kernels — placement-identical to the
scalar path, an order of magnitude faster at 1024+ GPUs.  The ``frag_aware``
policy (fragmentation-aware scoring per Ting et al.) is fabric-native.

Plan / score / commit
---------------------
``compact`` and ``reconfigure`` no longer mutate blindly: the policy runs
inside a ``ClusterState.transaction()``, the resulting diff is derived as a
``MigrationPlan``, priced by a ``MigrationCostModel`` (bytes to transfer,
downtime seconds, SLO disruption), and committed only if the configured
``CommitPolicy`` says the gains (GPUs saved, wastage removed) justify the
disruption — otherwise the transaction rolls back in O(ops), no clone-and-
restore.  The scored plan, the gains, and the decision ride back on the
``EngineResult`` either way.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Type, Union

from ..obs import get_telemetry
from . import baselines, heuristic
from .migration import (
    BytesFor,
    CommitDecision,
    CommitPolicy,
    MigrationCostModel,
    MigrationPlan,
    PlanCost,
    PlanGains,
    plan_migration,
)
from .state import ClusterState, Workload

__all__ = [
    "EngineResult",
    "PlacementPolicy",
    "PlacementEngine",
    "get_policy",
    "available_policies",
    "POLICIES",
    "CommitPolicy",
    "MigrationCostModel",
]

VERBS = ("deploy", "compact", "reconfigure")


@dataclasses.dataclass
class EngineResult:
    """Outcome of one engine verb."""

    policy: str
    verb: str
    pending: List[Workload]
    seconds: float
    #: scored migration plan (compact/reconfigure always; deploy only when
    #: the engine was built with ``plan_deploys=True``).
    plan: Optional[MigrationPlan] = None
    cost: Optional[PlanCost] = None
    gains: Optional[PlanGains] = None
    decision: Optional[CommitDecision] = None
    #: False when the CommitPolicy rejected the plan and the state was
    #: rolled back to its pre-verb layout.
    committed: bool = True
    #: the pre-verb snapshot the plan was derived against (set whenever a
    #: plan is) — callers needing before/after metrics reuse it instead of
    #: cloning the fleet a second time.
    baseline: Optional[ClusterState] = None


# ---------------------------------------------------------------------------
# policy interface
# ---------------------------------------------------------------------------
#: fleets at or above this size route deployments through the vectorized
#: fabric (core/fabric.py) when ``fabric="auto"`` — below it, the scalar
#: path's lower constant factors win (measured: at 128 GPUs the fabric is
#: ~1.7x faster for first_fit and ~3x for rule_based; at 64 it can lose).
FABRIC_AUTO_MIN_GPUS = 128


class PlacementPolicy:
    """One placement approach; verbs mutate a *single-device* state in place.

    ``fabric`` selects the vectorized fast path for policies that have one
    (first_fit / load_balanced / rule_based deploys): ``"auto"`` uses it on
    fleets of >= FABRIC_AUTO_MIN_GPUS GPUs, ``"on"`` / ``"off"`` force it.
    The fabric paths are placement-identical to the scalar references.
    """

    name: str = "abstract"
    supports: Tuple[str, ...] = VERBS

    def __init__(self, time_limit: float = 30.0, fabric: str = "auto"):
        if fabric not in ("auto", "on", "off"):
            raise ValueError(f"fabric must be auto/on/off, got {fabric!r}")
        self.time_limit = time_limit
        self.fabric = fabric

    def _use_fabric(self, state: ClusterState) -> bool:
        if self.fabric == "on":
            return True
        if self.fabric == "off":
            return False
        return len(state.gpus) >= FABRIC_AUTO_MIN_GPUS

    def deploy(
        self, state: ClusterState, new_workloads: Sequence[Workload]
    ) -> List[Workload]:
        raise NotImplementedError

    def compact(self, state: ClusterState) -> None:
        raise NotImplementedError

    def reconfigure(self, state: ClusterState) -> List[Workload]:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# baseline policies (first-fit / load-balanced)
# ---------------------------------------------------------------------------
def _spot_first_fit(
    state: ClusterState, w: Workload, candidates: Sequence[str]
) -> Optional[Tuple[str, int]]:
    for gid in sorted(candidates):
        idx = baselines._try_place(state.gpus[gid], w, numeric_order=True)
        if idx is not None:
            return gid, idx
    return None


def _spot_load_balanced(
    state: ClusterState, w: Workload, candidates: Sequence[str]
) -> Optional[Tuple[str, int]]:
    ordered = sorted(
        candidates, key=lambda gid: (state.gpus[gid].joint_slice_utilization(), gid)
    )
    for gid in ordered:
        idx = baselines._try_place(state.gpus[gid], w, numeric_order=True)
        if idx is not None:
            return gid, idx
    return None


class _BaselinePolicy(PlacementPolicy):
    """Shared compaction/reconfiguration replay for the two baselines."""

    _spot: Callable = None  # (state, w, candidates) -> (gid, idx) | None
    _deploy: Callable = None
    _fabric_deploy: str = ""  # fabric fast-path function name

    def deploy(self, state, new_workloads):
        if self._fabric_deploy and self._use_fabric(state):
            from . import fabric

            return getattr(fabric, self._fabric_deploy)(state, new_workloads)
        return type(self)._deploy(state, new_workloads)

    def compact(self, state):
        """Vacate the least utilized GPU into other allocated GPUs, placing
        per the baseline rule; one-shot migrations only (Sec 5.2.2)."""
        spot = type(self)._spot
        progress = True
        while progress:
            progress = False
            used = sorted(
                state.used_gpus(), key=lambda g: (g.joint_slice_utilization(), g.gid)
            )
            for gpu in used:
                others = [g.gid for g in state.used_gpus() if g.gid != gpu.gid]
                before = {o: state.gpus[o].clone() for o in others}
                with state.transaction() as txn:
                    moves: List[Tuple[str, str, int]] = []
                    ok = True
                    for pl in list(state.gpus[gpu.gid].placements):
                        w = state.workloads[pl.wid]
                        state.remove(pl.wid, gpu.gid)
                        s = spot(state, w, others)
                        if s is None:
                            ok = False
                            break
                        state.place(w.wid, *s)
                        moves.append((w.wid, *s))
                    if ok:
                        # one-shot property: destinations free pre-compaction
                        for wid, dst, idx in moves:
                            prof = state.gpus[dst].device.profile(
                                state.workloads[wid].profile_id
                            )
                            if not before[dst].can_place_at(prof, idx):
                                ok = False
                                break
                    if not ok:
                        txn.rollback()
                if ok:
                    progress = True
                    break

    def reconfigure(self, state):
        """Re-place ALL workloads from scratch with the baseline rule
        (arrival order, indexes from 0 — paper Sec 5.2.3)."""
        from .fabric import replay_fresh_deploy

        return replay_fresh_deploy(state, self.deploy)  # fabric-accel if routed


class FirstFitPolicy(_BaselinePolicy):
    name = "first_fit"
    _spot = staticmethod(_spot_first_fit)
    _deploy = staticmethod(baselines.first_fit)
    _fabric_deploy = "fabric_first_fit"


class LoadBalancedPolicy(_BaselinePolicy):
    name = "load_balanced"
    _spot = staticmethod(_spot_load_balanced)
    _deploy = staticmethod(baselines.load_balanced)
    _fabric_deploy = "fabric_load_balanced"


# ---------------------------------------------------------------------------
# rule-based heuristic (Sec 4.2)
# ---------------------------------------------------------------------------
class RuleBasedPolicy(PlacementPolicy):
    name = "rule_based"

    def deploy(self, state, new_workloads):
        if self._use_fabric(state):
            from . import fabric

            return fabric.fabric_initial_deployment(state, new_workloads)
        return heuristic.initial_deployment(state, new_workloads)

    def compact(self, state):
        heuristic.compaction(state)

    def reconfigure(self, state):
        return heuristic.reconfiguration(state)


# ---------------------------------------------------------------------------
# fragmentation-aware policy (beyond-paper; Ting et al. scoring on the fabric)
# ---------------------------------------------------------------------------
class FragAwarePolicy(PlacementPolicy):
    """Fabric-native policy scoring every candidate triple by post-placement
    fragmentation delta + wastage (Ting et al.); runs at any fleet size."""

    name = "frag_aware"

    def deploy(self, state, new_workloads):
        from . import fabric

        return fabric.fabric_frag_aware_deploy(state, new_workloads)

    def compact(self, state):
        from . import fabric

        fabric.fabric_frag_aware_compact(state)

    def reconfigure(self, state):
        from . import fabric

        return fabric.fabric_frag_aware_reconfigure(state)


# ---------------------------------------------------------------------------
# WPM MIP (Sec 4.1)
# ---------------------------------------------------------------------------
def _adopt(state: ClusterState, solved: ClusterState) -> None:
    """Land a solver-produced layout in ``state`` via the journaled
    diff-apply (no GPUState swaps — engine transactions can undo it)."""
    state.adopt(solved)


class MIPPolicy(PlacementPolicy):
    """WPM with existing placements fixed on deploy (paper 'mip')."""

    name = "mip"
    _joint_deploy = False

    def deploy(self, state, new_workloads):
        from .wpm_mip import solve_wpm

        res = solve_wpm(
            state,
            new_workloads,
            movable=self._joint_deploy,
            allow_reconfig=self._joint_deploy,
            time_limit=self.time_limit,
        )
        _adopt(state, res.state)
        return res.pending

    def compact(self, state):
        from .wpm_mip import solve_wpm

        res = solve_wpm(
            state, (), movable=True, allow_reconfig=True, time_limit=self.time_limit
        )
        _adopt(state, res.state)

    def reconfigure(self, state):
        from .wpm_mip import solve_wpm

        res = solve_wpm(
            state, (), movable=True, allow_reconfig=True, time_limit=self.time_limit
        )
        _adopt(state, res.state)
        return res.pending


class JointMIPPolicy(MIPPolicy):
    """WPM jointly re-placing existing workloads on deploy (paper 'joint_mip')."""

    name = "joint_mip"
    _joint_deploy = True


# ---------------------------------------------------------------------------
# pattern-enumeration exact solver (beyond-paper)
# ---------------------------------------------------------------------------
class PatternsPolicy(PlacementPolicy):
    """Exact for (#GPUs, wastage); re-places everything, so migration cost is
    ignored — reconfiguration-style by construction."""

    name = "patterns"
    supports = ("deploy", "reconfigure")

    def deploy(self, state, new_workloads):
        from .patterns import reconfigure_patterns

        for w in new_workloads:
            state.add_workload(w)
        try:
            res = reconfigure_patterns(
                state, extra_workloads=new_workloads, time_limit=self.time_limit
            )
        except RuntimeError:
            # Not enough GPUs (or ILP infeasible) for the joint re-placement:
            # reject the batch, keep the current layout untouched.
            return list(new_workloads)
        _adopt(state, res.state)
        return []

    def reconfigure(self, state):
        from .patterns import reconfigure_patterns

        res = reconfigure_patterns(state, time_limit=self.time_limit)
        _adopt(state, res.state)
        return []


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
POLICIES: Dict[str, Type[PlacementPolicy]] = {
    p.name: p
    for p in (
        FirstFitPolicy,
        LoadBalancedPolicy,
        RuleBasedPolicy,
        FragAwarePolicy,
        MIPPolicy,
        JointMIPPolicy,
        PatternsPolicy,
    )
}
#: legacy aliases (serving layer historically called the heuristic this)
_ALIASES = {"heuristic": "rule_based"}


def available_policies() -> Tuple[str, ...]:
    return tuple(POLICIES)


def get_policy(
    name: str, time_limit: float = 30.0, fabric: str = "auto"
) -> PlacementPolicy:
    key = _ALIASES.get(name, name)
    if key not in POLICIES:
        raise ValueError(f"unknown policy {name!r}; choose from {available_policies()}")
    return POLICIES[key](time_limit=time_limit, fabric=fabric)


# ---------------------------------------------------------------------------
# the engine: verbs + heterogeneous-fleet routing
# ---------------------------------------------------------------------------
class PlacementEngine:
    """Single entry point for all placement decisions.

    ``deploy`` / ``compact`` / ``reconfigure`` mutate the passed state in
    place.  On a homogeneous cluster the policy runs directly; on a mixed
    fleet the engine runs it once per device group over a sub-view sharing
    the real ``GPUState`` objects, so results land in the real state.
    """

    def __init__(
        self,
        policy: str = "rule_based",
        time_limit: float = 30.0,
        fabric: str = "auto",
        commit: Union[str, CommitPolicy] = "always",
        cost_model: Optional[MigrationCostModel] = None,
        plan_deploys: bool = False,
    ):
        self.policy = get_policy(policy, time_limit, fabric)
        self.commit_policy = (
            commit if isinstance(commit, CommitPolicy) else CommitPolicy(mode=commit)
        )
        self.cost_model = cost_model or MigrationCostModel()
        #: optional wid -> live bytes hook (serving layer: weights + KV).
        self.bytes_for: Optional[BytesFor] = None
        #: derive scored plans for deploys too (off by default: the clone +
        #: diff walk is pure overhead on the fleet-scale arrival hot path).
        self.plan_deploys = plan_deploys

    @property
    def policy_name(self) -> str:
        return self.policy.name

    # -- device grouping ---------------------------------------------------
    @staticmethod
    def _groups(state: ClusterState) -> Dict[str, List[str]]:
        """Schedulable GPUs by device kind.

        Unhealthy GPUs (failed / draining / maintenance / degraded — see
        ``state.HEALTH_STATES``) are excluded here, at the single chokepoint
        every verb routes through, so no policy — scalar, fabric-vectorized,
        or MIP — can land new placements on a quarantined GPU, and plan
        verbs never try to repack placements that survive on a degraded one.
        """
        groups: Dict[str, List[str]] = {}
        for gid in state.ordered_gids():
            gpu = state.gpus[gid]
            if not gpu.schedulable:
                continue
            groups.setdefault(gpu.device.name, []).append(gid)
        return groups

    @staticmethod
    def _subview(state: ClusterState, gids: Sequence[str]) -> ClusterState:
        """A per-group view sharing GPUState objects and the workload dict.

        Subviews are memoized on the parent state (keyed by the gid tuple)
        so that the fabric mirror a fast-path deploy attaches to the view
        survives across engine calls — the online-trace hot path.  On reuse
        the gpu/workload references are re-pointed at the parent's current
        objects; the fabric layer re-syncs by placement content, so wholesale
        GPUState replacement (MIP adoption, budget rollback) stays safe.
        """
        subs = state.__dict__.setdefault("_subviews", {})
        key = tuple(gids)
        sub = subs.get(key)
        if sub is None:
            sub = ClusterState(
                gpus={gid: state.gpus[gid] for gid in gids},
                workloads=state.workloads,
            )
            subs[key] = sub
        else:
            for gid in key:
                sub.gpus[gid] = state.gpus[gid]
            sub.workloads = state.workloads
        # Ops performed through the view journal into the parent's open
        # transaction (shared GPUState objects / workload dict make them
        # undoable from the parent) — the commit-gating rollback path.
        sub.link_journal_parent(state)
        return sub

    def _route(
        self, state: ClusterState, workloads: Sequence[Workload]
    ) -> Dict[str, List[Workload]]:
        """Split workloads across device groups by ``device_kind``."""
        groups = self._groups(state)
        if not groups:  # empty cluster: nothing can host anything
            return {}
        if len(groups) == 1:
            kind = next(iter(groups))
            for w in workloads:
                if w.device_kind and w.device_kind != kind:
                    raise ValueError(
                        f"workload {w.wid} targets {w.device_kind!r}, fleet "
                        f"is all {kind!r}"
                    )
            return {kind: list(workloads)}
        routed: Dict[str, List[Workload]] = {k: [] for k in groups}
        for w in workloads:
            if not w.device_kind:
                raise ValueError(
                    f"workload {w.wid} has no device_kind on a mixed fleet "
                    f"({tuple(groups)})"
                )
            if w.device_kind not in routed:
                raise ValueError(
                    f"workload {w.wid} targets {w.device_kind!r}, fleet has "
                    f"{tuple(groups)}"
                )
            routed[w.device_kind].append(w)
        return routed

    def _per_group(self, state: ClusterState, fn) -> List[Workload]:
        """Run ``fn(sub_state, group_gids)`` per device group, copy back."""
        groups = self._groups(state)
        pending: List[Workload] = []
        for kind, gids in groups.items():
            sub = self._subview(state, gids)
            out = fn(sub, kind)
            # Policies may have replaced GPUState objects (reconfigure/MIP)
            # or even the sub dicts; mirror into the real state.
            for gid in gids:
                state.gpus[gid] = sub.gpus[gid]
            if state.workloads is not sub.workloads:
                state.workloads.update(sub.workloads)
            if out:
                pending.extend(out)
        return pending

    # -- plan scoring ------------------------------------------------------
    @staticmethod
    def _wastage(state: ClusterState) -> int:
        return sum(
            g.compute_waste() + g.memory_waste() for g in state.used_gpus()
        )

    def _score(
        self, before: ClusterState, state: ClusterState
    ) -> Tuple[MigrationPlan, PlanCost, PlanGains, CommitDecision]:
        plan = plan_migration(before, state)
        cost = self.cost_model.price(plan, state, bytes_for=self.bytes_for)
        plan.cost = cost
        gains = PlanGains(
            gpus_saved=len(before.used_gpus()) - len(state.used_gpus()),
            waste_saved=self._wastage(before) - self._wastage(state),
        )
        return plan, cost, gains, self.commit_policy.decide(gains, cost)

    # -- telemetry ---------------------------------------------------------
    def _record_verb(self, tel, res: EngineResult) -> None:
        """Feed one verb outcome into the metrics registry (live only)."""
        m = tel.metrics
        labels = {"verb": res.verb, "policy": res.policy}
        m.histogram(
            "planner_latency_seconds", "wall time of one engine verb",
            labels=labels,
        ).observe(res.seconds)
        m.counter("engine_verbs_total", "engine verb invocations",
                  labels=labels).inc()
        if res.decision is not None:
            which = "plans_committed_total" if res.committed else "plans_rejected_total"
            m.counter(
                which, "commit decisions by verb and deciding term",
                labels={**labels, "term": res.decision.term or "unknown"},
            ).inc()
        if res.cost is not None:
            m.counter("bytes_priced_total", "bytes priced across scored plans",
                      labels=labels).inc(float(res.cost.total_bytes))
        if res.pending:
            m.counter("workloads_pending_total",
                      "workloads a verb failed to place",
                      labels=labels).inc(float(len(res.pending)))

    # -- verbs -------------------------------------------------------------
    def deploy(
        self, state: ClusterState, new_workloads: Sequence[Workload]
    ) -> EngineResult:
        self._check("deploy")
        tel = get_telemetry()
        t0 = time.time()
        with tel.tracer.span("deploy") as sp:
            routed = self._route(state, new_workloads)
            if not routed:  # empty cluster: scalar-policy parity = all pending
                for w in new_workloads:
                    state.add_workload(w)
                res = EngineResult(
                    self.policy.name, "deploy", list(new_workloads),
                    time.time() - t0,
                )
                if tel.enabled:
                    sp.set(policy=self.policy.name, n_workloads=0,
                           n_pending=len(res.pending))
                    self._record_verb(tel, res)
                return res

            def _deploy_group(sub, kind):
                if not routed[kind]:
                    return []  # don't wake solver policies for untouched groups
                return self.policy.deploy(sub, routed[kind])

            before = state.clone() if self.plan_deploys else None
            with tel.tracer.span("plan"):
                pending = self._per_group(state, _deploy_group)
            res = EngineResult(
                self.policy.name, "deploy", pending, time.time() - t0
            )
            if before is not None:
                # Deploys are admissions, not optimizations: score the plan
                # (new placements are wave-0 moves; joint policies may also
                # relocate existing replicas) but never gate the commit on it.
                with tel.tracer.span("score") as ssp:
                    res.plan, res.cost, res.gains, res.decision = self._score(
                        before, state
                    )
                    if tel.enabled:
                        ssp.set(n_moves=res.plan.n_moves,
                                total_bytes=res.cost.total_bytes)
                res.baseline = before
            res.seconds = time.time() - t0
            if tel.enabled:
                sp.set(policy=self.policy.name,
                       n_workloads=len(new_workloads),
                       n_pending=len(res.pending))
                self._record_verb(tel, res)
        return res

    def compact(self, state: ClusterState) -> EngineResult:
        return self._gated_verb(state, "compact", lambda sub: self.policy.compact(sub))

    def reconfigure(self, state: ClusterState) -> EngineResult:
        return self._gated_verb(
            state, "reconfigure", lambda sub: self.policy.reconfigure(sub)
        )

    def _gated_verb(self, state: ClusterState, verb: str, fn) -> EngineResult:
        """Run a mutating verb as plan -> score -> commit.

        The policy mutates inside a transaction (sub-view ops journal to it
        via the parent link); the resulting diff is priced and the
        CommitPolicy decides.  Rejection is a journal rollback — placement
        lists, occupancy caches, and GPUState identities all restored.
        """
        self._check(verb)
        tel = get_telemetry()
        t0 = time.time()
        with tel.tracer.span(verb) as sp:
            before = state.clone()  # plan baseline (placement lists only)
            pending: List[Workload] = []
            with state.transaction() as txn:
                with tel.tracer.span("plan"):
                    pending = self._per_group(state, lambda sub, kind: fn(sub)) or []
                with tel.tracer.span("score") as ssp:
                    plan, cost, gains, decision = self._score(before, state)
                    if tel.enabled:
                        ssp.set(n_moves=plan.n_moves,
                                total_bytes=cost.total_bytes,
                                gpus_saved=gains.gpus_saved,
                                waste_saved=gains.waste_saved)
                if not decision.commit:
                    with tel.tracer.span("rollback") as rsp:
                        txn.rollback()
                        if tel.enabled:
                            rsp.set(reason=decision.reason, term=decision.term)
                    pending = []  # layout kept: nothing was evicted
                else:
                    # Commit = leaving the transaction without rollback; the
                    # span marks the decision so every committed verb has a
                    # complete plan/score/commit tree in the trace.
                    with tel.tracer.span("commit") as csp:
                        if tel.enabled:
                            csp.set(reason=decision.reason, term=decision.term,
                                    n_moves=plan.n_migrations)
            res = EngineResult(
                self.policy.name,
                verb,
                pending,
                time.time() - t0,
                plan=plan,
                cost=cost,
                gains=gains,
                decision=decision,
                committed=decision.commit,
                baseline=before,
            )
            if tel.enabled:
                sp.set(policy=self.policy.name, committed=decision.commit,
                       reason=decision.reason, term=decision.term,
                       n_moves=plan.n_moves,
                       bytes_priced=cost.total_bytes)
                self._record_verb(tel, res)
        return res

    def _check(self, verb: str) -> None:
        if verb not in self.policy.supports:
            raise ValueError(
                f"policy {self.policy.name!r} does not support {verb!r} "
                f"(supports {self.policy.supports})"
            )
