"""Cluster state model: workloads, placements, GPUs (paper Sec 2.1).

A *workload* is one replica of an LLM-inferencing deployment, matched to a
partition profile.  A *configuration* (paper terminology) is the set of
partitions + workload assignments on a GPU; here a ``GPUState`` holds the
placements directly (partition == placement, since under DRA a partition is
created per workload placement).
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

from .profiles import A100_80GB, DeviceModel, Profile

__all__ = ["Workload", "Placement", "GPUState", "ClusterState"]


@dataclasses.dataclass(frozen=True)
class Workload:
    """One model replica to be hosted in a MIG partition."""

    wid: str
    profile_id: int
    #: model tag, used by the serving layer; irrelevant to placement math.
    model: str = ""
    #: per-workload placement reward p_w and migration penalty gamma^M_w.
    reward: float = 100.0
    migration_cost: float = 1.0

    def profile(self, device: DeviceModel = A100_80GB) -> Profile:
        return device.profile(self.profile_id)


@dataclasses.dataclass(frozen=True)
class Placement:
    """A workload placed at a concrete slice index on a GPU."""

    wid: str
    profile_id: int
    index: int

    def spans(self, device: DeviceModel) -> Tuple[range, range]:
        return device.profile(self.profile_id).span(self.index, device.n_gpu_slices)


@dataclasses.dataclass
class GPUState:
    """One GPU (bin) with its current placements."""

    gid: str
    device: DeviceModel = A100_80GB
    placements: List[Placement] = dataclasses.field(default_factory=list)

    # ---- occupancy -------------------------------------------------------
    def memory_occupancy(self) -> List[Optional[str]]:
        """memory position -> wid or None."""
        occ: List[Optional[str]] = [None] * self.device.n_memory_slices
        for pl in self.placements:
            mem, _ = pl.spans(self.device)
            for pos in mem:
                if occ[pos] is not None:
                    raise ValueError(
                        f"{self.gid}: overlapping placements at memory pos {pos}"
                    )
                occ[pos] = pl.wid
        return occ

    def gpu_slice_occupancy(self) -> List[Optional[str]]:
        """GPU slice -> wid or None (positions 0..n_gpu_slices-1)."""
        return self.memory_occupancy()[: self.device.n_gpu_slices]

    def free_gpu_slices(self) -> List[int]:
        return [i for i, w in enumerate(self.gpu_slice_occupancy()) if w is None]

    def used_compute_slices(self) -> int:
        return sum(
            self.device.profile(p.profile_id).compute_slices for p in self.placements
        )

    def used_memory_slices(self) -> int:
        return sum(
            self.device.profile(p.profile_id).memory_slices for p in self.placements
        )

    def media_extensions_used(self) -> int:
        return sum(
            self.device.profile(p.profile_id).media_extensions
            for p in self.placements
        )

    def is_empty(self) -> bool:
        return not self.placements

    # ---- feasibility -----------------------------------------------------
    def can_place_at(self, profile: Profile, index: int) -> bool:
        """Is placing ``profile`` at ``index`` feasible in the current state?"""
        if index not in profile.allowed_indexes:
            return False
        mem, _ = profile.span(index, self.device.n_gpu_slices)
        if mem.stop > self.device.n_memory_slices:
            return False
        occ = self.memory_occupancy()
        if any(occ[pos] is not None for pos in mem):
            return False
        if (
            profile.media_extensions
            and self.media_extensions_used() + profile.media_extensions
            > self.device.max_media_extensions
        ):
            return False
        return True

    def first_feasible_index(
        self, profile: Profile, order: Optional[Iterable[int]] = None
    ) -> Optional[int]:
        """First feasible index in ``order`` (default: Table-1 preference)."""
        for idx in profile.allowed_indexes if order is None else order:
            if self.can_place_at(profile, idx):
                return idx
        return None

    def place(self, wid: str, profile_id: int, index: int) -> Placement:
        profile = self.device.profile(profile_id)
        if not self.can_place_at(profile, index):
            raise ValueError(f"{self.gid}: cannot place {profile.name} at {index}")
        pl = Placement(wid, profile_id, index)
        self.placements.append(pl)
        return pl

    def remove(self, wid: str) -> Placement:
        for i, pl in enumerate(self.placements):
            if pl.wid == wid:
                return self.placements.pop(i)
        raise KeyError(f"{self.gid}: workload {wid} not placed here")

    # ---- wastage (index-level; Table 3 semantics) -------------------------
    def compute_waste(self) -> int:
        """GPU slices blocked by placements but not backed by compute."""
        return sum(
            self.device.profile(p.profile_id).compute_waste_at(
                p.index, self.device.n_gpu_slices
            )
            for p in self.placements
        )

    def memory_waste(self) -> int:
        """Stranded extra memory position (m7 unusable; paper 3.2.3)."""
        if not self.device.extra_memory:
            return 0
        occ = self.memory_occupancy()
        last_gpu_slice = self.device.n_gpu_slices - 1  # slice 6
        extra_pos = self.device.n_memory_slices - 1  # m7
        holder = occ[last_gpu_slice]
        if holder is not None and occ[extra_pos] is None:
            # slice 6 is held by a partition that does not extend into m7
            # (e.g. profile 19 at index 6) -> m7 is unusable.
            return 1
        return 0

    def joint_slice_utilization(self) -> float:
        """(s_m + s_c) / (S_m + S_c) — heuristic GPU sort key (Sec 4.2)."""
        s_m, s_c = self.used_memory_slices(), self.used_compute_slices()
        return (s_m + s_c) / (self.device.n_memory_slices + self.device.n_gpu_slices)

    def clone(self) -> "GPUState":
        return GPUState(self.gid, self.device, list(self.placements))


@dataclasses.dataclass
class ClusterState:
    """A cluster of (possibly heterogeneous) MIG-capable GPUs."""

    gpus: Dict[str, GPUState] = dataclasses.field(default_factory=dict)
    workloads: Dict[str, Workload] = dataclasses.field(default_factory=dict)

    @classmethod
    def homogeneous(
        cls, n_gpus: int, device: DeviceModel = A100_80GB, prefix: str = "gpu"
    ) -> "ClusterState":
        return cls(
            gpus={
                f"{prefix}{i}": GPUState(f"{prefix}{i}", device)
                for i in range(n_gpus)
            }
        )

    # ---- lookups ----------------------------------------------------------
    def gpu_of(self, wid: str) -> Optional[str]:
        for gid, gpu in self.gpus.items():
            if any(p.wid == wid for p in gpu.placements):
                return gid
        return None

    def placement_of(self, wid: str) -> Optional[Tuple[str, Placement]]:
        for gid, gpu in self.gpus.items():
            for p in gpu.placements:
                if p.wid == wid:
                    return gid, p
        return None

    def used_gpus(self) -> List[GPUState]:
        return [g for g in self.gpus.values() if not g.is_empty()]

    def free_gpus(self) -> List[GPUState]:
        return [g for g in self.gpus.values() if g.is_empty()]

    def placed_workloads(self) -> List[Workload]:
        out = []
        for gpu in self.gpus.values():
            for p in gpu.placements:
                out.append(self.workloads[p.wid])
        return out

    def ordered_gids(self) -> List[str]:
        return sorted(self.gpus.keys())

    def add_workload(self, w: Workload) -> None:
        self.workloads[w.wid] = w

    def place(self, wid: str, gid: str, index: int) -> Placement:
        w = self.workloads[wid]
        return self.gpus[gid].place(wid, w.profile_id, index)

    def clone(self) -> "ClusterState":
        return ClusterState(
            gpus={gid: g.clone() for gid, g in self.gpus.items()},
            workloads=dict(self.workloads),
        )

    def validate(self) -> None:
        """Raise if any GPU has overlapping/illegal placements."""
        for gpu in self.gpus.values():
            gpu.memory_occupancy()
            for p in gpu.placements:
                prof = gpu.device.profile(p.profile_id)
                if p.index not in prof.allowed_indexes:
                    raise ValueError(
                        f"{gpu.gid}: {prof.name} at illegal index {p.index}"
                    )
