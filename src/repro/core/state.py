"""Cluster state model: workloads, placements, GPUs (paper Sec 2.1).

A *workload* is one replica of an LLM-inferencing deployment, matched to a
partition profile.  A *configuration* (paper terminology) is the set of
partitions + workload assignments on a GPU; here a ``GPUState`` holds the
placements directly (partition == placement, since under DRA a partition is
created per workload placement).

Performance model
-----------------
``GPUState`` keeps an incrementally-maintained occupancy cache (memory
position -> wid, plus used-slice / media-extension counters) so feasibility
checks are O(profile span) instead of O(placements x span) rebuilds.  The
cache survives direct mutation of ``placements`` (some callers backtrack by
editing the list) by keying it on a tuple snapshot of the list.

``ClusterState.transaction()`` provides an O(1)-per-op apply/undo journal so
trial placements (compaction vacate search, baseline replays, online
what-ifs) no longer need ``clone()`` of the whole cluster: mutate in place,
then ``rollback()`` to restore byte-identical state, or commit by falling
off the end of the ``with`` block.  Inside a transaction, use the
*cluster-level* ``place`` / ``remove`` / ``add_workload`` mutators — direct
``GPUState`` mutation is legal but bypasses the journal.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

from .profiles import A100_80GB, DeviceModel, Profile

__all__ = [
    "Workload",
    "Placement",
    "GPUState",
    "ClusterState",
    "Transaction",
    "HEALTH_STATES",
]


@dataclasses.dataclass(frozen=True)
class Workload:
    """One model replica to be hosted in a MIG partition."""

    wid: str
    profile_id: int
    #: model tag, used by the serving layer; irrelevant to placement math.
    model: str = ""
    #: per-workload placement reward p_w and migration penalty gamma^M_w.
    reward: float = 100.0
    migration_cost: float = 1.0
    #: device-model name this workload's profile_id refers to; blank means
    #: "whatever the (homogeneous) cluster runs".  Heterogeneous fleets set
    #: it so the placement engine can route to compatible GPUs only.
    device_kind: str = ""

    def profile(self, device: DeviceModel = A100_80GB) -> Profile:
        return device.profile(self.profile_id)


@dataclasses.dataclass(frozen=True)
class Placement:
    """A workload placed at a concrete slice index on a GPU."""

    wid: str
    profile_id: int
    index: int

    def spans(self, device: DeviceModel) -> Tuple[range, range]:
        return device.profile(self.profile_id).span(self.index, device.n_gpu_slices)


#: GPU health marks (fault injection / recovery control plane).  Anything
#: but "healthy" quarantines the GPU: the placement engine excludes it from
#: every device group, so no policy — scalar, fabric, or MIP — can land new
#: placements on it.  "degraded" (a failed memory slice) keeps surviving
#: placements serving; the other marks mean the GPU was evicted.
HEALTH_STATES = ("healthy", "failed", "draining", "maintenance", "degraded")


@dataclasses.dataclass
class GPUState:
    """One GPU (bin) with its current placements."""

    gid: str
    device: DeviceModel = A100_80GB
    placements: List[Placement] = dataclasses.field(default_factory=list)
    health: str = "healthy"

    def __post_init__(self) -> None:
        self._occ: List[Optional[str]] = []
        self._snap: Optional[Tuple[Placement, ...]] = None  # cache key
        self._used_cmp = 0
        self._used_mem = 0
        self._me_used = 0

    # ---- occupancy cache -------------------------------------------------
    def _rebuild_cache(self, snap: Tuple[Placement, ...]) -> None:
        occ: List[Optional[str]] = [None] * self.device.n_memory_slices
        cmp_ = mem_ = me_ = 0
        for pl in snap:
            prof = self.device.profile(pl.profile_id)
            span, _ = prof.span(pl.index, self.device.n_gpu_slices)
            for pos in span:
                if occ[pos] is not None:
                    raise ValueError(
                        f"{self.gid}: overlapping placements at memory pos {pos}"
                    )
                occ[pos] = pl.wid
            cmp_ += prof.compute_slices
            mem_ += prof.memory_slices
            me_ += prof.media_extensions
        self._occ = occ
        self._used_cmp, self._used_mem, self._me_used = cmp_, mem_, me_
        self._snap = snap

    def _occupancy(self) -> List[Optional[str]]:
        """The cached occupancy array (do not mutate)."""
        snap = tuple(self.placements)
        if snap != self._snap:
            self._rebuild_cache(snap)
        return self._occ

    # ---- occupancy -------------------------------------------------------
    def memory_occupancy(self) -> List[Optional[str]]:
        """memory position -> wid or None."""
        return list(self._occupancy())

    def gpu_slice_occupancy(self) -> List[Optional[str]]:
        """GPU slice -> wid or None (positions 0..n_gpu_slices-1)."""
        return list(self._occupancy()[: self.device.n_gpu_slices])

    def free_gpu_slices(self) -> List[int]:
        occ = self._occupancy()
        return [i for i in range(self.device.n_gpu_slices) if occ[i] is None]

    def used_compute_slices(self) -> int:
        self._occupancy()
        return self._used_cmp

    def used_memory_slices(self) -> int:
        self._occupancy()
        return self._used_mem

    def media_extensions_used(self) -> int:
        self._occupancy()
        return self._me_used

    def is_empty(self) -> bool:
        return not self.placements

    @property
    def schedulable(self) -> bool:
        """Eligible for NEW placements (existing ones may keep serving)."""
        return self.health == "healthy"

    # ---- feasibility -----------------------------------------------------
    def can_place_at(self, profile: Profile, index: int) -> bool:
        """Is placing ``profile`` at ``index`` feasible in the current state?"""
        if self.health != "healthy":
            return False  # quarantined: failed / draining / maintenance
        if index not in profile.allowed_indexes:
            return False
        stop = index + profile.memory_slices
        if stop > self.device.n_memory_slices:
            return False
        occ = self._occupancy()
        if any(occ[pos] is not None for pos in range(index, stop)):
            return False
        if (
            profile.media_extensions
            and self._me_used + profile.media_extensions
            > self.device.max_media_extensions
        ):
            return False
        return True

    def first_feasible_index(
        self, profile: Profile, order: Optional[Iterable[int]] = None
    ) -> Optional[int]:
        """First feasible index in ``order`` (default: Table-1 preference)."""
        for idx in profile.allowed_indexes if order is None else order:
            if self.can_place_at(profile, idx):
                return idx
        return None

    def place(self, wid: str, profile_id: int, index: int) -> Placement:
        profile = self.device.profile(profile_id)
        if not self.can_place_at(profile, index):
            raise ValueError(f"{self.gid}: cannot place {profile.name} at {index}")
        pl = Placement(wid, profile_id, index)
        self.placements.append(pl)
        # can_place_at validated the cache; extend it incrementally.
        for pos in range(index, index + profile.memory_slices):
            self._occ[pos] = wid
        self._used_cmp += profile.compute_slices
        self._used_mem += profile.memory_slices
        self._me_used += profile.media_extensions
        self._snap = self._snap + (pl,)
        return pl

    def remove(self, wid: str) -> Placement:
        for i, pl in enumerate(self.placements):
            if pl.wid == wid:
                self._occupancy()  # ensure cache is valid pre-mutation
                self.placements.pop(i)
                self._shrink_cache(pl)
                return pl
        raise KeyError(f"{self.gid}: workload {wid} not placed here")

    def _shrink_cache(self, pl: Placement) -> None:
        prof = self.device.profile(pl.profile_id)
        for pos in range(pl.index, pl.index + prof.memory_slices):
            self._occ[pos] = None
        self._used_cmp -= prof.compute_slices
        self._used_mem -= prof.memory_slices
        self._me_used -= prof.media_extensions
        self._snap = tuple(self.placements)

    # ---- journal undo primitives (Transaction only) ----------------------
    def _undo_place(self, pl: Placement) -> None:
        """Reverse a journaled place(); the placement is still last."""
        self._occupancy()
        last = self.placements.pop()
        assert last == pl, f"{self.gid}: journal out of sync ({last} != {pl})"
        self._shrink_cache(pl)

    def _undo_remove(self, pl: Placement, at: int) -> None:
        """Reverse a journaled remove(), restoring list order exactly."""
        self._occupancy()
        self.placements.insert(at, pl)
        prof = self.device.profile(pl.profile_id)
        for pos in range(pl.index, pl.index + prof.memory_slices):
            self._occ[pos] = pl.wid
        self._used_cmp += prof.compute_slices
        self._used_mem += prof.memory_slices
        self._me_used += prof.media_extensions
        self._snap = tuple(self.placements)

    # ---- wastage (index-level; Table 3 semantics) -------------------------
    def compute_waste(self) -> int:
        """GPU slices blocked by placements but not backed by compute."""
        return sum(
            self.device.profile(p.profile_id).compute_waste_at(
                p.index, self.device.n_gpu_slices
            )
            for p in self.placements
        )

    def memory_waste(self) -> int:
        """Stranded extra memory position (m7 unusable; paper 3.2.3)."""
        if not self.device.extra_memory:
            return 0
        occ = self._occupancy()
        last_gpu_slice = self.device.n_gpu_slices - 1  # slice 6
        extra_pos = self.device.n_memory_slices - 1  # m7
        holder = occ[last_gpu_slice]
        if holder is not None and occ[extra_pos] is None:
            # slice 6 is held by a partition that does not extend into m7
            # (e.g. profile 19 at index 6) -> m7 is unusable.
            return 1
        return 0

    def fragmentation(self) -> float:
        """Free-slice fragmentation in [0, 1) (Ting et al.'s free-space health).

        ``1 - largest_free_run / total_free`` over memory positions: 0.0 when
        the free space is one contiguous run (or the GPU is full), approaching
        1 as the free space shatters into many small runs that cannot host
        large profiles.
        """
        occ = self._occupancy()
        total = best = run = 0
        for pos in range(self.device.n_memory_slices):
            if occ[pos] is None:
                total += 1
                run += 1
                if run > best:
                    best = run
            else:
                run = 0
        if total == 0:
            return 0.0
        return 1.0 - best / total

    def joint_slice_utilization(self) -> float:
        """(s_m + s_c) / (S_m + S_c) — heuristic GPU sort key (Sec 4.2)."""
        self._occupancy()
        return (self._used_mem + self._used_cmp) / (
            self.device.n_memory_slices + self.device.n_gpu_slices
        )

    def clone(self) -> "GPUState":
        return GPUState(self.gid, self.device, list(self.placements), self.health)


# ---------------------------------------------------------------------------
# transactions
# ---------------------------------------------------------------------------
class Transaction:
    """Undo journal over a ClusterState (O(1) record per mutation).

    Obtained from ``ClusterState.transaction()``.  Mutations made through the
    cluster-level mutators while the transaction is the innermost open one
    are journaled.  ``rollback()`` restores the exact pre-transaction state
    (placement list order included); falling off the ``with`` block commits
    (an inner transaction's ops are spliced into its parent so an outer
    rollback still undoes them).  An exception rolls back automatically.
    """

    def __init__(self, state: "ClusterState", parent: Optional["Transaction"]):
        self._state = state
        self._parent = parent
        self._ops: List[Tuple] = []
        self.closed = False

    # -- recording (ClusterState only) --
    def _record(self, op: Tuple) -> None:
        if not self.closed:
            self._ops.append(op)

    # -- lifecycle --
    def rollback(self) -> None:
        """Undo every journaled op, newest first; the txn becomes inert."""
        if self.closed:
            return
        st = self._state
        for op in reversed(self._ops):
            kind = op[0]
            if kind == "place":
                _, gid, pl = op
                st.gpus[gid]._undo_place(pl)
            elif kind == "remove":
                _, gid, pl, at = op
                st.gpus[gid]._undo_remove(pl, at)
            elif kind == "add_wl":
                _, wid, prev = op
                if prev is None:
                    st.workloads.pop(wid, None)
                else:
                    st.workloads[wid] = prev
            elif kind == "health":
                _, gid, prev = op
                st.gpus[gid].health = prev
            else:  # pragma: no cover - journal is internal
                raise AssertionError(f"unknown journal op {kind}")
        self._ops.clear()
        self.closed = True

    def commit(self) -> None:
        """Keep the mutations; splice ops into the parent txn if any.

        A root transaction on a *linked* state (an engine sub-view, see
        ``ClusterState.link_journal_parent``) forwards its ops to the parent
        state's innermost open transaction, so an engine-level rollback can
        undo policy work done through per-group views.
        """
        if self.closed:
            return
        if self._parent is not None:
            self._parent._ops.extend(self._ops)
        else:
            parent_state = self._state.__dict__.get("_journal_parent")
            if parent_state is not None:
                for op in self._ops:
                    parent_state._journal(op)
        self._ops.clear()
        self.closed = True

    # -- context manager --
    def __enter__(self) -> "Transaction":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self.rollback()
        else:
            self.commit()
        assert self._state._txns and self._state._txns[-1] is self
        self._state._txns.pop()
        return False


@dataclasses.dataclass
class ClusterState:
    """A cluster of (possibly heterogeneous) MIG-capable GPUs."""

    gpus: Dict[str, GPUState] = dataclasses.field(default_factory=dict)
    workloads: Dict[str, Workload] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        self._txns: List[Transaction] = []

    @classmethod
    def homogeneous(
        cls, n_gpus: int, device: DeviceModel = A100_80GB, prefix: str = "gpu"
    ) -> "ClusterState":
        return cls(
            gpus={
                f"{prefix}{i}": GPUState(f"{prefix}{i}", device)
                for i in range(n_gpus)
            }
        )

    # ---- lookups ----------------------------------------------------------
    def gpu_of(self, wid: str) -> Optional[str]:
        for gid, gpu in self.gpus.items():
            if any(p.wid == wid for p in gpu.placements):
                return gid
        return None

    def placement_of(self, wid: str) -> Optional[Tuple[str, Placement]]:
        for gid, gpu in self.gpus.items():
            for p in gpu.placements:
                if p.wid == wid:
                    return gid, p
        return None

    def used_gpus(self) -> List[GPUState]:
        return [g for g in self.gpus.values() if not g.is_empty()]

    def free_gpus(self) -> List[GPUState]:
        return [g for g in self.gpus.values() if g.is_empty()]

    def placed_workloads(self) -> List[Workload]:
        out = []
        for gpu in self.gpus.values():
            for p in gpu.placements:
                out.append(self.workloads[p.wid])
        return out

    def ordered_gids(self) -> List[str]:
        return sorted(self.gpus.keys())

    # ---- transactional mutators -------------------------------------------
    def transaction(self) -> Transaction:
        """Open a (nestable) undo journal; use as ``with state.transaction() as txn``."""
        txn = Transaction(self, self._txns[-1] if self._txns else None)
        self._txns.append(txn)
        return txn

    def _journal(self, op: Tuple) -> None:
        # Nearest OPEN transaction: after an inner rollback() (closed but not
        # yet exited), subsequent ops must still journal to the ancestor so an
        # outer rollback stays byte-identical.
        for txn in reversed(self._txns):
            if not txn.closed:
                txn._record(op)
                return
        # No open txn here: forward to a linked parent state (engine
        # sub-views share GPUState objects and the workload dict with their
        # parent, so the parent's journal can undo these ops directly).
        parent = self.__dict__.get("_journal_parent")
        if parent is not None:
            parent._journal(op)

    def link_journal_parent(self, parent: Optional["ClusterState"]) -> None:
        """Forward journal ops to ``parent`` when no local txn is open.

        Used for engine sub-views: the view shares ``GPUState`` objects and
        the workloads dict with ``parent``, so ops recorded on the view are
        undoable through the parent's transactions.
        """
        self.__dict__["_journal_parent"] = parent

    def add_workload(self, w: Workload) -> None:
        self._journal(("add_wl", w.wid, self.workloads.get(w.wid)))
        self.workloads[w.wid] = w

    def forget_workload(self, wid: str) -> Optional[Workload]:
        """Journaled deregistration (fault eviction: the replica leaves the
        system, but a transaction rollback restores it byte-identically)."""
        prev = self.workloads.pop(wid, None)
        if prev is not None:
            self._journal(("add_wl", wid, prev))
        return prev

    def set_health(self, gid: str, health: str) -> None:
        """Journaled GPU health mark (see ``HEALTH_STATES``)."""
        if health not in HEALTH_STATES:
            raise ValueError(
                f"health must be one of {HEALTH_STATES}, got {health!r}"
            )
        gpu = self.gpus[gid]
        if gpu.health == health:
            return
        self._journal(("health", gid, gpu.health))
        gpu.health = health

    def place(
        self, wid: str, gid: str, index: int, profile_id: Optional[int] = None
    ) -> Placement:
        if profile_id is None:
            profile_id = self.workloads[wid].profile_id
        pl = self.gpus[gid].place(wid, profile_id, index)
        self._journal(("place", gid, pl))
        return pl

    def remove(self, wid: str, gid: Optional[str] = None) -> Placement:
        """Journaled unplacement (the workload stays registered)."""
        if gid is None:
            gid = self.gpu_of(wid)
            if gid is None:
                raise KeyError(f"workload {wid} is not placed")
        gpu = self.gpus[gid]
        at = next((i for i, p in enumerate(gpu.placements) if p.wid == wid), None)
        if at is None:
            raise KeyError(f"{gid}: workload {wid} not placed here")
        pl = gpu.remove(wid)
        self._journal(("remove", gid, pl, at))
        return pl

    def adopt(self, layout: "ClusterState") -> None:
        """Diff-apply ``layout``'s placements onto this state, journaled.

        Solver policies (MIP, patterns, fresh-replay reconfigurations) build
        their result in a scratch state; ``adopt`` lands it here through the
        cluster-level mutators, so the change is (a) journaled — an engine
        transaction can reject the whole plan with an O(ops) rollback — and
        (b) identity-preserving: ``GPUState`` objects are never swapped out,
        which keeps sub-views and fabric mirrors valid.

        Workloads registered in ``layout`` are registered here; placements
        present here but moved/absent in ``layout`` are removed before the
        new spots are filled.
        """
        want: Dict[str, Tuple[str, Placement]] = {
            p.wid: (gid, p)
            for gid, g in layout.gpus.items()
            for p in g.placements
        }
        have: Dict[str, Tuple[str, Placement]] = {
            p.wid: (gid, p)
            for gid, g in self.gpus.items()
            for p in g.placements
        }
        for wid, w in layout.workloads.items():
            if self.workloads.get(wid) != w:
                self.add_workload(w)
        for wid, (gid, pl) in have.items():
            if want.get(wid) != (gid, pl):
                self.remove(wid, gid)
        for wid, (gid, pl) in want.items():
            if have.get(wid) != (gid, pl):
                self.place(wid, gid, pl.index, profile_id=pl.profile_id)

    def clone(self) -> "ClusterState":
        return ClusterState(
            gpus={gid: g.clone() for gid, g in self.gpus.items()},
            workloads=dict(self.workloads),
        )

    def validate(self) -> None:
        """Raise if any GPU has overlapping/illegal placements."""
        for gpu in self.gpus.values():
            gpu._occupancy()
            for p in gpu.placements:
                prof = gpu.device.profile(p.profile_id)
                if p.index not in prof.allowed_indexes:
                    raise ValueError(
                        f"{gpu.gid}: {prof.name} at illegal index {p.index}"
                    )
