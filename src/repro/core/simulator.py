"""Cluster simulation and random test-case generation (paper Sec 5.1).

A test case mimics a cluster with one or more 8-GPU nodes:
  * ~60% of GPUs allocated, the rest free;
  * each allocated GPU gets a random target utilization (up to 100%) and is
    filled with randomly drawn profile workloads placed at preference-order
    indexes until the target is met;
  * for the initial-deployment use case, new workloads totalling ~60% of the
    whole cluster's memory-slice capacity are generated.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from .fleetgen import build_fleet
from .profiles import A100_80GB, DeviceModel
from .state import ClusterState, Workload

__all__ = ["TestCase", "generate_test_case", "random_workloads"]

#: profiles drawn for random workloads (paper Table 1, excl. the full-GPU
#: profile 0 — a 7g.80gb replica trivially owns a GPU and adds no packing
#: signal — and the rare +me profile 20 by default).
_DEFAULT_PROFILE_POOL = (5, 9, 14, 15, 19)


@dataclasses.dataclass
class TestCase:
    name: str
    initial: ClusterState
    new_workloads: List[Workload]


def random_workloads(
    rng: np.random.Generator,
    total_memory_slices: int,
    device: DeviceModel = A100_80GB,
    prefix: str = "new",
    pool: Sequence[int] = _DEFAULT_PROFILE_POOL,
) -> List[Workload]:
    """Random profile workloads summing to ~total_memory_slices memory."""
    out: List[Workload] = []
    used = 0
    i = 0
    while used < total_memory_slices:
        pid = int(rng.choice(pool))
        prof = device.profile(pid)
        if used + prof.memory_slices > total_memory_slices:
            # close the gap with the smallest profile
            pid = pool[-1]
            prof = device.profile(pid)
            if used + prof.memory_slices > total_memory_slices:
                break
        out.append(Workload(wid=f"{prefix}{i}", profile_id=pid))
        used += prof.memory_slices
        i += 1
    return out


def generate_test_case(
    seed: int,
    n_gpus: int = 8,
    device: DeviceModel = A100_80GB,
    allocated_fraction: float = 0.6,
    new_workload_fraction: float = 0.6,
    pool: Sequence[int] = _DEFAULT_PROFILE_POOL,
) -> TestCase:
    """One Sec-5.1 test case (seeded, reproducible)."""
    rng = np.random.default_rng(seed)
    # Shared fleet builder (fleetgen) with the historical 'gpu{i}' naming.
    state = build_fleet([(device, n_gpus)], gid_format="gpu{i}")
    gids = state.ordered_gids()
    n_alloc = int(round(n_gpus * allocated_fraction))
    alloc_gids = list(rng.choice(gids, size=n_alloc, replace=False))

    wi = 0
    for gid in alloc_gids:
        gpu = state.gpus[gid]
        target = rng.uniform(0.2, 1.0)  # random utilization up to 100%
        # fill with random workloads until target joint utilization reached
        attempts = 0
        while gpu.joint_slice_utilization() < target and attempts < 20:
            pid = int(rng.choice(pool))
            prof = device.profile(pid)
            idx = gpu.first_feasible_index(prof)
            if idx is None:
                attempts += 1
                continue
            w = Workload(wid=f"w{wi}", profile_id=pid)
            state.add_workload(w)
            gpu.place(w.wid, pid, idx)
            wi += 1
    # New workloads ~ fraction of total cluster memory capacity.
    total_mem = n_gpus * device.n_memory_slices
    news = random_workloads(
        rng, int(total_mem * new_workload_fraction), device, pool=pool
    )
    return TestCase(name=f"case{seed}-{n_gpus}gpu", initial=state, new_workloads=news)
