"""Core: the paper's contribution — MIG workload placement optimization.

Public API:
    profiles     — Table-1 device/profile geometry (A100/H100)
    tpu_profiles — TPU pod-partition adaptation
    state        — Workload / Placement / GPUState / ClusterState
    preprocess   — Algorithm 1 (free partitions P_g)
    indexing     — bin-level solution -> concrete slice indexes
    wpm_mip      — the WPM mixed-integer program (Eqns 2a-2k)
    heuristic    — Sec-4.2 rule-based placement (3 use cases)
    baselines    — first-fit / load-balanced schedulers
    patterns     — beyond-paper pattern-enumeration exact solver
    metrics      — Table-3 evaluation metrics
    migration    — migration planning (one-shot vs sequential)
    simulator    — Sec-5.1 random test-case generation
    fleetgen     — shared (possibly heterogeneous) fleet construction
    engine       — PlacementEngine: all approaches behind one interface
    events       — event-driven online simulation over timestamped traces
    fabric       — vectorized fleet-scale feasibility/scoring (JAX-batched)
    traffic      — seeded request-arrival generators (demand axis)
    perfmodel    — per-partition service rates (prefill/decode tokens/s)
    autoscaler   — SLO-aware replica controller (offered load -> targets)
    faults       — seeded fault injection (GPU/slice failures, drains)
"""
from .autoscaler import SLO, Autoscaler, AutoscalerConfig  # noqa: F401
from .engine import EngineResult, PlacementEngine, available_policies  # noqa: F401
from .faults import FaultEvent, FaultInjector, FaultSpec  # noqa: F401
from .perfmodel import PerfModel  # noqa: F401
from .profiles import A100_80GB, H100_96GB, DeviceModel, Profile  # noqa: F401
from .state import (  # noqa: F401
    HEALTH_STATES,
    ClusterState,
    GPUState,
    Placement,
    Transaction,
    Workload,
)
from .traffic import ModelTraffic, RequestTrace, generate_requests  # noqa: F401
