"""Index assignment: bin-level solutions -> concrete slice indexes.

Assumption 1 (paper Sec 4) lets the MIP reason at bin-packing level; this
module is the "indexing step" that follows.  Given a multiset of profiles to
realise on a GPU (possibly with immovable pre-existing placements), find a
feasible assignment of start indexes honoring Table-1 allowed indexes, the
preference order, and non-overlap.

The search is exact (backtracking) but tiny: <= 7 placements per GPU and
<= 7 candidate indexes per placement.  Profiles are placed big-first and
preference-first, which empirically lands on the paper's "preferred" layouts
(e.g. 3g.40gb at index 4, 1g.20gb at index 6) and minimizes wastage; among
feasible completions we keep the one with minimal (compute waste, memory
waste, fragmentation).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from .profiles import DeviceModel, Profile
from .state import GPUState, Placement

__all__ = [
    "assign_indexes",
    "best_index_for",
    "feasible_multiset",
    "enumerate_feasible_multisets",
]


def _waste_key(gpu: GPUState) -> Tuple[int, int, int]:
    """Lexicographic quality of a concrete layout (lower is better)."""
    # Fragmentation: number of maximal free runs (fewer, longer runs are
    # better for future availability — paper objective 3).
    free = gpu.free_gpu_slices()
    runs = 0
    prev = None
    for i in free:
        if prev is None or i != prev + 1:
            runs += 1
        prev = i
    return (gpu.compute_waste(), gpu.memory_waste(), runs)


def assign_indexes(
    gpu: GPUState,
    profile_ids: Sequence[int],
    wids: Optional[Sequence[str]] = None,
    optimize: bool = True,
) -> Optional[List[Placement]]:
    """Place ``profile_ids`` (a multiset) onto ``gpu`` atop existing placements.

    Returns the new placements (in input order) or None if infeasible.
    ``gpu`` is not mutated.  With ``optimize=True`` the minimal-waste feasible
    layout is returned; otherwise the first found (preference order).
    """
    device = gpu.device
    if wids is None:
        wids = [f"_w{i}" for i in range(len(profile_ids))]
    order = sorted(
        range(len(profile_ids)),
        key=lambda i: device.profile(profile_ids[i]).sort_key,
    )  # big -> small

    best: Optional[Tuple[Tuple[int, int, int], List[Placement]]] = None
    scratch = gpu.clone()
    chosen: Dict[int, Placement] = {}

    def bt(pos: int) -> bool:
        nonlocal best
        if pos == len(order):
            key = _waste_key(scratch)
            if best is None or key < best[0]:
                best = (key, [chosen[i] for i in range(len(profile_ids))])
            return not optimize  # stop at first solution unless optimizing
        i = order[pos]
        prof = device.profile(profile_ids[i])
        for idx in prof.allowed_indexes:
            if scratch.can_place_at(prof, idx):
                pl = scratch.place(wids[i], prof.profile_id, idx)
                chosen[i] = pl
                if bt(pos + 1):
                    return True
                scratch.placements.remove(pl)
                del chosen[i]
        return False

    bt(0)
    return None if best is None else best[1]


def best_index_for(gpu: GPUState, profile: Profile) -> Optional[int]:
    """Preference-order first feasible index for one profile (Table 1)."""
    return gpu.first_feasible_index(profile)


def feasible_multiset(device: DeviceModel, counts: Dict[int, int]) -> bool:
    """Can this multiset of profiles be realised at concrete indexes?"""
    gpu = GPUState("_probe", device)
    flat: List[int] = []
    for pid, n in counts.items():
        flat.extend([pid] * n)
    return assign_indexes(gpu, flat, optimize=False) is not None


def enumerate_feasible_multisets(
    device: DeviceModel,
) -> List[Dict[int, int]]:
    """All index-feasible profile multisets for an empty device.

    Used by the pattern-enumeration solver (beyond-paper) and by the
    Assumption-1 validation test.  The count is small (a few dozen for A100).
    """
    profs = device.profiles_sorted_desc()
    out: List[Dict[int, int]] = []

    def rec(i: int, counts: Dict[int, int]) -> None:
        if i == len(profs):
            if counts and feasible_multiset(device, counts):
                out.append(dict(counts))
            return
        p = profs[i]
        max_n = min(
            device.n_gpu_slices // max(p.compute_slices, 1),
            device.n_memory_slices // max(p.memory_slices, 1),
        )
        if p.media_extensions:
            max_n = min(max_n, device.max_media_extensions)
        for n in range(max_n + 1):
            if n:
                counts[p.profile_id] = n
            elif p.profile_id in counts:
                del counts[p.profile_id]
            trial = {**counts}
            if device.fits(trial):
                rec(i + 1, counts)
            if n and p.profile_id in counts:
                del counts[p.profile_id]
        return

    rec(0, {})
    # dedupe (profile ids may repeat names but ids are unique)
    seen = set()
    uniq = []
    for c in out:
        key = tuple(sorted(c.items()))
        if key not in seen:
            seen.add(key)
            uniq.append(c)
    return uniq
