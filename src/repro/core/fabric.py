"""Vectorized placement fabric: JAX-batched feasibility/scoring (fleet scale).

The scalar placement core (``state.py`` / ``baselines.py`` / ``heuristic.py``)
checks one (gpu, index, profile) candidate at a time — fine for the paper's
8–80 GPU evaluation, quadratic pain for the ROADMAP's fleets of thousands of
devices.  This module keeps a *dense array mirror* of the whole fleet and
answers feasibility/scoring queries for **all** (gpu, start-index, profile)
triples in one batched kernel call:

  * ``FleetFabric``   — one row per GPU, padded across heterogeneous
                        ``DeviceModel``s: occupancy bitmask ``occ[g, m]``,
                        per-row slice counts, media-extension budgets, and
                        per-device profile tables (memory/compute spans,
                        Table-1 allowed-index masks, preference ranks).
  * feasibility       — a jitted, ``vmap``-batched kernel reproducing
                        ``GPUState.can_place_at`` exactly: allowed-index,
                        span-fit (incl. the m7 attachment rule, which falls
                        out of the span arithmetic), overlap, and
                        media-extension constraints.
  * scoring           — fragmentation-aware placement scores per Ting et al.
                        ("An Online Fragmentation-Aware Scheduler ..."):
                        post-placement free-run fragmentation delta plus
                        compute/memory wastage (slice-6 truncation, m7
                        stranding).
  * fast paths        — ``fabric_first_fit`` / ``fabric_load_balanced`` /
                        ``fabric_initial_deployment`` are placement-identical
                        to their scalar references (tie-breaks included) but
                        replace the per-candidate Python scan with one kernel
                        sweep per workload; ``fabric_frag_aware_*`` implement
                        the new ``frag_aware`` policy.

Parity contract
---------------
For any ``ClusterState``, ``FleetFabric(state).feasible_all()[g, p, i]`` is
True iff ``state.gpus[gid_g].can_place_at(profile_p, i)`` — property-tested
in ``tests/test_fabric.py`` on randomized heterogeneous fleets.  The fast
paths must pick byte-identical (gid, index) spots to the scalar policies.

JAX is optional: kernels are written against the array-API subset shared by
``numpy`` and ``jax.numpy``; with JAX present the batched variants are
``jax.jit``-compiled (shapes are static per fleet, so each fleet shape
compiles once), otherwise the numpy instantiation runs.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import get_telemetry
from .profiles import DeviceModel, Profile
from .state import ClusterState, Placement, Workload

try:  # JAX is an optional dependency of the placement core.
    import jax
    import jax.numpy as jnp

    _HAVE_JAX = True
except ImportError:  # pragma: no cover - exercised on JAX-free installs
    jax = None
    jnp = None
    _HAVE_JAX = False

__all__ = [
    "FleetFabric",
    "fleet_fabric",
    "fabric_first_fit",
    "fabric_load_balanced",
    "fabric_initial_deployment",
    "fabric_frag_aware_deploy",
    "fabric_frag_aware_compact",
    "fabric_frag_aware_reconfigure",
    "replay_fresh_deploy",
    "have_jax",
]

#: preference rank sentinel for disallowed (profile, index) pairs.
_NO_RANK = np.int32(32767)


def have_jax() -> bool:
    return _HAVE_JAX


# ---------------------------------------------------------------------------
# kernels (written once against the numpy/jax.numpy shared API)
# ---------------------------------------------------------------------------
def _feasible_kernel(xp, occ, n_mem, me_used, me_cap, mem_sl, me_req, allowed, mask):
    """Feasibility of one profile at every (gpu, index).

    occ (G, M) bool, n_mem/me_used/me_cap (G,), mem_sl/me_req scalars,
    allowed (I,) bool, mask (G,) bool (candidate rows) -> (G, I) bool.

    Reproduces ``GPUState.can_place_at``: index allowed, span inside the
    device's memory positions, span free, media-extension budget respected.
    """
    M = occ.shape[1]
    idx = xp.arange(M)
    pos = xp.arange(M)
    span = (pos[None, :] >= idx[:, None]) & (pos[None, :] < idx[:, None] + mem_sl)
    overlap = (occ[:, None, :] & span[None, :, :]).any(axis=-1)  # (G, I)
    fits = idx[None, :] + mem_sl <= n_mem[:, None]  # (G, I)
    me_ok = me_used + me_req <= me_cap  # (G,)
    return allowed[None, :] & fits & ~overlap & me_ok[:, None] & mask[:, None]


def _score_kernel(xp, occ, n_mem, n_gpu, extra_mem, mem_sl, cmp_sl):
    """Fragmentation/wastage scores of one profile at every (gpu, index).

    Returns (waste_delta, frag_runs_after), both (G, I) int32:

    * ``waste_delta``    — compute slices blocked-but-unusable by the span
                           (slice-6 truncation, paper 3.2.3) plus the change
                           in m7 stranding this placement causes.
    * ``frag_runs_after``— number of maximal free runs in the post-placement
                           occupancy (fewer/longer runs = less fragmented,
                           Ting et al.'s free-space health).

    Only meaningful where the placement is feasible; callers mask.
    """
    M = occ.shape[1]
    idx = xp.arange(M)
    pos = xp.arange(M)
    span = (pos[None, :] >= idx[:, None]) & (pos[None, :] < idx[:, None] + mem_sl)
    post = occ[:, None, :] | span[None, :, :]  # (G, I, M)

    # free runs after placement (padding rows of occ are pre-marked occupied,
    # so runs never cross the device's real memory boundary).
    free = ~post
    prev = xp.concatenate(
        [xp.zeros_like(free[..., :1]), free[..., :-1]], axis=-1
    )
    runs_after = (free & ~prev).sum(axis=-1).astype(xp.int32)  # (G, I)

    # compute wastage of the span itself: GPU slices covered minus compute.
    gpu_cover = xp.minimum(idx[None, :] + mem_sl, n_gpu[:, None]) - idx[None, :]
    waste_c = (gpu_cover - cmp_sl).astype(xp.int32)  # (G, I)

    # m7 stranding delta (extra-memory devices only): slice n_gpu-1 held
    # while position n_mem-1 stays free -> 1 stranded memory position.
    last_gpu = xp.take_along_axis(
        post, (n_gpu - 1)[:, None, None], axis=2
    )[..., 0]
    extra_pos = xp.take_along_axis(
        post, (n_mem - 1)[:, None, None], axis=2
    )[..., 0]
    stranded_after = (last_gpu & ~extra_pos) & extra_mem[:, None]
    occ_last = xp.take_along_axis(occ, (n_gpu - 1)[:, None], axis=1)[..., 0]
    occ_extra = xp.take_along_axis(occ, (n_mem - 1)[:, None], axis=1)[..., 0]
    stranded_before = (occ_last & ~occ_extra) & extra_mem
    waste_delta = waste_c + stranded_after.astype(xp.int32) - stranded_before[
        :, None
    ].astype(xp.int32)
    return waste_delta, runs_after


_feasible_np = functools.partial(_feasible_kernel, np)
_score_np = functools.partial(_score_kernel, np)

if _HAVE_JAX:
    #: all-profiles variants: vmap over the profile axis of the per-profile
    #: kernels -> (G, P, I) for the whole fleet in one compiled sweep.
    _feasible_all_jit = jax.jit(
        jax.vmap(
            functools.partial(_feasible_kernel, jnp),
            in_axes=(None, None, None, None, 0, 0, 0, None),
            out_axes=1,
        )
    )
    _score_all_jit = jax.jit(
        jax.vmap(
            functools.partial(_score_kernel, jnp),
            in_axes=(None, None, None, None, 0, 0),
            out_axes=1,
        )
    )


def _feasible_all_np(occ, n_mem, me_used, me_cap, mem_sl, me_req, allowed, mask):
    return np.stack(
        [
            _feasible_np(
                occ, n_mem, me_used, me_cap, mem_sl[p], me_req[p], allowed[p], mask
            )
            for p in range(len(mem_sl))
        ],
        axis=1,
    )


def _score_all_np(occ, n_mem, n_gpu, extra_mem, mem_sl, cmp_sl):
    per = [
        _score_np(occ, n_mem, n_gpu, extra_mem, mem_sl[p], cmp_sl[p])
        for p in range(len(mem_sl))
    ]
    return (
        np.stack([w for w, _ in per], axis=1),
        np.stack([f for _, f in per], axis=1),
    )


# ---------------------------------------------------------------------------
# per-device-kind profile tables
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class _KindTable:
    device: DeviceModel
    #: profile-id -> slot (row in the arrays below; == position in device.profiles)
    slot_of: Dict[int, int]
    mem_sl: np.ndarray  # (P,) int32
    cmp_sl: np.ndarray  # (P,) int32
    me_req: np.ndarray  # (P,) int32
    allowed: np.ndarray  # (P, I) bool
    pref_rank: np.ndarray  # (P, I) int32; _NO_RANK where disallowed


def _kind_table(device: DeviceModel, n_idx: int) -> _KindTable:
    profs = device.profiles
    P = len(profs)
    mem_sl = np.zeros(P, np.int32)
    cmp_sl = np.zeros(P, np.int32)
    me_req = np.zeros(P, np.int32)
    allowed = np.zeros((P, n_idx), bool)
    pref = np.full((P, n_idx), _NO_RANK, np.int32)
    for p, prof in enumerate(profs):
        mem_sl[p] = prof.memory_slices
        cmp_sl[p] = prof.compute_slices
        me_req[p] = prof.media_extensions
        for rank, i in enumerate(prof.allowed_indexes):
            if i < n_idx:
                allowed[p, i] = True
                pref[p, i] = rank
    return _KindTable(
        device=device,
        slot_of={prof.profile_id: p for p, prof in enumerate(profs)},
        mem_sl=mem_sl,
        cmp_sl=cmp_sl,
        me_req=me_req,
        allowed=allowed,
        pref_rank=pref,
    )


# ---------------------------------------------------------------------------
# the fabric
# ---------------------------------------------------------------------------
class FleetFabric:
    """Dense array mirror of a ``ClusterState`` (rows in sorted-gid order).

    The mirror is built once (O(G·M)) and updated incrementally through
    ``apply`` / ``unapply`` as the caller mutates the backing state.

    Feasibility and scores for **all** (gpu, profile, index) triples are
    computed by one batched kernel sweep (``feasible_all`` / ``scores_all``)
    and cached; a placement changes exactly one row, so ``apply``/``unapply``
    refresh that row alone (O(P·I·M) scalar work).  Spot picking is then a
    pure O(G) reduction per workload — no per-candidate Python scanning and
    no kernel dispatch inside the sequential deploy loop.
    """

    def __init__(self, state: ClusterState, use_jax: Optional[bool] = None):
        self.use_jax = _HAVE_JAX if use_jax is None else (use_jax and _HAVE_JAX)
        self.gids: List[str] = state.ordered_gids()
        self.row_of: Dict[str, int] = {g: r for r, g in enumerate(self.gids)}
        devices: List[DeviceModel] = [state.gpus[g].device for g in self.gids]
        #: max memory positions across kinds == index grid size (padded rows).
        self.M = max((d.n_memory_slices for d in devices), default=1)

        self.kinds: List[str] = []
        self.tables: Dict[str, _KindTable] = {}
        kind_id = np.zeros(len(self.gids), np.int32)
        for r, dev in enumerate(devices):
            if dev.name not in self.tables:
                self.tables[dev.name] = _kind_table(dev, self.M)
                self.kinds.append(dev.name)
            kind_id[r] = self.kinds.index(dev.name)
        self.kind_id = kind_id

        G = len(self.gids)
        self.occ = np.ones((G, self.M), bool)  # padding stays occupied
        self.n_mem = np.zeros(G, np.int32)
        self.n_gpu = np.zeros(G, np.int32)
        self.me_cap = np.zeros(G, np.int32)
        self.me_used = np.zeros(G, np.int32)
        self.used_mem = np.zeros(G, np.int32)
        self.used_cmp = np.zeros(G, np.int32)
        self.extra_mem = np.zeros(G, bool)
        self.n_placements = np.zeros(G, np.int32)
        for r, gid in enumerate(self.gids):
            gpu = state.gpus[gid]
            dev = gpu.device
            self.n_mem[r] = dev.n_memory_slices
            self.n_gpu[r] = dev.n_gpu_slices
            self.me_cap[r] = dev.max_media_extensions
            self.extra_mem[r] = dev.extra_memory
            occ_row = gpu.memory_occupancy()
            self.occ[r, : dev.n_memory_slices] = [o is not None for o in occ_row]
            self.me_used[r] = gpu.media_extensions_used()
            self.used_mem[r] = gpu.used_memory_slices()
            self.used_cmp[r] = gpu.used_compute_slices()
            self.n_placements[r] = len(gpu.placements)

        self.P_max = max(
            (len(t.device.profiles) for t in self.tables.values()), default=1
        )
        #: lazily-built all-triple caches, row-refreshed on apply/unapply.
        self._feas: Optional[np.ndarray] = None  # (G, P_max, I) bool
        self._waste: Optional[np.ndarray] = None  # (G, P_max, I) int32
        self._frag: Optional[np.ndarray] = None  # (G, P_max, I) int32
        #: per-row placement snapshots for cross-call sync(); None = the row
        #: was mutated through apply/unapply and re-syncs from the state.
        self._snaps: List[Optional[Tuple[Placement, ...]]] = [
            tuple(state.gpus[g].placements) for g in self.gids
        ]

    # -- bookkeeping ---------------------------------------------------------
    def _table_for(self, kind: Optional[str]) -> _KindTable:
        if kind is None:
            if len(self.tables) > 1:
                raise ValueError(
                    "profile kind is ambiguous on a mixed fleet; pass device_kind"
                )
            kind = self.kinds[0]
        return self.tables[kind]

    def _profile(self, profile_id: int, kind: Optional[str]) -> Tuple[_KindTable, int]:
        tab = self._table_for(kind)
        return tab, tab.slot_of[profile_id]

    def kind_mask(self, kind: Optional[str]) -> np.ndarray:
        if kind is None:
            return np.ones(len(self.gids), bool)
        return self.kind_id == self.kinds.index(kind)

    def apply(self, gid: str, profile: Profile, index: int) -> None:
        """Mirror a ``state.place`` the caller just performed."""
        r = self.row_of[gid]
        self.occ[r, index : index + profile.memory_slices] = True
        self.used_mem[r] += profile.memory_slices
        self.used_cmp[r] += profile.compute_slices
        self.me_used[r] += profile.media_extensions
        self.n_placements[r] += 1
        self._snaps[r] = None
        self._refresh_row(r)

    def unapply(self, gid: str, profile: Profile, index: int) -> None:
        """Mirror a ``state.remove`` the caller just performed."""
        r = self.row_of[gid]
        self.occ[r, index : index + profile.memory_slices] = False
        self.used_mem[r] -= profile.memory_slices
        self.used_cmp[r] -= profile.compute_slices
        self.me_used[r] -= profile.media_extensions
        self.n_placements[r] -= 1
        self._snaps[r] = None
        self._refresh_row(r)

    def _rebuild_row(self, r: int, gpu) -> None:
        """Re-read one row's mirrors straight from its GPUState."""
        dev = gpu.device
        self.occ[r, :] = True
        occ_row = gpu.memory_occupancy()
        self.occ[r, : dev.n_memory_slices] = [o is not None for o in occ_row]
        self.me_used[r] = gpu.media_extensions_used()
        self.used_mem[r] = gpu.used_memory_slices()
        self.used_cmp[r] = gpu.used_compute_slices()
        self.n_placements[r] = len(gpu.placements)
        self._refresh_row(r)

    def sync(self, state: ClusterState) -> bool:
        """Refresh rows whose placements changed since the last build/sync.

        Returns False when the fleet's shape changed (gids or device models)
        and the mirror must be rebuilt from scratch.  Steady-state cost is
        one O(placements) tuple snapshot per row; only mutated rows pay the
        O(P·I·M) slab refresh — this is what makes one persistent fabric per
        ClusterState (``fleet_fabric``) cheap across online arrival events.
        """
        if self.gids != state.ordered_gids():
            return False
        tel = get_telemetry()
        t0 = time.perf_counter() if tel.enabled else 0.0
        refreshed = 0
        for r, gid in enumerate(self.gids):
            gpu = state.gpus[gid]
            if gpu.device.name != self.kinds[self.kind_id[r]]:
                return False
            snap = tuple(gpu.placements)
            if snap != self._snaps[r]:
                self._rebuild_row(r, gpu)
                self._snaps[r] = snap
                refreshed += 1
        if tel.enabled:
            tel.metrics.histogram(
                "fabric_refresh_seconds",
                "per-sync cost of refreshing mutated fabric rows",
            ).observe(time.perf_counter() - t0)
            if refreshed:
                tel.metrics.counter(
                    "fabric_rows_refreshed_total",
                    "fabric rows rebuilt from their GPUState",
                ).inc(refreshed)
        return True

    def _refresh_row(self, r: int) -> None:
        """Recompute the cached all-triple slabs for one mutated row."""
        tab = self.tables[self.kinds[self.kind_id[r]]]
        sl = slice(r, r + 1)
        one = np.ones(1, bool)
        if self._feas is not None:
            got = _feasible_all_np(
                self.occ[sl], self.n_mem[sl], self.me_used[sl], self.me_cap[sl],
                tab.mem_sl, tab.me_req, tab.allowed, one,
            )
            self._feas[r] = False
            self._feas[r, : got.shape[1]] = got[0]
        if self._waste is not None:
            w, f = _score_all_np(
                self.occ[sl], self.n_mem[sl], self.n_gpu[sl], self.extra_mem[sl],
                tab.mem_sl, tab.cmp_sl,
            )
            self._waste[r, : w.shape[1]] = w[0]
            self._frag[r, : f.shape[1]] = f[0]

    def util(self) -> np.ndarray:
        """Joint slice utilization per row; bit-identical to the scalar
        ``GPUState.joint_slice_utilization`` (same int operands, float64)."""
        return (self.used_mem + self.used_cmp) / (self.n_mem + self.n_gpu)

    # -- batched kernels + all-triple caches ---------------------------------
    def _feas_cache(self) -> np.ndarray:
        if self._feas is None:
            self._feas = self._sweep_feasible()
        return self._feas

    def _score_cache(self) -> Tuple[np.ndarray, np.ndarray]:
        if self._waste is None:
            self._waste, self._frag = self._sweep_scores()
        return self._waste, self._frag

    def _sweep_feasible(self) -> np.ndarray:
        """One batched kernel sweep: (G, P_max, I) feasibility, all triples."""
        tel = get_telemetry()
        t0 = time.perf_counter() if tel.enabled else 0.0
        G = len(self.gids)
        out = np.zeros((G, self.P_max, self.M), bool)
        for kind in self.kinds:
            tab = self.tables[kind]
            row_mask = self.kind_mask(kind if len(self.tables) > 1 else None)
            args = (
                self.occ, self.n_mem, self.me_used, self.me_cap,
                tab.mem_sl, tab.me_req, tab.allowed, row_mask,
            )
            got = (
                np.asarray(_feasible_all_jit(*args))
                if self.use_jax
                else _feasible_all_np(*args)
            )
            out[:, : got.shape[1], :] |= got
        if tel.enabled:
            tel.metrics.histogram(
                "fabric_score_seconds",
                "batched kernel sweep time over all (gpu, profile, index) triples",
                labels={"kernel": "feasible"},
            ).observe(time.perf_counter() - t0)
        return out

    def _sweep_scores(self) -> Tuple[np.ndarray, np.ndarray]:
        """One batched kernel sweep: (G, P_max, I) waste_delta + frag runs."""
        tel = get_telemetry()
        t0 = time.perf_counter() if tel.enabled else 0.0
        G = len(self.gids)
        waste = np.zeros((G, self.P_max, self.M), np.int32)
        frag = np.zeros((G, self.P_max, self.M), np.int32)
        for kind in self.kinds:
            tab = self.tables[kind]
            rows = self.kind_mask(kind if len(self.tables) > 1 else None)
            args = (
                self.occ, self.n_mem, self.n_gpu, self.extra_mem,
                tab.mem_sl, tab.cmp_sl,
            )
            if self.use_jax:
                w, f = _score_all_jit(*args)
                w, f = np.asarray(w), np.asarray(f)
            else:
                w, f = _score_all_np(*args)
            P = w.shape[1]
            waste[rows, :P] = w[rows]
            frag[rows, :P] = f[rows]
        if tel.enabled:
            tel.metrics.histogram(
                "fabric_score_seconds",
                "batched kernel sweep time over all (gpu, profile, index) triples",
                labels={"kernel": "score"},
            ).observe(time.perf_counter() - t0)
        return waste, frag

    def feasible_all(self) -> np.ndarray:
        """(G, P_max, I) feasibility for every (gpu, profile-slot, index).

        Profile slot ``p`` of row ``g`` refers to ``device.profiles[p]`` for
        that row's device; slots past the device's profile count are
        all-False.  Returns a copy; the cached slab is maintained
        incrementally across ``apply``/``unapply``.
        """
        return self._feas_cache().copy()

    def feasible_profile(
        self,
        profile_id: int,
        kind: Optional[str] = None,
        mask: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """(G, I) feasibility of one profile at every (gpu, index)."""
        tab, p = self._profile(profile_id, kind)
        feas = self._feas_cache()[:, p, :]
        if len(self.tables) > 1:
            feas = feas & self.kind_mask(kind)[:, None]
        if mask is not None:
            feas = feas & mask[:, None]
        return feas

    def scores_profile(
        self, profile_id: int, kind: Optional[str] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(waste_delta, frag_runs_after), both (G, I), for one profile."""
        tab, p = self._profile(profile_id, kind)
        waste, frag = self._score_cache()
        return waste[:, p, :], frag[:, p, :]

    # -- spot picking (host-side selection over kernel output) ---------------
    def pick_first_fit(
        self, profile_id: int, kind: Optional[str] = None
    ) -> Optional[Tuple[str, int]]:
        """Scalar-parity first-fit spot: first gid (sorted), lowest index."""
        feas = self.feasible_profile(profile_id, kind)
        rows = feas.any(axis=1).nonzero()[0]
        if not rows.size:
            return None
        r = int(rows[0])
        return self.gids[r], int(feas[r].argmax())

    def pick_load_balanced(
        self, profile_id: int, kind: Optional[str] = None
    ) -> Optional[Tuple[str, int]]:
        """Scalar-parity load-balanced spot: min (util, gid), lowest index."""
        feas = self.feasible_profile(profile_id, kind)
        any_feas = feas.any(axis=1)
        if not any_feas.any():
            return None
        util = self.util()
        # rows are in sorted-gid order, so the first minimal-util feasible
        # row is exactly sorted(key=(util, gid))[0] of the scalar path.
        masked = np.where(any_feas, util, np.inf)
        r = int(masked.argmin())
        return self.gids[r], int(feas[r].argmax())

    def _pref_indexes(
        self, feas: np.ndarray, tab: _KindTable, p: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Per-row first feasible index in Table-1 preference order."""
        rank = np.where(feas, tab.pref_rank[p][None, :], _NO_RANK)
        best_rank = rank.min(axis=1)
        has = best_rank < _NO_RANK
        idx = rank.argmin(axis=1)
        return has, idx

    def pick_max_utilization(
        self,
        profile_id: int,
        kind: Optional[str] = None,
        allow_new_gpu: bool = True,
    ) -> Optional[Tuple[str, int]]:
        """Scalar-parity rule-based spot (``place_max_utilization``): among
        *used* GPUs with a preference-order feasible index, max current
        utilization (ties -> lowest gid); else the first free GPU."""
        tab, p = self._profile(profile_id, kind)
        feas = self.feasible_profile(profile_id, kind)
        has, idx = self._pref_indexes(feas, tab, p)
        used = self.n_placements > 0
        cand = has & used
        if cand.any():
            util = np.where(cand, self.util(), -np.inf)
            r = int(util.argmax())  # first max == lowest gid on ties
            return self.gids[r], int(idx[r])
        if allow_new_gpu:
            free_rows = (has & ~used).nonzero()[0]
            if free_rows.size:
                r = int(free_rows[0])
                return self.gids[r], int(idx[r])
        return None

    def pick_frag_aware(
        self,
        profile_id: int,
        kind: Optional[str] = None,
        mask: Optional[np.ndarray] = None,
        allow_new_gpu: bool = True,
    ) -> Optional[Tuple[str, int]]:
        """Fragmentation-aware spot (Ting et al. scoring, beyond-paper).

        Among used GPUs (free GPUs only as fallback, preserving the
        rule-based GPUs-used discipline), lexicographically minimize

          (waste_delta, frag_runs_after, -utilization, preference rank, gid)

        i.e. first avoid creating wastage, then keep free space contiguous,
        then pack the fullest GPU, then the paper's preferred index.
        """
        tab, p = self._profile(profile_id, kind)
        feas = self.feasible_profile(profile_id, kind, mask=mask)
        if not feas.any():
            return None
        waste, frag = self.scores_profile(profile_id, kind)
        used = self.n_placements > 0
        tiers = [feas & used[:, None]]
        if allow_new_gpu:
            tiers.append(feas & ~used[:, None])
        util = self.util()
        for tier in tiers:
            rows, cols = tier.nonzero()
            if not rows.size:
                continue
            order = np.lexsort(
                (
                    cols,
                    rows,
                    tab.pref_rank[p][cols],
                    -util[rows],
                    frag[rows, cols],
                    waste[rows, cols],
                )
            )
            r, i = int(rows[order[0]]), int(cols[order[0]])
            return self.gids[r], i
        return None


# ---------------------------------------------------------------------------
# persistent per-state mirror
# ---------------------------------------------------------------------------
def fleet_fabric(state: ClusterState, use_jax: Optional[bool] = None) -> FleetFabric:
    """The cached ``FleetFabric`` mirror of ``state`` (built on first use).

    The mirror lives on the ClusterState instance and is row-synced against
    the placement lists on each call, so repeated engine deploys over a
    long-lived fleet (the online-trace hot path: one arrival per deploy) pay
    O(G) sync instead of an O(G·M) rebuild plus full kernel sweep.
    ``clone()`` does not carry the mirror; shape changes trigger a rebuild.
    """
    fab = state.__dict__.get("_fabric_mirror")
    if fab is not None and (use_jax is None or use_jax == fab.use_jax):
        if fab.sync(state):
            return fab
    fab = FleetFabric(state, use_jax=use_jax)
    state.__dict__["_fabric_mirror"] = fab
    return fab


# ---------------------------------------------------------------------------
# vectorized fast-path deploys (placement-identical to the scalar policies)
# ---------------------------------------------------------------------------
def _kind_for(fab: FleetFabric, w: Workload) -> Optional[str]:
    if w.device_kind:
        return w.device_kind
    if len(fab.tables) > 1:
        raise ValueError(
            f"workload {w.wid} has no device_kind on a mixed fleet "
            f"({tuple(fab.kinds)})"
        )
    return None


def _device_of(fab: FleetFabric, w: Workload) -> DeviceModel:
    return fab._table_for(w.device_kind or None).device


def _sequential_deploy(state, workloads, pick, ordered=None):
    """Shared sequential loop: pick a spot per workload, mirror into fabric."""
    fab = fleet_fabric(state)
    if not fab.gids:  # empty fleet: scalar parity = everything pends
        for w in workloads:
            state.add_workload(w)
        return list(workloads)
    pending: List[Workload] = []
    for w in ordered(fab, workloads) if ordered else workloads:
        state.add_workload(w)
        kind = _kind_for(fab, w)
        spot = pick(fab, w, kind)
        if spot is None:
            pending.append(w)
            continue
        gid, idx = spot
        state.place(w.wid, gid, idx)
        fab.apply(gid, _device_of(fab, w).profile(w.profile_id), idx)
    return pending


def fabric_first_fit(
    state: ClusterState, workloads: Sequence[Workload]
) -> List[Workload]:
    """Vectorized ``baselines.first_fit`` (identical placements)."""
    return _sequential_deploy(
        state,
        sorted(workloads, key=lambda w: w.wid),
        lambda fab, w, kind: fab.pick_first_fit(w.profile_id, kind),
    )


def fabric_load_balanced(
    state: ClusterState, workloads: Sequence[Workload]
) -> List[Workload]:
    """Vectorized ``baselines.load_balanced`` (identical placements)."""
    return _sequential_deploy(
        state,
        list(workloads),  # arrival order
        lambda fab, w, kind: fab.pick_load_balanced(w.profile_id, kind),
    )


def _size_sorted(fab: FleetFabric, workloads: Sequence[Workload]):
    return sorted(
        workloads,
        key=lambda w: (_device_of(fab, w).profile(w.profile_id).sort_key, w.wid),
    )


def fabric_initial_deployment(
    state: ClusterState, workloads: Sequence[Workload]
) -> List[Workload]:
    """Vectorized ``heuristic.initial_deployment`` (identical placements)."""
    return _sequential_deploy(
        state,
        workloads,
        lambda fab, w, kind: fab.pick_max_utilization(w.profile_id, kind),
        ordered=_size_sorted,
    )


# ---------------------------------------------------------------------------
# the frag_aware policy verbs (beyond-paper; Ting et al. scoring)
# ---------------------------------------------------------------------------
def fabric_frag_aware_deploy(
    state: ClusterState, workloads: Sequence[Workload]
) -> List[Workload]:
    """Initial deployment minimizing (wastage, fragmentation) per placement."""
    return _sequential_deploy(
        state,
        workloads,
        lambda fab, w, kind: fab.pick_frag_aware(w.profile_id, kind),
        ordered=_size_sorted,
    )


def fabric_frag_aware_compact(state: ClusterState) -> None:
    """Vacate least-utilized GPUs with frag-aware one-shot respotting.

    Same outer loop as the baselines' compaction replay (Sec 5.2.2): walk
    allocated GPUs by ascending joint utilization, try to empty each into the
    other allocated GPUs; all moves must land on spans that were free before
    the vacate began (one-shot migrations, enforced by restricting candidates
    to GPUs that never gain free space during the vacate), else roll back.

    One ``FleetFabric`` mirror persists across the whole compaction: a failed
    vacate rolls the state transaction back and replays the recorded mirror
    ops in reverse, so no candidate sweep ever rebuilds the fabric.
    """
    fab = fleet_fabric(state)
    progress = True
    while progress:
        progress = False
        used = sorted(
            state.used_gpus(), key=lambda g: (g.joint_slice_utilization(), g.gid)
        )
        for gpu in used:
            others = {g.gid for g in state.used_gpus() if g.gid != gpu.gid}
            if not others:
                continue
            cand = np.array([g in others for g in fab.gids])
            journal: List[Tuple[bool, str, Profile, int]] = []  # (placed?, ...)
            with state.transaction() as txn:
                ok = True
                victims = sorted(
                    state.gpus[gpu.gid].placements,
                    key=lambda p: gpu.device.profile(p.profile_id).sort_key,
                )
                for pl in list(victims):
                    w = state.workloads[pl.wid]
                    state.remove(pl.wid, gpu.gid)
                    prof_v = gpu.device.profile(pl.profile_id)
                    fab.unapply(gpu.gid, prof_v, pl.index)
                    journal.append((False, gpu.gid, prof_v, pl.index))
                    spot = fab.pick_frag_aware(
                        w.profile_id, w.device_kind or None,
                        mask=cand, allow_new_gpu=False,
                    )
                    if spot is None:
                        ok = False
                        break
                    dst, idx = spot
                    state.place(w.wid, dst, idx)
                    prof_d = state.gpus[dst].device.profile(w.profile_id)
                    fab.apply(dst, prof_d, idx)
                    journal.append((True, dst, prof_d, idx))
                if not ok:
                    txn.rollback()
                    for placed, gid, prof, idx in reversed(journal):
                        (fab.unapply if placed else fab.apply)(gid, prof, idx)
            if ok:
                progress = True
                break


def replay_fresh_deploy(
    state: ClusterState, deploy_fn, keep_on_pending: bool = False
) -> List[Workload]:
    """Re-place ALL workloads from scratch via ``deploy_fn(fresh, workloads)``
    and splice the fresh layout into ``state`` (shared by the baselines'
    reconfiguration replay and the frag_aware reconfigure).

    With ``keep_on_pending`` the current layout is retained whenever the
    re-placement cannot fit every workload (the Sec-4.2 heuristic's safety
    behavior: a maintenance re-pack must never evict a placed workload);
    otherwise the fresh layout is adopted as-is and the unplaced workloads
    are returned (the baselines' measured Sec-5.2.3 behavior).
    """
    from .state import GPUState  # local import to keep module deps one-way

    workloads = state.placed_workloads()
    fresh = ClusterState(
        gpus={gid: GPUState(gid, state.gpus[gid].device) for gid in state.gpus},
        workloads={w.wid: w for w in workloads},
    )
    pending = deploy_fn(fresh, workloads)
    if pending and keep_on_pending:
        return []
    # Journaled diff-apply: preserves GPUState identity (fabric mirrors and
    # engine sub-views stay valid) and lets an engine-level transaction
    # reject the whole re-pack.
    state.adopt(fresh)
    return pending


def fabric_frag_aware_reconfigure(state: ClusterState) -> List[Workload]:
    """Re-place everything from scratch with frag-aware scoring; keeps the
    current layout when the re-pack cannot fit everything (no evictions)."""
    return replay_fresh_deploy(state, fabric_frag_aware_deploy, keep_on_pending=True)
