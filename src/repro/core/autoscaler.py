"""SLO-aware replica autoscaler: offered load -> per-model replica targets.

The controller closes the demand loop: traffic generators
(``core/traffic.py``) produce request streams, the perf model
(``core/perfmodel.py``) prices one replica's capacity, and this module
decides how many replicas each model should run *right now*.  The wiring
layers (``core/events.py`` for simulation, ``serving/cluster.py`` for live
engines) then issue the deploy/retire/resize requests through the
``PlacementEngine`` — migrations stay priced and gated by the engine's
``CommitPolicy``; the autoscaler only sets targets.

Two controller modes (both queueing-based on ``offered / capacity``):

  * ``target-utilization`` — classic M/M/c sizing: enough replicas that
    steady-state utilization sits at ``target_utilization``, plus a queue
    drain term so a backlog is worked off within ``drain_window_seconds``.
  * ``slo`` — starts from the same sizing but *reacts to measured SLO
    attainment*: below-target attainment forces a multiplicative scale-up
    even when utilization looks fine (tail latency sees what averages
    hide); scale-down additionally requires attainment comfortably above
    target.

Stability machinery (no flapping under steady load):

  * scale-up after ``up_cooldown`` since the last scale-up (fast);
  * scale-down only when the desired count undershoots the current one by
    the ``hysteresis`` fraction AND has done so continuously for
    ``down_cooldown`` (slow, deliberate — MISO's "grow eagerly, shrink
    lazily" asymmetry).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence

__all__ = [
    "SLO",
    "AutoscalerConfig",
    "ModelLoad",
    "ScaleDecision",
    "Autoscaler",
]


@dataclasses.dataclass(frozen=True)
class SLO:
    """Latency targets one request must meet to count as attained."""

    ttft_seconds: float = 2.0  # time to first token (queue wait + prefill)
    tpot_seconds: float = 0.1  # time per output token (decode pace)
    attainment_target: float = 0.95  # fraction of requests meeting both


@dataclasses.dataclass(frozen=True)
class AutoscalerConfig:
    mode: str = "target-utilization"  # or "slo"
    target_utilization: float = 0.70
    #: scale down only when desired <= current * (1 - hysteresis).
    hysteresis: float = 0.2
    up_cooldown: float = 5.0
    down_cooldown: float = 45.0
    #: a backlog should be drained within this window (sizes the queue term).
    drain_window_seconds: float = 30.0
    min_replicas: int = 0
    max_replicas: int = 256
    #: multiplicative step when the SLO is being missed (slo mode).
    slo_scaleup_factor: float = 1.25

    def __post_init__(self) -> None:
        mode = self.mode.replace("_", "-")
        if mode not in ("target-utilization", "slo"):
            raise ValueError(f"unknown autoscaler mode {self.mode!r}")
        object.__setattr__(self, "mode", mode)
        if not 0.0 < self.target_utilization <= 1.0:
            raise ValueError("target_utilization must be in (0, 1]")


@dataclasses.dataclass(frozen=True)
class ModelLoad:
    """One model's observed state at a control tick."""

    model: str
    offered_rps: float  # arrival rate over the last window
    capacity_rps: float  # ONE replica's sustainable rate (perf model)
    replicas: int  # currently live (placed, non-draining)
    queue_depth: int = 0  # requests waiting fleet-wide
    slo_attainment: float = 1.0  # fraction attained over the last window
    slo: SLO = SLO()


@dataclasses.dataclass(frozen=True)
class ScaleDecision:
    model: str
    current: int
    target: int
    reason: str

    @property
    def delta(self) -> int:
        return self.target - self.current


class Autoscaler:
    """Stateful replica controller (cooldown/hysteresis memory per model)."""

    def __init__(self, config: AutoscalerConfig = AutoscalerConfig()):
        self.config = config
        self._last_up: Dict[str, float] = {}
        self._last_down: Dict[str, float] = {}
        #: when the desired count first undershot the hysteresis band.
        self._low_since: Dict[str, float] = {}

    # -- sizing -------------------------------------------------------------
    def desired_replicas(self, obs: ModelLoad) -> int:
        """Raw queueing-based target, before hysteresis/cooldown gating."""
        cfg = self.config
        cap = max(obs.capacity_rps, 1e-9)
        # Steady-state term: run each replica at target utilization.
        need = obs.offered_rps / (cfg.target_utilization * cap)
        # Backlog term: extra capacity to drain the queue within the window.
        need += obs.queue_depth / (cap * cfg.drain_window_seconds)
        n = math.ceil(need - 1e-9)
        if cfg.mode == "slo" and obs.slo_attainment < obs.slo.attainment_target:
            # Tail latency is missing target: multiplicative bump over the
            # *current* fleet regardless of what averages claim suffices.
            n = max(n, math.ceil(obs.replicas * cfg.slo_scaleup_factor), obs.replicas + 1)
        return max(cfg.min_replicas, min(cfg.max_replicas, n))

    # -- control tick -------------------------------------------------------
    def tick(self, now: float, observations: Sequence[ModelLoad]) -> List[ScaleDecision]:
        """Gated decisions for one control tick; targets == current when the
        controller holds (cooldown / hysteresis)."""
        cfg = self.config
        out: List[ScaleDecision] = []
        for obs in observations:
            m = obs.model
            desired = self.desired_replicas(obs)
            target = obs.replicas
            reason = "hold"
            if desired > obs.replicas:
                self._low_since.pop(m, None)
                if now - self._last_up.get(m, -math.inf) >= cfg.up_cooldown:
                    target = desired
                    reason = (
                        f"up: offered {obs.offered_rps:.2f} rps / cap "
                        f"{obs.capacity_rps:.2f} -> {desired}"
                    )
                    self._last_up[m] = now
                else:
                    reason = "hold: up-cooldown"
            elif desired <= math.floor(obs.replicas * (1.0 - cfg.hysteresis)):
                since = self._low_since.setdefault(m, now)
                held = now - since
                in_down_cd = now - self._last_down.get(m, -math.inf) < cfg.down_cooldown
                if held >= cfg.down_cooldown and not in_down_cd:
                    target = desired
                    reason = f"down: sustained low for {held:.0f}s -> {desired}"
                    self._last_down[m] = now
                    self._low_since.pop(m, None)
                else:
                    reason = "hold: down-cooldown"
            else:
                # Inside the hysteresis band: by design, do nothing.
                self._low_since.pop(m, None)
            out.append(ScaleDecision(model=m, current=obs.replicas, target=target,
                                     reason=reason))
        return out
