"""WPM: Workload Placement and Migration MIP (paper Sec 4.1, Eqns 2a-2k).

A profit-maximization MILP that jointly handles initial placement of new
workloads, migration/compaction of existing workloads, and GPU
reconfiguration (via imaginary GPUs).  The paper solved it with CPLEX; here
it is solved with HiGHS through ``scipy.optimize.milp`` when available, or
with the pure-Python branch-and-bound in ``bb_solver`` otherwise.  The
formulation is identical either way.

Variables (see Table 2):
  x[w,b]   in {0,1}  workload w placed on bin b (free GPU, imaginary GPU, or
                     free partition from Algorithm 1)
  stay[w]  in {0,1}  existing workload w keeps its current placement
  y[g]     in {0,1}  GPU g used (free, imaginary, or pre-existing)
  z[p]     in {0,1}  free partition p hosts at least one workload
  delta[b] in {0,1}  bin b's compute is NOT full (u_b >= 1)
  u,v,U,V  >= 0      compute/memory slack and wastage (slice units)

The MIP is bin-level (Assumption 1); ``extract_solution`` performs the
indexing step and repairs the (rare) index-infeasible merged-bin contents.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .indexing import assign_indexes
from .preprocess import FreePartition, determine_free_partitions, merge_partitions
from .state import ClusterState, GPUState, Workload

__all__ = ["ObjectiveWeights", "WPMResult", "solve_wpm"]


@dataclasses.dataclass(frozen=True)
class ObjectiveWeights:
    """Penalty ordering per Sec 4.1: placement >> GPU >> repartition ~ waste >> migration."""

    placement_reward: float = 1000.0  # p_w
    gpu_cost: float = 100.0  # q_g
    repartition_cost: float = 2.0  # gamma^R_g
    migration_cost: float = 1.0  # gamma^M_w
    wastage_cost: float = 10.0  # gamma^W_g


@dataclasses.dataclass
class WPMResult:
    state: ClusterState
    pending: List[Workload]
    objective: float
    status: str
    solve_seconds: float
    mip_gap: Optional[float] = None
    n_variables: int = 0
    n_constraints: int = 0
    repaired: int = 0  # index-repair interventions after the bin-level solve


class _Model:
    """Tiny MILP builder: max c'x s.t. lb <= Ax <= ub, bounds, binaries."""

    def __init__(self) -> None:
        self.obj: List[float] = []
        self.lb: List[float] = []
        self.ub: List[float] = []
        self.is_int: List[bool] = []
        self.names: List[str] = []
        self.rows: List[Tuple[Dict[int, float], float, float]] = []

    def var(self, name: str, lo: float, hi: float, integer: bool, obj: float = 0.0) -> int:
        self.names.append(name)
        self.lb.append(lo)
        self.ub.append(hi)
        self.is_int.append(integer)
        self.obj.append(obj)
        return len(self.names) - 1

    def binary(self, name: str, obj: float = 0.0) -> int:
        return self.var(name, 0.0, 1.0, True, obj)

    def add(self, coeffs: Dict[int, float], lo: float, hi: float) -> None:
        self.rows.append((coeffs, lo, hi))

    def solve(self, time_limit: float, mip_gap: float) -> Tuple[np.ndarray, str, Optional[float]]:
        try:
            return self._solve_scipy(time_limit, mip_gap)
        except ImportError:
            from .bb_solver import solve_milp  # pure-Python fallback

            x, status = solve_milp(
                c=np.asarray(self.obj),
                rows=self.rows,
                lb=np.asarray(self.lb),
                ub=np.asarray(self.ub),
                is_int=np.asarray(self.is_int),
                maximize=True,
                time_limit=time_limit,
            )
            return x, status, None

    def _solve_scipy(self, time_limit: float, mip_gap: float):
        from scipy.optimize import Bounds, LinearConstraint, milp
        from scipy.sparse import csr_matrix

        n = len(self.obj)
        data, ri, ci, lo, hi = [], [], [], [], []
        for r, (coeffs, l, h) in enumerate(self.rows):
            for j, a in coeffs.items():
                ri.append(r)
                ci.append(j)
                data.append(a)
            lo.append(l)
            hi.append(h)
        A = csr_matrix((data, (ri, ci)), shape=(len(self.rows), n))
        res = milp(
            c=-np.asarray(self.obj),  # scipy minimizes
            constraints=LinearConstraint(A, lo, hi),
            integrality=np.asarray(self.is_int, dtype=np.int64),
            bounds=Bounds(np.asarray(self.lb), np.asarray(self.ub)),
            options={"time_limit": time_limit, "mip_rel_gap": mip_gap},
        )
        if res.x is None:
            raise RuntimeError(f"WPM infeasible or unsolved: {res.message}")
        gap = getattr(res, "mip_gap", None)
        return np.asarray(res.x), ("optimal" if res.status == 0 else "time_limit"), gap


def solve_wpm(
    initial: ClusterState,
    new_workloads: Sequence[Workload] = (),
    *,
    movable: bool = True,
    allow_reconfig: bool = True,
    weights: ObjectiveWeights = ObjectiveWeights(),
    time_limit: float = 30.0,
    mip_gap: float = 1e-4,
    merge_free_partitions: bool = True,
) -> WPMResult:
    """Solve WPM for the given initial state.

    movable=False, allow_reconfig=False  -> pure initial deployment (paper "MIP")
    movable=True,  allow_reconfig=True   -> paper "joint-MIP" / compaction / reconfiguration
    """
    t0 = time.time()
    device = next(iter(initial.gpus.values())).device
    W = weights

    used_gpus = sorted(initial.used_gpus(), key=lambda g: g.gid)
    free_gpus = sorted(initial.free_gpus(), key=lambda g: g.gid)
    existing: List[Tuple[Workload, str]] = []  # (workload, current gid)
    for g in used_gpus:
        for pl in g.placements:
            existing.append((initial.workloads[pl.wid], g.gid))

    # ---- bins -------------------------------------------------------------
    # Whole-GPU bins: free GPUs and (if reconfiguring) imaginary counterparts.
    whole_bins: List[Tuple[str, GPUState, Optional[str]]] = []  # (bin id, gpu, imag-of)
    for g in free_gpus:
        whole_bins.append((g.gid, g, None))
    if allow_reconfig and movable:
        for g in used_gpus:
            whole_bins.append((f"{g.gid}~imag", g, g.gid))

    # Partition bins (Algorithm 1) on partially-used GPUs.
    parts: List[FreePartition] = []
    for g in used_gpus:
        pg = determine_free_partitions(g)
        parts.extend(merge_partitions(pg, device) if merge_free_partitions else pg)

    m = _Model()

    # ---- variables ----------------------------------------------------------
    y: Dict[str, int] = {}
    for gid, _, imag_of in whole_bins:
        cost = W.gpu_cost + (W.repartition_cost if imag_of else 0.0)
        y[gid] = m.binary(f"y[{gid}]", obj=-cost)
    for g in used_gpus:
        y[g.gid] = m.binary(f"y[{g.gid}]", obj=-W.gpu_cost)

    z: Dict[str, int] = {p.pid: m.binary(f"z[{p.pid}]") for p in parts}

    movers: List[Tuple[Workload, str]] = existing if movable else []
    fixed: List[Tuple[Workload, str]] = [] if movable else existing
    news = list(new_workloads)

    x: Dict[Tuple[str, str], int] = {}  # (wid, bin id) -> var
    stay: Dict[str, int] = {}
    all_wl: List[Tuple[Workload, Optional[str]]] = [(w, gid) for w, gid in movers]
    all_wl += [(w, None) for w in news]

    bin_caps: Dict[str, Tuple[int, int, int]] = {}  # bin -> (C, Mslices, me)
    for gid, g, _ in whole_bins:
        bin_caps[gid] = (
            device.n_gpu_slices,
            device.n_memory_slices,
            device.max_media_extensions,
        )
    for p in parts:
        me = device.max_media_extensions if True else 0
        bin_caps[p.pid] = (p.compute_capacity, p.memory_capacity, me)

    part_by_id = {p.pid: p for p in parts}
    x_by_wid: Dict[str, List[int]] = {}
    x_by_bin: Dict[str, List[Tuple[str, int]]] = {}

    def _mk_x(wid: str, bid: str, reward: float) -> None:
        xi = m.binary(f"x[{wid},{bid}]", obj=reward)
        x[(wid, bid)] = xi
        x_by_wid.setdefault(wid, []).append(xi)
        x_by_bin.setdefault(bid, []).append((wid, xi))

    for w, cur in all_wl:
        prof = device.profile(w.profile_id)
        for gid, _, _ in whole_bins:
            _mk_x(w.wid, gid, W.placement_reward)
        for p in parts:
            if p.gid != cur and p.admits(prof, device):
                # A mover may not re-enter a free partition of its own GPU
                # (its own vacated span is not re-offered; conservative and
                # consistent with Assumption 2's zero-cost within-GPU moves
                # being handled via the imaginary-GPU route instead).
                _mk_x(w.wid, p.pid, W.placement_reward)
        if cur is not None:
            stay[w.wid] = m.binary(f"stay[{w.wid}]", obj=W.placement_reward)

    # Migration penalty: existing workload migrates unless it stays or lands
    # on its own imaginary GPU.  gamma^M*(1 - stay - x[w, imag(cur)]).
    for w, cur in movers:
        gm = W.migration_cost * w.migration_cost
        m.obj[stay[w.wid]] += gm
        imag_id = f"{cur}~imag"
        if (w.wid, imag_id) in x:
            m.obj[x[(w.wid, imag_id)]] += gm
        # constant term -gm omitted (doesn't affect argmax; reported obj adjusts)
    const_obj = -sum(W.migration_cost * w.migration_cost for w, _ in movers)

    u: Dict[str, int] = {}
    v: Dict[str, int] = {}
    Uv: Dict[str, int] = {}
    Vv: Dict[str, int] = {}
    dlt: Dict[str, int] = {}
    for bid, (C, M, _) in bin_caps.items():
        u[bid] = m.var(f"u[{bid}]", 0, C, False)
        v[bid] = m.var(f"v[{bid}]", 0, M, False)
        Uv[bid] = m.var(f"U[{bid}]", 0, C, False, obj=-W.wastage_cost)
        Vv[bid] = m.var(f"V[{bid}]", 0, M, False, obj=-W.wastage_cost)
        dlt[bid] = m.binary(f"delta[{bid}]")

    # ---- constraints --------------------------------------------------------
    INF = float("inf")
    wl_by_id = {w.wid: w for w, _ in all_wl}

    # (2b)/(2c): count caps tie x to y (whole bins) / z (partitions).
    for bid, (C, M, _) in bin_caps.items():
        gate = y[bid] if bid in y else z[bid]
        row = {xi: 1.0 for _, xi in x_by_bin.get(bid, [])}
        if row:
            row[gate] = -float(C)
            m.add(row, -INF, 0.0)

    # (2d): partitions on g' imply y[g'], capped by compute slices.
    for g in used_gpus:
        row = {z[p.pid]: 1.0 for p in parts if p.gid == g.gid}
        if row:
            row[y[g.gid]] = -float(device.n_gpu_slices)
            m.add(row, -INF, 0.0)

    # Existing workloads on kept GPUs: stay => y[g']; stay + y[imag] <= 1.
    for w, cur in movers:
        m.add({stay[w.wid]: 1.0, y[cur]: -1.0}, -INF, 0.0)
        imag_id = f"{cur}~imag"
        if imag_id in y:
            m.add({stay[w.wid]: 1.0, y[imag_id]: 1.0}, -INF, 1.0)
    if not movable:
        # Fixed workloads pin their GPUs as used.
        for g in used_gpus:
            m.add({y[g.gid]: 1.0}, 1.0, 1.0)

    # (2e): each workload placed exactly once (existing) / at most once (new).
    for w, cur in all_wl:
        row = {xi: 1.0 for xi in x_by_wid.get(w.wid, [])}
        if cur is not None:
            row[stay[w.wid]] = 1.0
            m.add(row, 1.0, 1.0)
        else:
            m.add(row, 0.0, 1.0)

    # (2h): original xor imaginary.
    if allow_reconfig and movable:
        for g in used_gpus:
            imag_id = f"{g.gid}~imag"
            if imag_id in y:
                m.add({y[g.gid]: 1.0, y[imag_id]: 1.0}, -INF, 1.0)

    # (2f)/(2g): compute & memory bin packing with explicit slack; plus me cap.
    for bid, (C, M, ME) in bin_caps.items():
        crow: Dict[int, float] = {u[bid]: 1.0}
        mrow: Dict[int, float] = {v[bid]: 1.0}
        merow: Dict[int, float] = {}
        for wid, xi in x_by_bin.get(bid, []):
            prof = device.profile(wl_by_id[wid].profile_id)
            crow[xi] = float(prof.compute_slices)
            mrow[xi] = float(prof.memory_slices)
            if prof.media_extensions:
                merow[xi] = float(prof.media_extensions)
        m.add(crow, float(C), float(C))
        m.add(mrow, float(M), float(M))
        if merow:
            m.add(merow, -INF, float(ME))

    # (2i)-(2k): wastage linearization.
    for bid, (C, M, _) in bin_caps.items():
        m.add({u[bid]: 1.0, v[bid]: -1.0, Uv[bid]: -1.0}, -INF, 0.0)  # (2i)
        m.add({dlt[bid]: 1.0, u[bid]: -1.0}, -INF, 0.0)  # delta <= u
        m.add({u[bid]: 1.0, dlt[bid]: -float(C)}, -INF, 0.0)  # u <= C delta
        m.add({v[bid]: 1.0, dlt[bid]: -float(M), Vv[bid]: -1.0}, -INF, 0.0)  # (2k)

    # ---- solve ------------------------------------------------------------
    xsol, status, gap = m.solve(time_limit, mip_gap)
    xb = xsol > 0.5

    # ---- extract + indexing step -------------------------------------------
    final = ClusterState(
        gpus={gid: GPUState(gid, initial.gpus[gid].device) for gid in initial.gpus},
        workloads=dict(initial.workloads),
    )
    for w in news:
        final.workloads[w.wid] = w
    repaired = 0
    pending: List[Workload] = []

    # Fixed (immovable) workloads keep their placements verbatim.
    for w, cur in fixed:
        pl = initial.placement_of(w.wid)[1]
        final.gpus[cur].placements.append(pl)
    # Stays keep their placements verbatim.
    for w, cur in movers:
        if xb[stay[w.wid]]:
            pl = initial.placement_of(w.wid)[1]
            final.gpus[cur].placements.append(pl)

    # Whole-GPU bins: collect contents, index-assign from scratch.
    leftovers: List[Workload] = []
    for gid, g, imag_of in whole_bins:
        wids = [wid for wid, xi in x_by_bin.get(gid, []) if xb[xi]]
        if not wids:
            continue
        target = imag_of if imag_of else gid
        host = final.gpus[target]
        profs = [final.workloads[wid].profile_id for wid in wids]
        placements = assign_indexes(host, profs, wids)
        if placements is None:
            repaired += len(wids)
            leftovers.extend(final.workloads[wid] for wid in wids)
        else:
            host.placements.extend(placements)

    # Partition bins: index-assign within the owning GPU (stays already placed).
    by_gpu: Dict[str, List[str]] = {}
    for (wid, b), xi in x.items():
        if b in part_by_id and xb[xi]:
            by_gpu.setdefault(part_by_id[b].gid, []).append(wid)
    for gid, wids in by_gpu.items():
        host = final.gpus[gid]
        profs = [final.workloads[wid].profile_id for wid in wids]
        placements = assign_indexes(host, profs, wids)
        if placements is None:
            repaired += len(wids)
            leftovers.extend(final.workloads[wid] for wid in wids)
        else:
            host.placements.extend(placements)

    # Repair: greedily place leftovers (merged-bin index artifacts).
    from .baselines import place_max_utilization

    for w in leftovers:
        spot = place_max_utilization(final, w)
        if spot is None:
            pending.append(w)
        else:
            final.place(w.wid, *spot)
    for w in news:
        if final.placement_of(w.wid) is None and w not in pending:
            pending.append(w)
    final.validate()

    nvars = len(m.obj)
    ncons = len(m.rows)
    obj = float(np.dot(m.obj, xsol)) + const_obj
    return WPMResult(
        state=final,
        pending=pending,
        objective=obj,
        status=status,
        solve_seconds=time.time() - t0,
        mip_gap=gap,
        n_variables=nvars,
        n_constraints=ncons,
        repaired=repaired,
    )
