"""Pure-Python MILP fallback: dense two-phase simplex + branch & bound.

Used only when scipy/HiGHS is unavailable.  Correct but intended for small
instances (single-node clusters); tests cross-check it against HiGHS on tiny
WPM models.  Maximization, row form lb <= a.x <= ub, variable bounds, binary
integrality.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["solve_milp"]

_EPS = 1e-9


def _solve_lp(
    c: np.ndarray,
    A: np.ndarray,
    b: np.ndarray,
    senses: Sequence[str],
    lo: np.ndarray,
    hi: np.ndarray,
) -> Optional[Tuple[np.ndarray, float]]:
    """max c.x st A x (<=,=) b, lo<=x<=hi.  Returns (x, obj) or None.

    Standardization: shift x by lo, add upper-bound rows, slacks, then
    two-phase tableau simplex (dense; fine for the small fallback sizes).
    """
    n = len(c)
    shift = lo.copy()
    b = b - A @ shift
    ub_rows = []
    ub_rhs = []
    for j in range(n):
        if np.isfinite(hi[j]):
            row = np.zeros(n)
            row[j] = 1.0
            ub_rows.append(row)
            ub_rhs.append(hi[j] - lo[j])
    A2 = np.vstack([A] + ([np.array(ub_rows)] if ub_rows else []))
    b2 = np.concatenate([b, np.array(ub_rhs)] if ub_rows else [b])
    senses2 = list(senses) + ["<="] * len(ub_rhs)

    m = len(b2)
    # slacks for <= rows; flip rows with negative rhs later via phase 1
    n_slack = sum(1 for s in senses2 if s == "<=")
    T = np.zeros((m, n + n_slack))
    T[:, :n] = A2
    si = n
    slack_of = {}
    for i, s in enumerate(senses2):
        if s == "<=":
            T[i, si] = 1.0
            slack_of[i] = si
            si += 1
    rhs = b2.copy()
    for i in range(m):
        if rhs[i] < 0:
            T[i] *= -1
            rhs[i] *= -1
            if i in slack_of:
                pass  # slack coefficient is now -1; needs artificial anyway

    # artificials for = rows and flipped <= rows (slack coef -1)
    total = T.shape[1]
    art_cols = []
    basis = [-1] * m
    for i in range(m):
        if i in slack_of and T[i, slack_of[i]] > 0:
            basis[i] = slack_of[i]
        else:
            art_cols.append(i)
    Tfull = np.hstack([T, np.zeros((m, len(art_cols)))])
    for k, i in enumerate(art_cols):
        Tfull[i, total + k] = 1.0
        basis[i] = total + k

    def pivot(Tab, rhs_, basis_, obj_row, obj_val):
        it = 0
        while it < 20000:
            it += 1
            j = int(np.argmin(obj_row))
            if obj_row[j] > -1e-10:
                return obj_val
            col = Tab[:, j]
            mask = col > _EPS
            if not mask.any():
                return None  # unbounded
            ratios = np.where(mask, rhs_ / np.where(mask, col, 1), np.inf)
            i = int(np.argmin(ratios))
            piv = Tab[i, j]
            Tab[i] /= piv
            rhs_[i] /= piv
            for r in range(len(Tab)):
                if r != i and abs(Tab[r, j]) > _EPS:
                    f = Tab[r, j]
                    Tab[r] -= f * Tab[i]
                    rhs_[r] -= f * rhs_[i]
            f = obj_row[j]
            obj_row -= f * Tab[i]
            obj_val -= f * rhs_[i]
            basis_[i] = j
        return obj_val

    # phase 1
    ncols = Tfull.shape[1]
    obj1 = np.zeros(ncols)
    obj1[total:] = 1.0
    val1 = 0.0
    for i in range(m):
        if basis[i] >= total:
            obj1 -= Tfull[i]
            val1 -= rhs[i]
    r = pivot(Tfull, rhs, basis, obj1, val1)
    if r is None or -r > 1e-7:
        return None  # infeasible
    # phase 2
    obj2 = np.zeros(ncols)
    obj2[:n] = -c  # maximize c.x == minimize -c.x
    val2 = 0.0
    for i in range(m):
        if obj2[basis[i]] != 0:
            f = obj2[basis[i]]
            obj2 -= f * Tfull[i]
            val2 -= f * rhs[i]
    obj2[total:] = 1e6  # forbid artificials re-entering
    r2 = pivot(Tfull, rhs, basis, obj2, val2)
    if r2 is None:
        return None
    x = np.zeros(ncols)
    for i, bcol in enumerate(basis):
        x[bcol] = rhs[i]
    sol = x[:n] + shift
    return sol, float(c @ sol)


def solve_milp(
    c: np.ndarray,
    rows: List[Tuple[Dict[int, float], float, float]],
    lb: np.ndarray,
    ub: np.ndarray,
    is_int: np.ndarray,
    maximize: bool = True,
    time_limit: float = 60.0,
    max_nodes: int = 20000,
) -> Tuple[np.ndarray, str]:
    """Branch & bound over binaries with LP-relaxation bounds."""
    assert maximize
    n = len(c)
    A_list, b_list, senses = [], [], []
    for coeffs, lo_r, hi_r in rows:
        row = np.zeros(n)
        for j, a in coeffs.items():
            row[j] = a
        if lo_r == hi_r:
            A_list.append(row)
            b_list.append(hi_r)
            senses.append("=")
        else:
            if np.isfinite(hi_r):
                A_list.append(row)
                b_list.append(hi_r)
                senses.append("<=")
            if np.isfinite(lo_r):
                A_list.append(-row)
                b_list.append(-lo_r)
                senses.append("<=")
    A = np.array(A_list) if A_list else np.zeros((0, n))
    b = np.array(b_list) if b_list else np.zeros(0)

    t0 = time.time()
    best_x: Optional[np.ndarray] = None
    best_obj = -np.inf
    # stack of (extra lo, extra hi)
    stack = [(lb.astype(float).copy(), ub.astype(float).copy())]
    nodes = 0
    while stack and nodes < max_nodes and time.time() - t0 < time_limit:
        lo, hi = stack.pop()
        nodes += 1
        res = _solve_lp(c, A, b, senses, lo, hi)
        if res is None:
            continue
        x, obj = res
        if obj <= best_obj + 1e-9:
            continue  # bound
        frac = [
            j
            for j in range(n)
            if is_int[j] and abs(x[j] - round(x[j])) > 1e-6
        ]
        if not frac:
            xi = x.copy()
            xi[is_int.astype(bool)] = np.round(xi[is_int.astype(bool)])
            best_x, best_obj = xi, obj
            continue
        j = max(frac, key=lambda j: abs(x[j] - round(x[j])))
        lo1, hi1 = lo.copy(), hi.copy()
        hi1[j] = np.floor(x[j])
        lo2, hi2 = lo.copy(), hi.copy()
        lo2[j] = np.ceil(x[j])
        # explore the rounding-up branch first (placements are rewarded)
        stack.append((lo1, hi1))
        stack.append((lo2, hi2))
    if best_x is None:
        raise RuntimeError("bb_solver: no feasible solution found")
    status = "optimal" if not stack else "time_limit"
    return best_x, status
