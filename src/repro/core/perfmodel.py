"""Per-partition service-rate model: what a placed replica can actually serve.

Placement decides *where* a replica lives; this module decides *how fast* it
runs there, closing the loop between slice geometry and request traffic.
LLM inference has two phases with different bottlenecks:

  * **prefill** is compute-bound  -> throughput scales with the partition's
    share of compute slices (MIG SMs / pod rows);
  * **decode** is bandwidth-bound -> throughput scales with the partition's
    share of memory slices (MIG memory carries its HBM controllers with it,
    so bandwidth is proportional to memory slices — the MISO observation).

``PerfModel.rates(device, profile_id)`` therefore maps a whole-device
throughput pair to per-profile (prefill tokens/s, decode tokens/s) via the
profile's compute/memory fractions, optionally raised to a
``parallel_efficiency`` exponent <= 1 (sublinear scaling of small slices;
still monotone: a bigger slice never serves slower).  Whole-device numbers
come from a user calibration dict, a ``calibrator`` hook, or a built-in
table, in that order — measurements outrank planning numbers.  The kernel
calibration profiler (``repro.obs.profile`` via ``benchmarks/calibrate.py``)
produces a ``CALIBRATION.json`` artifact that
:meth:`PerfModel.from_calibration` loads straight into the calibration
dict, so autoscaling and SLO attainment can plan on measured rates.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Dict, Mapping, Optional, Tuple, Union

from .profiles import DeviceModel

__all__ = [
    "DeviceThroughput",
    "DEVICE_THROUGHPUT",
    "PerfModel",
]


@dataclasses.dataclass(frozen=True)
class DeviceThroughput:
    """Aggregate serving throughput of one WHOLE device (all slices)."""

    prefill_tokens_per_s: float
    decode_tokens_per_s: float

    def scaled(self, prefill_frac: float, decode_frac: float) -> "DeviceThroughput":
        return DeviceThroughput(
            prefill_tokens_per_s=self.prefill_tokens_per_s * prefill_frac,
            decode_tokens_per_s=self.decode_tokens_per_s * decode_frac,
        )


#: built-in whole-device throughputs for a mid-size (~10B-class) serving
#: model — deliberately round planning numbers, not measurements; calibrate
#: with real ones via ``PerfModel(calibration=...)`` or the roofline hook.
DEVICE_THROUGHPUT: Dict[str, DeviceThroughput] = {
    "A100-80GB": DeviceThroughput(20_000.0, 2_000.0),
    "H100-96GB": DeviceThroughput(50_000.0, 4_500.0),
    # a 16x16 v5e pod aggregates 256 chips; decode is per-pod aggregate.
    "TPUv5e-16x16-pod": DeviceThroughput(400_000.0, 60_000.0),
}

#: fallback for unknown devices: scale a conservative per-memory-GB rate.
_FALLBACK_PER_GB = DeviceThroughput(150.0, 15.0)


@dataclasses.dataclass(frozen=True)
class PerfModel:
    """Profile -> service-rate mapping with optional calibration.

    Throughput sources, highest precedence first:

    1. ``calibration`` — explicit measured table per device name
       (``PerfModel.from_calibration`` builds one from the profiler's
       ``CALIBRATION.json``);
    2. ``calibrator`` — a measurement hook (e.g. the kernel profiler or a
       roofline pass), consulted once per device and cached.  A supplied
       hook *beats the built-in table*: measurements outrank the
       hand-written planning numbers;
    3. the built-in ``DEVICE_THROUGHPUT`` table;
    4. a conservative per-memory-GB fallback for unknown devices.
    """

    calibration: Optional[Dict[str, DeviceThroughput]] = None
    calibrator: Optional[Callable[[DeviceModel], DeviceThroughput]] = None
    #: slice-count scaling exponent in (0, 1]: 1.0 = linear; lower models
    #: sublinear parallel efficiency of large partitions.  Monotone for any
    #: value > 0 (bigger fraction => >= throughput).
    parallel_efficiency: float = 1.0

    def __post_init__(self) -> None:
        if not 0.0 < self.parallel_efficiency <= 1.0:
            raise ValueError(
                f"parallel_efficiency must be in (0, 1], "
                f"got {self.parallel_efficiency}"
            )

    # -- whole-device -------------------------------------------------------
    def device_throughput(self, device: DeviceModel) -> DeviceThroughput:
        if self.calibration and device.name in self.calibration:
            return self.calibration[device.name]
        cache = self.__dict__.setdefault("_hook_cache", {})
        if self.calibrator is not None:
            if device.name not in cache:
                cache[device.name] = self.calibrator(device)
            return cache[device.name]
        if device.name in DEVICE_THROUGHPUT:
            return DEVICE_THROUGHPUT[device.name]
        if device.name not in cache:
            gb = float(getattr(device, "mem_per_slice_gb", 10) or 10)
            total_gb = gb * device.n_memory_slices
            cache[device.name] = _FALLBACK_PER_GB.scaled(total_gb, total_gb)
        return cache[device.name]

    # -- calibration artifact loader ---------------------------------------
    @classmethod
    def from_calibration(
        cls,
        source: Union[str, "os.PathLike[str]", Mapping],
        parallel_efficiency: Optional[float] = None,
    ) -> "PerfModel":
        """Build a measured PerfModel from the kernel profiler's artifact.

        ``source`` is a ``CALIBRATION.json`` path or the already-parsed
        report dict (``repro.obs.profile.run_calibration`` output).  Each
        device's ``whole_device`` rates become the calibration table entry
        and the profiler's fitted ``parallel_efficiency`` (mean across
        devices, clamped to (0, 1]) becomes the scaling exponent unless
        overridden.
        """
        if isinstance(source, Mapping):
            rep = source
        else:
            with open(source) as f:
                rep = json.load(f)
        schema = str(rep.get("schema", "calibration/v1"))
        if not schema.startswith("calibration/"):
            raise ValueError(f"not a calibration artifact (schema={schema!r})")
        devices = rep.get("devices") or {}
        if not devices:
            raise ValueError("calibration artifact has no devices section")
        table: Dict[str, DeviceThroughput] = {}
        effs = []
        for name, entry in devices.items():
            whole = entry.get("whole_device") or {}
            prefill = float(whole.get("prefill_tokens_per_s", 0.0))
            decode = float(whole.get("decode_tokens_per_s", 0.0))
            if prefill <= 0.0 or decode <= 0.0:
                raise ValueError(
                    f"device {name!r}: non-positive whole-device rates "
                    f"({prefill}, {decode})"
                )
            table[name] = DeviceThroughput(prefill, decode)
            e = entry.get("parallel_efficiency")
            if isinstance(e, (int, float)):
                effs.append(float(e))
        if parallel_efficiency is None:
            parallel_efficiency = sum(effs) / len(effs) if effs else 1.0
            parallel_efficiency = min(max(parallel_efficiency, 1e-3), 1.0)
        return cls(calibration=table, parallel_efficiency=parallel_efficiency)

    # -- per-profile --------------------------------------------------------
    def rates(self, device: DeviceModel, profile_id: int) -> Tuple[float, float]:
        """(prefill tokens/s, decode tokens/s) of ``profile_id`` on ``device``."""
        prof = device.profile(profile_id)
        base = self.device_throughput(device)
        e = self.parallel_efficiency
        cfrac = (prof.compute_slices / device.n_gpu_slices) ** e
        mfrac = (prof.memory_slices / device.n_memory_slices) ** e
        return (
            base.prefill_tokens_per_s * cfrac,
            base.decode_tokens_per_s * mfrac,
        )

    def service_seconds(
        self, device: DeviceModel, profile_id: int, prompt_len: int, decode_len: int
    ) -> Tuple[float, float]:
        """(prefill seconds, decode seconds) for one request on the profile."""
        prefill_tps, decode_tps = self.rates(device, profile_id)
        return prompt_len / prefill_tps, decode_len / decode_tps

    def tpot_seconds(self, device: DeviceModel, profile_id: int) -> float:
        """Steady-state time-per-output-token of the profile."""
        _, decode_tps = self.rates(device, profile_id)
        return 1.0 / decode_tps

    def capacity_rps(
        self,
        device: DeviceModel,
        profile_id: int,
        mean_prompt_len: int,
        mean_decode_len: int,
    ) -> float:
        """Sustainable requests/s of ONE replica on the profile, at the
        model's mean request shape (the autoscaler's denominator)."""
        prefill_s, decode_s = self.service_seconds(
            device, profile_id, mean_prompt_len, mean_decode_len
        )
        return 1.0 / max(prefill_s + decode_s, 1e-12)
