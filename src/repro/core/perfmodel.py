"""Per-partition service-rate model: what a placed replica can actually serve.

Placement decides *where* a replica lives; this module decides *how fast* it
runs there, closing the loop between slice geometry and request traffic.
LLM inference has two phases with different bottlenecks:

  * **prefill** is compute-bound  -> throughput scales with the partition's
    share of compute slices (MIG SMs / pod rows);
  * **decode** is bandwidth-bound -> throughput scales with the partition's
    share of memory slices (MIG memory carries its HBM controllers with it,
    so bandwidth is proportional to memory slices — the MISO observation).

``PerfModel.rates(device, profile_id)`` therefore maps a whole-device
throughput pair to per-profile (prefill tokens/s, decode tokens/s) via the
profile's compute/memory fractions, optionally raised to a
``parallel_efficiency`` exponent <= 1 (sublinear scaling of small slices;
still monotone: a bigger slice never serves slower).  Whole-device numbers
come from a built-in table, a user calibration dict, or a ``calibrator``
hook — e.g. a roofline pass (``benchmarks/roofline.py``) measuring the real
hardware, which is why the hook takes the ``DeviceModel`` itself.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

from .profiles import DeviceModel

__all__ = [
    "DeviceThroughput",
    "DEVICE_THROUGHPUT",
    "PerfModel",
]


@dataclasses.dataclass(frozen=True)
class DeviceThroughput:
    """Aggregate serving throughput of one WHOLE device (all slices)."""

    prefill_tokens_per_s: float
    decode_tokens_per_s: float

    def scaled(self, prefill_frac: float, decode_frac: float) -> "DeviceThroughput":
        return DeviceThroughput(
            prefill_tokens_per_s=self.prefill_tokens_per_s * prefill_frac,
            decode_tokens_per_s=self.decode_tokens_per_s * decode_frac,
        )


#: built-in whole-device throughputs for a mid-size (~10B-class) serving
#: model — deliberately round planning numbers, not measurements; calibrate
#: with real ones via ``PerfModel(calibration=...)`` or the roofline hook.
DEVICE_THROUGHPUT: Dict[str, DeviceThroughput] = {
    "A100-80GB": DeviceThroughput(20_000.0, 2_000.0),
    "H100-96GB": DeviceThroughput(50_000.0, 4_500.0),
    # a 16x16 v5e pod aggregates 256 chips; decode is per-pod aggregate.
    "TPUv5e-16x16-pod": DeviceThroughput(400_000.0, 60_000.0),
}

#: fallback for unknown devices: scale a conservative per-memory-GB rate.
_FALLBACK_PER_GB = DeviceThroughput(150.0, 15.0)


@dataclasses.dataclass(frozen=True)
class PerfModel:
    """Profile -> service-rate mapping with optional calibration.

    ``calibration`` overrides the built-in table per device name;
    ``calibrator`` is consulted (once per device, cached) when neither table
    has the device — wire a roofline measurement pass here.
    """

    calibration: Optional[Dict[str, DeviceThroughput]] = None
    calibrator: Optional[Callable[[DeviceModel], DeviceThroughput]] = None
    #: slice-count scaling exponent in (0, 1]: 1.0 = linear; lower models
    #: sublinear parallel efficiency of large partitions.  Monotone for any
    #: value > 0 (bigger fraction => >= throughput).
    parallel_efficiency: float = 1.0

    def __post_init__(self) -> None:
        if not 0.0 < self.parallel_efficiency <= 1.0:
            raise ValueError(
                f"parallel_efficiency must be in (0, 1], "
                f"got {self.parallel_efficiency}"
            )

    # -- whole-device -------------------------------------------------------
    def device_throughput(self, device: DeviceModel) -> DeviceThroughput:
        if self.calibration and device.name in self.calibration:
            return self.calibration[device.name]
        if device.name in DEVICE_THROUGHPUT:
            return DEVICE_THROUGHPUT[device.name]
        cache = self.__dict__.setdefault("_hook_cache", {})
        if device.name in cache:
            return cache[device.name]
        if self.calibrator is not None:
            tp = self.calibrator(device)
        else:
            gb = float(getattr(device, "mem_per_slice_gb", 10) or 10)
            total_gb = gb * device.n_memory_slices
            tp = _FALLBACK_PER_GB.scaled(total_gb, total_gb)
        cache[device.name] = tp
        return tp

    # -- per-profile --------------------------------------------------------
    def rates(self, device: DeviceModel, profile_id: int) -> Tuple[float, float]:
        """(prefill tokens/s, decode tokens/s) of ``profile_id`` on ``device``."""
        prof = device.profile(profile_id)
        base = self.device_throughput(device)
        e = self.parallel_efficiency
        cfrac = (prof.compute_slices / device.n_gpu_slices) ** e
        mfrac = (prof.memory_slices / device.n_memory_slices) ** e
        return (
            base.prefill_tokens_per_s * cfrac,
            base.decode_tokens_per_s * mfrac,
        )

    def service_seconds(
        self, device: DeviceModel, profile_id: int, prompt_len: int, decode_len: int
    ) -> Tuple[float, float]:
        """(prefill seconds, decode seconds) for one request on the profile."""
        prefill_tps, decode_tps = self.rates(device, profile_id)
        return prompt_len / prefill_tps, decode_len / decode_tps

    def tpot_seconds(self, device: DeviceModel, profile_id: int) -> float:
        """Steady-state time-per-output-token of the profile."""
        _, decode_tps = self.rates(device, profile_id)
        return 1.0 / decode_tps

    def capacity_rps(
        self,
        device: DeviceModel,
        profile_id: int,
        mean_prompt_len: int,
        mean_decode_len: int,
    ) -> float:
        """Sustainable requests/s of ONE replica on the profile, at the
        model's mean request shape (the autoscaler's denominator)."""
        prefill_s, decode_s = self.service_seconds(
            device, profile_id, mean_prompt_len, mean_decode_len
        )
        return 1.0 / max(prefill_s + decode_s, 1e-12)
