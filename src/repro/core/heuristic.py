"""Rule-based / heuristic placement model (paper Sec 4.2).

Solves the three use cases separately, avoiding sequential migration by
construction:

* ``initial_deployment``  — size-sorted max-utilization placement.
* ``compaction``          — vacate least-utilized GPUs into other allocated
                            GPUs; if blocked, use one free GPU provided it
                            saves more than one GPU net (paper Fig. 8).
* ``reconfiguration``     — lower-bound GPU count (Eq. 3), extra-memory
                            profiles first, then first-fit decreasing with
                            feasibility checks and preference-order indexes.

All functions mutate the given ClusterState in place and return the list of
pending (unplaceable) workloads.
"""
from __future__ import annotations

import math
from typing import List, Sequence, Tuple

from .baselines import place_max_utilization
from .state import ClusterState, GPUState, Workload

__all__ = ["initial_deployment", "compaction", "reconfiguration"]


# ---------------------------------------------------------------------------
# Initial deployment (Sec 4.2, Steps 1-3)
# ---------------------------------------------------------------------------
def initial_deployment(
    state: ClusterState, new_workloads: Sequence[Workload]
) -> List[Workload]:
    device = next(iter(state.gpus.values())).device
    pending: List[Workload] = []
    # Step 1: sort new workloads in descending size (profile id is the proxy).
    ordered = sorted(
        new_workloads, key=lambda w: (device.profile(w.profile_id).sort_key, w.wid)
    )
    for w in ordered:
        state.add_workload(w)
        # Steps 2-3: GPU with max utilization after assignment, preference
        # order for the index; allocate a new GPU when nothing fits.
        spot = place_max_utilization(state, w)
        if spot is None:
            pending.append(w)
        else:
            state.place(w.wid, *spot)
    return pending


# ---------------------------------------------------------------------------
# Compaction (Sec 4.2)
# ---------------------------------------------------------------------------
def _vacate(state: ClusterState, gid: str, targets: Sequence[str]) -> bool:
    """Try to empty ``gid`` into ``targets`` with one-shot migrations only.

    Runs inside a transaction on the real state: the moves are committed on
    success and rolled back (O(#ops), no clone) on failure.  "One-shot" means
    every destination span must already be free *before this vacate started*
    (no dependency on other moves off-GPU).
    """
    targets = [t for t in targets if t != gid]
    # Pre-move snapshots of the destinations, for the one-shot verification.
    before = {t: state.gpus[t].clone() for t in targets}
    with state.transaction() as txn:
        moves: List[Tuple[str, str, int]] = []
        victims = sorted(
            state.gpus[gid].placements,
            key=lambda p: state.gpus[gid].device.profile(p.profile_id).sort_key,
        )
        for pl in list(victims):
            w = state.workloads[pl.wid]
            state.remove(pl.wid, gid)
            spot = place_max_utilization(
                state, w, candidates=targets, allow_new_gpu=False
            )
            if spot is None:
                txn.rollback()
                return False
            state.place(w.wid, *spot)
            moves.append((w.wid, spot[0], spot[1]))
        for wid, dst, idx in moves:
            prof = state.gpus[dst].device.profile(state.workloads[wid].profile_id)
            if not before[dst].can_place_at(prof, idx):
                txn.rollback()
                return False
    return True


def compaction(state: ClusterState) -> List[Workload]:
    """Vacate underutilized GPUs (paper Sec 4.2 compaction steps 1-3)."""
    progress = True
    while progress:
        progress = False
        # Step 1: sort allocated GPUs by joint slice utilization ascending.
        used = sorted(
            state.used_gpus(), key=lambda g: (g.joint_slice_utilization(), g.gid)
        )
        for gpu in used:
            others = [g.gid for g in state.used_gpus() if g.gid != gpu.gid]
            # Step 3 feasibility pre-check: enough free slices elsewhere?
            need = sum(
                gpu.device.profile(p.profile_id).memory_slices
                for p in gpu.placements
            )
            have = sum(len(state.gpus[o].free_gpu_slices()) for o in others) + sum(
                1
                for o in others
                if state.gpus[o].memory_occupancy()[-1] is None
            )
            if have < need:
                continue
            if _vacate(state, gpu.gid, others):
                progress = True
                break
        if progress:
            continue
        # Fallback (paper Fig. 8): borrow ONE free GPU if that lets us vacate
        # more than one allocated GPU (net saving >= 1).
        free = sorted(state.free_gpus(), key=lambda g: g.gid)
        if not free:
            continue
        borrowed = free[0].gid
        with state.transaction() as outer:
            vacated = 0
            used = sorted(
                state.used_gpus(), key=lambda g: (g.joint_slice_utilization(), g.gid)
            )
            for gpu in used:
                targets = [
                    g.gid for g in state.used_gpus() if g.gid != gpu.gid
                ] + [borrowed]
                if _vacate(state, gpu.gid, targets):
                    vacated += 1
            if vacated > 1:
                progress = True
            else:
                outer.rollback()
    return []


# ---------------------------------------------------------------------------
# Reconfiguration / redeployment (Sec 4.2)
# ---------------------------------------------------------------------------
def min_gpus_needed(device, workloads: Sequence[Workload]) -> int:
    """Equation 3 lower bound."""
    c = sum(device.profile(w.profile_id).compute_slices for w in workloads)
    m = sum(device.profile(w.profile_id).memory_slices for w in workloads)
    return max(
        math.ceil(c / device.n_gpu_slices), math.ceil(m / device.n_memory_slices)
    )


def reconfiguration(state: ClusterState) -> List[Workload]:
    """Re-place ALL existing workloads optimally (paper Sec 4.2 steps 1-5)."""
    device = next(iter(state.gpus.values())).device
    workloads = state.placed_workloads()
    if not workloads:
        return []
    n_min = min_gpus_needed(device, workloads)

    # Step 2 ordering: least utilized first => free GPUs first.
    by_util = sorted(
        state.gpus.values(), key=lambda g: (g.joint_slice_utilization(), g.gid)
    )
    all_gids = [g.gid for g in by_util]

    for n in range(n_min, len(all_gids) + 1):
        targets = all_gids[:n]
        fresh = ClusterState(
            gpus={gid: GPUState(gid, device) for gid in targets},
            workloads={w.wid: w for w in workloads},
        )
        pending = _reconfigure_into(fresh, device, workloads)
        if not pending:
            # Commit: adopt the fresh layout (journaled diff-apply — GPUs
            # outside ``targets`` are emptied by the removals it derives).
            state.adopt(fresh)
            return []
    # Could not place everything even with all GPUs (shouldn't happen when
    # the initial state was feasible): keep initial layout.
    return []


def _reconfigure_into(
    fresh: ClusterState, device, workloads: Sequence[Workload]
) -> List[Workload]:
    gids = sorted(fresh.gpus.keys())
    remaining = list(workloads)

    # Step 3: extra-memory profiles first (profile 9, then 15), one per GPU,
    # at the index that captures m7.
    for pid, idx in ((9, 4), (15, 6)):
        for gid in gids:
            if fresh.gpus[gid].memory_occupancy()[-1] is not None:
                continue
            cand = next((w for w in remaining if w.profile_id == pid), None)
            if cand is None:
                break
            prof = device.profile(pid)
            if fresh.gpus[gid].can_place_at(prof, idx):
                fresh.gpus[gid].place(cand.wid, pid, idx)
                remaining.remove(cand)

    # Step 4: sort remaining by profile id (descending size).
    remaining.sort(key=lambda w: (device.profile(w.profile_id).sort_key, w.wid))

    # Step 5: first-fit decreasing with preference-order indexes.
    pending: List[Workload] = []
    for w in remaining:
        prof = device.profile(w.profile_id)
        for gid in gids:
            idx = fresh.gpus[gid].first_feasible_index(prof)
            if idx is not None:
                fresh.gpus[gid].place(w.wid, w.profile_id, idx)
                break
        else:
            pending.append(w)
    return pending
