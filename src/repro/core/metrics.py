"""Table-3 evaluation metrics.

All metrics are computed on a *final* ClusterState against the *initial*
ClusterState (for migration-related metrics) and the workload set (for
pending-related metrics).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from .state import ClusterState, Workload

__all__ = ["PlacementMetrics", "evaluate"]


@dataclasses.dataclass
class PlacementMetrics:
    n_gpus: int
    memory_wastage: int
    compute_wastage: int
    availability: int
    migration_size: int
    pending_model_size: int
    sequential_migrations: int
    memory_utilization: float
    compute_utilization: float
    n_pending: int
    n_migrations: int
    #: mean free-slice fragmentation over used GPUs (Ting et al.): 0 = every
    #: GPU's free space is one contiguous run, ->1 = shattered free space.
    fragmentation: float = 0.0

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def evaluate(
    final: ClusterState,
    initial: Optional[ClusterState] = None,
    all_workloads: Optional[Sequence[Workload]] = None,
) -> PlacementMetrics:
    """Compute every Table-3 metric for a final placement solution."""
    final.validate()
    used = final.used_gpus()
    n_gpus = len(used)

    memory_wastage = sum(g.memory_waste() for g in used)
    compute_wastage = sum(g.compute_waste() for g in used)

    # Pending workloads: requested but not placed anywhere.
    placed_wids = {p.wid for g in final.gpus.values() for p in g.placements}
    pending: List[Workload] = []
    if all_workloads is not None:
        pending = [w for w in all_workloads if w.wid not in placed_wids]
    pending_size = sum(
        w.profile(final.gpus[next(iter(final.gpus))].device).memory_slices
        for w in pending
    ) if final.gpus else 0

    # Availability: free GPU slices cluster-wide minus total pending size.
    free_slices = sum(len(g.free_gpu_slices()) for g in final.gpus.values())
    availability = free_slices - pending_size

    # Migration metrics need the initial state.
    migration_size = 0
    sequential = 0
    n_migrations = 0
    if initial is not None:
        for wid in placed_wids:
            src = initial.placement_of(wid)
            dst = final.placement_of(wid)
            if src is None or dst is None:
                continue
            (src_gid, src_pl), (dst_gid, dst_pl) = src, dst
            if src_gid == dst_gid and src_pl.index == dst_pl.index:
                continue
            n_migrations += 1
            if src_gid != dst_gid:
                device = final.gpus[dst_gid].device
                migration_size += device.profile(dst_pl.profile_id).memory_slices
                # Sequential migration: the target (index, profile) span was
                # not free in the *initial* state of the destination GPU.
                prof = device.profile(dst_pl.profile_id)
                if not initial.gpus[dst_gid].can_place_at(prof, dst_pl.index):
                    sequential += 1

    # Utilizations over *used* GPUs only (Table 3).
    tot_mem = sum(g.device.n_memory_slices for g in used)
    tot_cmp = sum(g.device.n_gpu_slices for g in used)
    used_mem = sum(g.used_memory_slices() for g in used)
    used_cmp = sum(g.used_compute_slices() for g in used)

    return PlacementMetrics(
        n_gpus=n_gpus,
        memory_wastage=memory_wastage,
        compute_wastage=compute_wastage,
        availability=availability,
        migration_size=migration_size,
        pending_model_size=pending_size,
        sequential_migrations=sequential,
        memory_utilization=used_mem / tot_mem if tot_mem else 0.0,
        compute_utilization=used_cmp / tot_cmp if tot_cmp else 0.0,
        n_pending=len(pending),
        n_migrations=n_migrations,
        fragmentation=(
            sum(g.fragmentation() for g in used) / len(used) if used else 0.0
        ),
    )
