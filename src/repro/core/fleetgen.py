"""Shared fleet construction (used by simulator, events, and benchmarks).

``core/simulator.py`` (random Sec-5.1 test cases) and ``core/events.py``
(online traces over possibly-mixed fleets) used to build clusters through
separate code paths; this module is the single builder both call.

gid naming is caller-controlled via ``gid_format`` so the two historical
schemes stay byte-identical:

  * test cases:  ``gpu{i}``   (``ClusterState.homogeneous`` style)
  * trace fleets: ``{tag}-{i}`` where tag is the lowercased device-name stem

Indexes continue across spec entries sharing a tag, so two ``(A100_80GB, n)``
entries yield distinct gids instead of colliding.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

from .profiles import DeviceModel
from .state import ClusterState, GPUState

__all__ = ["FleetSpec", "build_fleet"]

#: (device model, count) pairs describing a possibly-mixed fleet.
FleetSpec = Sequence[Tuple[DeviceModel, int]]


def build_fleet(spec: FleetSpec, gid_format: str = "{tag}-{i}") -> ClusterState:
    """A (possibly heterogeneous) cluster from (device, count) pairs."""
    gpus: Dict[str, GPUState] = {}
    next_i: Dict[str, int] = {}
    for device, count in spec:
        tag = device.name.split("-")[0].lower()
        for _ in range(count):
            i = next_i.get(tag, 0)
            next_i[tag] = i + 1
            gid = gid_format.format(tag=tag, i=i)
            if gid in gpus:
                raise ValueError(f"gid collision {gid!r} (gid_format={gid_format!r})")
            gpus[gid] = GPUState(gid, device)
    return ClusterState(gpus=gpus)
