"""Beyond-paper: pattern-enumeration exact placement (Gilmore–Gomory style).

The WPM MIP's variable count grows as O(|W| x |G|), which is why the paper
caps CPLEX at 30 s for 80-GPU clusters.  But the *content* of one GPU is one
of a small finite set of index-feasible profile multisets ("patterns" —
a few hundred for the A100 geometry).  Reconfiguration (and any placement
onto empty devices) therefore reduces to an integer program over pattern
counts whose size is INDEPENDENT of cluster size:

    min   sum_P n_P * (q + gamma_W * waste(P))
    s.t.  sum_P n_P * count_P(profile) = demand(profile)   for each profile
          n_P >= 0 integer

With ~6 coverage rows and a few hundred columns this solves in milliseconds
for clusters of any size (we demonstrate 10k+ GPUs in the solver-scaling
benchmark), and the solution is provably optimal for the (#GPUs, wastage)
objective.  Waste per pattern is precomputed once via the exact indexing
step, so the reported wastage is index-accurate, not the bin-level proxy.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .indexing import assign_indexes, enumerate_feasible_multisets
from .profiles import A100_80GB, DeviceModel
from .state import ClusterState, GPUState, Workload

__all__ = ["Pattern", "pattern_catalog", "reconfigure_patterns", "PatternResult"]


@dataclasses.dataclass(frozen=True)
class Pattern:
    counts: Tuple[Tuple[int, int], ...]  # sorted (profile_id, n)
    compute_waste: int
    memory_waste: int
    layout: Tuple[Tuple[int, int], ...]  # (profile_id, index) optimal indexing

    @property
    def size(self) -> int:
        return sum(n for _, n in self.counts)


@functools.lru_cache(maxsize=8)
def pattern_catalog(device: DeviceModel = A100_80GB) -> Tuple[Pattern, ...]:
    """All index-feasible patterns with their optimal-waste concrete layouts."""
    out: List[Pattern] = []
    for counts in enumerate_feasible_multisets(device):
        flat: List[int] = []
        for pid, n in sorted(counts.items()):
            flat.extend([pid] * n)
        gpu = GPUState("_pat", device)
        placements = assign_indexes(gpu, flat, optimize=True)
        assert placements is not None  # feasible by construction
        gpu.placements.extend(placements)
        out.append(
            Pattern(
                counts=tuple(sorted(counts.items())),
                compute_waste=gpu.compute_waste(),
                memory_waste=gpu.memory_waste(),
                layout=tuple((p.profile_id, p.index) for p in placements),
            )
        )
    return tuple(out)


@dataclasses.dataclass
class PatternResult:
    state: ClusterState
    n_gpus: int
    objective: float
    solve_seconds: float
    status: str


def reconfigure_patterns(
    state: ClusterState,
    extra_workloads: Sequence[Workload] = (),
    gpu_cost: float = 100.0,
    wastage_cost: float = 10.0,
    time_limit: float = 30.0,
) -> PatternResult:
    """Optimal reconfiguration: re-place ALL workloads (plus extras) from scratch.

    Requires enough total GPUs; raises otherwise.  Solution is exact for the
    (#GPUs, total index-level wastage) objective.
    """
    t0 = time.time()
    device = next(iter(state.gpus.values())).device
    workloads = list(state.placed_workloads()) + list(extra_workloads)
    demand: Dict[int, int] = {}
    for w in workloads:
        demand[w.profile_id] = demand.get(w.profile_id, 0) + 1

    cat = [
        p
        for p in pattern_catalog(device)
        if all(pid in demand for pid, _ in p.counts)
    ]
    pids = sorted(demand)
    A = np.zeros((len(pids), len(cat)))
    for j, pat in enumerate(cat):
        for pid, n in pat.counts:
            A[pids.index(pid), j] = n
    cost = np.array(
        [gpu_cost + wastage_cost * (p.compute_waste + p.memory_waste) for p in cat]
    )
    b = np.array([demand[p] for p in pids], dtype=float)

    from scipy.optimize import Bounds, LinearConstraint, milp

    n_max = len(state.gpus)
    res = milp(
        c=cost,
        constraints=[LinearConstraint(A, b, b)],
        integrality=np.ones(len(cat), dtype=np.int64),
        bounds=Bounds(np.zeros(len(cat)), np.full(len(cat), float(n_max))),
        options={"time_limit": time_limit},
    )
    if res.x is None:
        raise RuntimeError(f"pattern ILP infeasible: {res.message}")
    counts = np.round(res.x).astype(int)
    n_used = int(counts.sum())
    if n_used > len(state.gpus):
        raise RuntimeError(f"needs {n_used} GPUs, cluster has {len(state.gpus)}")

    # Materialize: assign concrete workloads to pattern slots, preferring to
    # keep workloads on their current GPU when the pattern matches (reduces
    # migration size at no objective cost).
    final = ClusterState(
        gpus={gid: GPUState(gid, state.gpus[gid].device) for gid in state.gpus},
        workloads={w.wid: w for w in workloads},
    )
    pool: Dict[int, List[Workload]] = {}
    for w in workloads:
        pool.setdefault(w.profile_id, []).append(w)
    # Fill free GPUs first (one-shot migration, paper Sec 2.3.3).
    order = [g.gid for g in state.free_gpus()] + [g.gid for g in state.used_gpus()]
    gi = 0
    for j, n in enumerate(counts):
        for _ in range(int(n)):
            gid = order[gi]
            gi += 1
            for pid, idx in cat[j].layout:
                w = pool[pid].pop()
                final.gpus[gid].place(w.wid, pid, idx)
    final.validate()
    return PatternResult(
        state=final,
        n_gpus=n_used,
        objective=float(cost @ counts),
        solve_seconds=time.time() - t0,
        status="optimal" if res.status == 0 else "time_limit",
    )
