"""Baseline schedulers from the paper's evaluation (Sec 5.1, 'Approaches').

* ``first_fit``      — sort GPUs and workloads by ID; place each workload at
                       the first feasible (GPU, index), indexes tried in
                       increasing numeric order starting at 0.
* ``load_balanced``  — resource-based dynamic load balancing: GPUs sorted by
                       joint slice utilization ascending (re-sorted after
                       every placement); workloads in arrival order; indexes
                       tried in increasing numeric order starting at 0.

Both mutate the given state and return the list of pending workloads.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from .state import ClusterState, GPUState, Workload

__all__ = ["first_fit", "load_balanced", "place_max_utilization"]


def _numeric_index_order(profile) -> List[int]:
    return sorted(profile.allowed_indexes)


def _try_place(
    gpu: GPUState, w: Workload, numeric_order: bool
) -> Optional[int]:
    prof = gpu.device.profile(w.profile_id)
    order = _numeric_index_order(prof) if numeric_order else prof.allowed_indexes
    return gpu.first_feasible_index(prof, order)


def first_fit(
    state: ClusterState, workloads: Sequence[Workload]
) -> List[Workload]:
    """First-fit by IDs; returns pending workloads."""
    pending: List[Workload] = []
    gids = state.ordered_gids()
    for w in sorted(workloads, key=lambda w: w.wid):
        state.add_workload(w)
        placed = False
        for gid in gids:
            idx = _try_place(state.gpus[gid], w, numeric_order=True)
            if idx is not None:
                state.place(w.wid, gid, idx)
                placed = True
                break
        if not placed:
            pending.append(w)
    return pending


def load_balanced(
    state: ClusterState, workloads: Sequence[Workload]
) -> List[Workload]:
    """Resource-based dynamic load balancing; returns pending workloads."""
    pending: List[Workload] = []
    for w in workloads:  # arrival order
        state.add_workload(w)
        ordered = sorted(
            state.gpus.values(),
            key=lambda g: (g.joint_slice_utilization(), g.gid),
        )
        placed = False
        for gpu in ordered:
            idx = _try_place(gpu, w, numeric_order=True)
            if idx is not None:
                state.place(w.wid, gpu.gid, idx)
                placed = True
                break
        if not placed:
            pending.append(w)
    return pending


def place_max_utilization(
    state: ClusterState,
    w: Workload,
    candidates: Optional[Sequence[str]] = None,
    allow_new_gpu: bool = True,
) -> Optional[Tuple[str, int]]:
    """Rule-based placement primitive (Sec 4.2, initial deployment Step 3).

    Choose the GPU whose joint slice utilization is maximal *after* the
    assignment (ties broken towards lower waste index via the Table-1
    preference order); falls back to allocating a free GPU.
    Returns (gid, index) without mutating state, or None.
    """
    prof = state.gpus[next(iter(state.gpus))].device.profile(w.profile_id)
    pool = candidates if candidates is not None else state.ordered_gids()
    best: Optional[Tuple[float, str, int]] = None
    for gid in pool:
        gpu = state.gpus[gid]
        if gpu.is_empty() and candidates is None:
            continue  # used GPUs first; free GPUs are the fallback
        idx = gpu.first_feasible_index(prof)
        if idx is None:
            continue
        util = gpu.joint_slice_utilization()
        if best is None or util > best[0] or (util == best[0] and gid < best[1]):
            best = (util, gid, idx)
    if best is not None:
        return best[1], best[2]
    if allow_new_gpu and candidates is None:
        for gpu in sorted(state.free_gpus(), key=lambda g: g.gid):
            idx = gpu.first_feasible_index(prof)
            if idx is not None:
                return gpu.gid, idx
    return None
