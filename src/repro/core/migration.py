"""Migration planner + cost model (framework component 2, Fig. 1).

Given an initial and a final ClusterState, derive an executable plan:
ordered *waves* of moves where every move in a wave can run simultaneously
(its destination span is free once the previous waves completed).  Moves
whose destinations are free in the initial state form wave 0 — these are the
paper's non-disruptive one-shot migrations.  Cyclic dependencies (A waits on
B waits on A) are broken by marking one move per cycle *disruptive* (the
workload must be drained before redeployment), mirroring the paper's
discussion of Figure 4 -> Figure 5 without free GPUs.

Plans are *priced*, not just counted.  ``MigrationCostModel`` converts every
move into bytes-to-transfer (model weights + live KV-cache footprint when
the serving layer supplies per-workload sizes), estimated downtime seconds
(a short traffic-cutover blackout for wave-parallel copies vs a full
drain -> transfer -> resume for disruptive moves), and an SLO-disruption
scalar weighted by each workload's ``migration_cost``.  ``CommitPolicy``
then decides whether a scored plan's gains (GPUs saved, wastage removed)
justify its disruption — the decision rule behind the engine's
plan/score/commit control plane.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, List, Optional, Set, Tuple

from .state import ClusterState, Placement

__all__ = [
    "Move",
    "MigrationPlan",
    "plan_migration",
    "MoveCost",
    "PlanCost",
    "MigrationCostModel",
    "PlanGains",
    "CommitDecision",
    "CommitPolicy",
    "COMMIT_MODES",
]


@dataclasses.dataclass(frozen=True)
class Move:
    wid: str
    src_gid: Optional[str]  # None for a brand-new workload
    src_index: Optional[int]
    dst_gid: str
    dst_index: int
    profile_id: int
    disruptive: bool = False


@dataclasses.dataclass
class MigrationPlan:
    waves: List[List[Move]]
    disruptive: List[Move]
    #: filled by MigrationCostModel.price() on the engine's scoring path.
    cost: Optional["PlanCost"] = None

    def iter_moves(self) -> Iterator[Move]:
        for wave in self.waves:
            yield from wave
        yield from self.disruptive

    @property
    def n_moves(self) -> int:
        return sum(len(w) for w in self.waves) + len(self.disruptive)

    @property
    def n_migrations(self) -> int:
        """Moves of already-placed workloads (excludes fresh deployments)."""
        return sum(1 for mv in self.iter_moves() if mv.src_gid is not None)

    @property
    def n_sequential(self) -> int:
        """Moves that could not run in wave 0 (paper's sequential metric)."""
        return self.n_moves - (len(self.waves[0]) if self.waves else 0)


def _span(state: ClusterState, gid: str, pl: Placement) -> Set[Tuple[str, int]]:
    device = state.gpus[gid].device
    mem, _ = device.profile(pl.profile_id).span(pl.index, device.n_gpu_slices)
    return {(gid, pos) for pos in mem}


def plan_migration(initial: ClusterState, final: ClusterState) -> MigrationPlan:
    """Topologically order the moves needed to reach ``final`` from ``initial``."""
    moves: Dict[str, Move] = {}
    src_spans: Dict[str, Set[Tuple[str, int]]] = {}
    dst_spans: Dict[str, Set[Tuple[str, int]]] = {}

    for gid, gpu in final.gpus.items():
        for pl in gpu.placements:
            src = initial.placement_of(pl.wid)
            if src is not None:
                src_gid, src_pl = src
                if src_gid == gid and src_pl.index == pl.index:
                    continue  # unmoved
                mv = Move(pl.wid, src_gid, src_pl.index, gid, pl.index, pl.profile_id)
                src_spans[pl.wid] = _span(initial, src_gid, src_pl)
            else:
                mv = Move(pl.wid, None, None, gid, pl.index, pl.profile_id)
                src_spans[pl.wid] = set()
            moves[pl.wid] = mv
            dst_spans[pl.wid] = _span(final, gid, pl)

    # Slices occupied in the initial state by workloads that are NOT moving
    # (and not being removed) permanently block their span.
    moving = set(moves)
    final_wids = {p.wid for g in final.gpus.values() for p in g.placements}
    blocked: Set[Tuple[str, int]] = set()
    for gid, gpu in initial.gpus.items():
        for pl in gpu.placements:
            if pl.wid not in moving and pl.wid in final_wids:
                blocked |= _span(initial, gid, pl)

    # Dependency edges: move a depends on move b iff a's destination overlaps
    # b's initial span (b must vacate before a lands).
    deps: Dict[str, Set[str]] = {w: set() for w in moves}
    for a in moves:
        if dst_spans[a] & blocked:
            # Destination overlaps an immovable placement: infeasible final
            # state; treat as disruptive (should not happen for valid plans).
            pass
        for b in moves:
            if a != b and dst_spans[a] & src_spans[b]:
                deps[a].add(b)

    # Kahn's algorithm into waves; break cycles disruptively.
    waves: List[List[Move]] = []
    disruptive: List[Move] = []
    remaining = dict(deps)
    done: Set[str] = set()
    while remaining:
        ready = sorted(w for w, d in remaining.items() if d <= done)
        if not ready:
            # cycle: evict the workload with the smallest footprint (cheapest
            # to drain) and retry.
            victim = min(
                remaining,
                key=lambda w: (len(dst_spans[w]), w),
            )
            disruptive.append(dataclasses.replace(moves[victim], disruptive=True))
            done.add(victim)
            del remaining[victim]
            continue
        waves.append([moves[w] for w in ready])
        for w in ready:
            done.add(w)
            del remaining[w]
    if not waves:
        waves = [[]]
    return MigrationPlan(waves=waves, disruptive=disruptive)


# ---------------------------------------------------------------------------
# cost model: bytes / downtime / SLO disruption per move and per plan
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MoveCost:
    """Price of one move."""

    wid: str
    bytes: int
    transfer_seconds: float
    downtime_seconds: float
    slo_disruption: float
    disruptive: bool


@dataclasses.dataclass(frozen=True)
class PlanCost:
    """Price of a whole plan (sums + per-wave makespans)."""

    total_bytes: int
    downtime_seconds: float  # summed per-workload unavailability
    duration_seconds: float  # wall-clock migration window (waves + drains)
    slo_disruption: float  # migration_cost-weighted downtime
    n_moves: int
    n_disruptive: int
    wave_makespans: Tuple[float, ...] = ()

    def as_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d["wave_makespans"] = list(self.wave_makespans)
        return d


#: wid -> live bytes (weights + KV) supplied by the serving layer; return
#: None to fall back to the profile-derived estimate.
BytesFor = Callable[[str], Optional[int]]


@dataclasses.dataclass(frozen=True)
class MigrationCostModel:
    """Prices moves in bytes, downtime seconds, and SLO disruption.

    Non-disruptive moves copy state while the source replica keeps serving
    (wave-parallel copies), so their only unavailability is the traffic
    cutover; disruptive moves must drain first, so their downtime covers the
    drain, the transfer itself, and the cold resume.  A wave's *makespan* is
    the slowest transfer in it (copies within a wave run in parallel on
    disjoint links); the plan's duration is the sum of wave makespans plus
    the serialized disruptive drains.
    """

    #: effective copy bandwidth per move, GB/s (NVLink/ICI-class link).
    link_gbps: float = 50.0
    #: live-state bytes per occupied memory slice; set it to override the
    #: device-derived estimate.  None (default) derives from the device's
    #: ``mem_per_slice_gb`` (10 GiB fallback when a device lacks it).
    bytes_per_memory_slice: Optional[int] = None
    #: traffic-switch blackout for a non-disruptive (copied-then-cutover) move.
    cutover_seconds: float = 0.5
    #: drain + partition-teardown lead time before a disruptive move.
    drain_seconds: float = 5.0
    #: cold resume after a disruptive redeploy.
    resume_seconds: float = 1.0
    #: global scale on the SLO-disruption scalar.
    slo_weight: float = 1.0

    # -- per-move ----------------------------------------------------------
    def move_bytes(
        self, move: Move, state: ClusterState, bytes_for: Optional[BytesFor] = None
    ) -> int:
        """Live bytes to transfer for ``move`` (0 for fresh deployments)."""
        if move.src_gid is None:
            return 0  # new workload: weights stream from storage, no live state
        if bytes_for is not None:
            b = bytes_for(move.wid)
            if b is not None:
                return int(b)
        device = state.gpus[move.dst_gid].device
        prof = device.profile(move.profile_id)
        if self.bytes_per_memory_slice is not None:
            per_slice = self.bytes_per_memory_slice
        else:
            gb = getattr(device, "mem_per_slice_gb", None)
            per_slice = (int(gb) << 30) if gb else (10 << 30)
        return prof.memory_slices * per_slice

    def transfer_seconds(self, n_bytes: int) -> float:
        return n_bytes / (self.link_gbps * 1e9)

    def move_cost(
        self, move: Move, state: ClusterState, bytes_for: Optional[BytesFor] = None
    ) -> MoveCost:
        b = self.move_bytes(move, state, bytes_for)
        xfer = self.transfer_seconds(b)
        if move.src_gid is None:
            downtime = 0.0  # fresh deployment: nothing was serving yet
        elif move.disruptive:
            downtime = self.drain_seconds + xfer + self.resume_seconds
        else:
            downtime = self.cutover_seconds
        w = state.workloads.get(move.wid)
        weight = w.migration_cost if w is not None else 1.0
        return MoveCost(
            wid=move.wid,
            bytes=b,
            transfer_seconds=xfer,
            downtime_seconds=downtime,
            slo_disruption=self.slo_weight * weight * downtime,
            disruptive=move.disruptive,
        )

    # -- per-plan ----------------------------------------------------------
    def price(
        self,
        plan: MigrationPlan,
        state: ClusterState,
        bytes_for: Optional[BytesFor] = None,
    ) -> PlanCost:
        """Score ``plan`` against ``state`` (the state holding the workloads
        and destination devices — either endpoint works for pricing)."""
        total_bytes = 0
        downtime = 0.0
        slo = 0.0
        duration = 0.0
        makespans: List[float] = []
        n_moves = 0
        n_disruptive = 0
        for wave in plan.waves:
            span = 0.0
            for mv in wave:
                mc = self.move_cost(mv, state, bytes_for)
                total_bytes += mc.bytes
                downtime += mc.downtime_seconds
                slo += mc.slo_disruption
                if mv.src_gid is not None:
                    span = max(span, mc.transfer_seconds)
                n_moves += 1
            makespans.append(span)
            duration += span
        for mv in plan.disruptive:
            mc = self.move_cost(mv, state, bytes_for)
            total_bytes += mc.bytes
            downtime += mc.downtime_seconds
            slo += mc.slo_disruption
            duration += mc.downtime_seconds  # drains serialize the window
            n_moves += 1
            n_disruptive += 1
        return PlanCost(
            total_bytes=total_bytes,
            downtime_seconds=downtime,
            duration_seconds=duration,
            slo_disruption=slo,
            n_moves=n_moves,
            n_disruptive=n_disruptive,
            wave_makespans=tuple(makespans),
        )


# ---------------------------------------------------------------------------
# commit policy: do the plan's gains justify its disruption?
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PlanGains:
    """What committing the plan buys, measured before vs after."""

    gpus_saved: int = 0
    waste_saved: int = 0  # compute + memory wastage slices removed

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class CommitDecision:
    """The structured verdict of a :class:`CommitPolicy`.

    ``term`` names which gain/budget term decided the outcome and
    ``shortfall`` says by how much it failed (0 for commits), so callers —
    ``EngineResult``, ``TraceStats.plan_rejections``, telemetry — can
    aggregate *why* plans are rejected instead of a bare count:

    * ``"no-op"`` / ``"always"``  — trivially committed
    * ``"moves"``                 — move count over ``move_budget``
    * ``"bytes"``                 — bytes over ``bytes_budget``
    * ``"downtime"``              — downtime over ``downtime_budget_seconds``
    * ``"budgets"``               — budgeted mode, all budgets respected
    * ``"net-benefit"``           — net-positive mode's benefit-vs-price term
    """

    commit: bool
    reason: str
    benefit: float = 0.0
    price: float = 0.0
    #: which term decided (see class docstring).
    term: str = ""
    #: how far the failing term missed (benefit units for ``net-benefit``,
    #: the budgeted quantity's units otherwise); 0.0 when committed.
    shortfall: float = 0.0


COMMIT_MODES = ("always", "net-positive", "budgeted")


@dataclasses.dataclass(frozen=True)
class CommitPolicy:
    """When does a scored plan get committed?

    * ``always``       — unconditional (the pre-control-plane behavior).
    * ``net-positive`` — commit iff the gains, valued in GPU-seconds, exceed
                         the disruption price.  A freed GPU is worth
                         ``gpu_seconds_value`` (roughly: how long it stays
                         free before the next repack), a removed wastage
                         slice ``waste_seconds_value``.  The price is the
                         per-replica SLO disruption plus the fleet-level
                         migration window (wave makespans + drains, weighted
                         by ``window_seconds_weight``) plus an optional
                         network charge per GiB moved.
    * ``budgeted``     — commit iff the plan fits every configured budget
                         (downtime seconds, bytes, move count).
    """

    mode: str = "always"
    #: a freed GPU is only worth the time until churn / the next periodic
    #: repack would re-derive it — tens of seconds at online arrival rates.
    gpu_seconds_value: float = 45.0
    waste_seconds_value: float = 5.0
    window_seconds_weight: float = 1.0
    gib_moved_weight: float = 0.0
    downtime_budget_seconds: Optional[float] = 120.0
    bytes_budget: Optional[int] = None
    move_budget: Optional[int] = None
    #: escalation tier for fault recovery: ``"bypass"`` (default) lets
    #: emergency verbs — re-placing replicas evicted by a failure — run with
    #: gating and budgets lifted (capacity restoration beats disruption
    #: accounting when replicas are DOWN); ``"gated"`` keeps the normal
    #: decision rule even under incident pressure.
    emergency: str = "bypass"

    def __post_init__(self) -> None:
        mode = self.mode.replace("_", "-")
        if mode not in COMMIT_MODES:
            raise ValueError(
                f"commit mode must be one of {COMMIT_MODES}, got {self.mode!r}"
            )
        object.__setattr__(self, "mode", mode)
        if self.emergency not in ("bypass", "gated"):
            raise ValueError(
                f"emergency tier must be 'bypass' or 'gated', "
                f"got {self.emergency!r}"
            )

    def escalate(self) -> Optional["CommitPolicy"]:
        """The emergency tier of this policy, or None if escalation is off.

        Escalation is what the recovery path swaps in around its verbs when
        evicted replicas cannot be re-placed in the free space: an
        always-commit variant with every budget lifted, so a net-negative
        compaction/reconfiguration that MAKES ROOM still commits.  The
        caller restores the normal policy afterwards.
        """
        if self.emergency != "bypass":
            return None
        return dataclasses.replace(
            self,
            mode="always",
            downtime_budget_seconds=None,
            bytes_budget=None,
            move_budget=None,
        )

    def decide(self, gains: PlanGains, cost: PlanCost) -> CommitDecision:
        if cost.n_moves == 0:
            return CommitDecision(True, "no-op plan", term="no-op")
        # The move budget is a hard cap in EVERY mode (it is the legacy
        # ``migration_budget`` contract); the downtime/bytes budgets only
        # bind in ``budgeted`` mode.
        if self.move_budget is not None and cost.n_moves > self.move_budget:
            return CommitDecision(
                False, f"moves {cost.n_moves} > budget {self.move_budget}",
                term="moves", shortfall=float(cost.n_moves - self.move_budget),
            )
        if self.mode == "always":
            return CommitDecision(True, "always-commit", term="always")
        if self.mode == "budgeted":
            if self.bytes_budget is not None and cost.total_bytes > self.bytes_budget:
                return CommitDecision(
                    False, f"bytes {cost.total_bytes} > budget {self.bytes_budget}",
                    term="bytes",
                    shortfall=float(cost.total_bytes - self.bytes_budget),
                )
            if (
                self.downtime_budget_seconds is not None
                and cost.downtime_seconds > self.downtime_budget_seconds
            ):
                return CommitDecision(
                    False,
                    f"downtime {cost.downtime_seconds:.1f}s > "
                    f"budget {self.downtime_budget_seconds:.1f}s",
                    term="downtime",
                    shortfall=cost.downtime_seconds - self.downtime_budget_seconds,
                )
            return CommitDecision(True, "within budgets", term="budgets")
        # net-positive
        benefit = (
            gains.gpus_saved * self.gpu_seconds_value
            + gains.waste_saved * self.waste_seconds_value
        )
        price = (
            cost.slo_disruption
            + self.window_seconds_weight * cost.duration_seconds
            + self.gib_moved_weight * (cost.total_bytes / 2**30)
        )
        if benefit > price:
            return CommitDecision(
                True, f"benefit {benefit:.1f} > disruption {price:.1f}",
                benefit=benefit, price=price, term="net-benefit",
            )
        return CommitDecision(
            False, f"benefit {benefit:.1f} <= disruption {price:.1f}",
            benefit=benefit, price=price, term="net-benefit",
            shortfall=price - benefit,
        )
