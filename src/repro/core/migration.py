"""Migration planner (framework component 2, Fig. 1; future-work item 1).

Given an initial and a final ClusterState, derive an executable plan:
ordered *waves* of moves where every move in a wave can run simultaneously
(its destination span is free once the previous waves completed).  Moves
whose destinations are free in the initial state form wave 0 — these are the
paper's non-disruptive one-shot migrations.  Cyclic dependencies (A waits on
B waits on A) are broken by marking one move per cycle *disruptive* (the
workload must be drained before redeployment), mirroring the paper's
discussion of Figure 4 -> Figure 5 without free GPUs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from .state import ClusterState, Placement

__all__ = ["Move", "MigrationPlan", "plan_migration"]


@dataclasses.dataclass(frozen=True)
class Move:
    wid: str
    src_gid: Optional[str]  # None for a brand-new workload
    src_index: Optional[int]
    dst_gid: str
    dst_index: int
    profile_id: int
    disruptive: bool = False


@dataclasses.dataclass
class MigrationPlan:
    waves: List[List[Move]]
    disruptive: List[Move]

    @property
    def n_moves(self) -> int:
        return sum(len(w) for w in self.waves) + len(self.disruptive)

    @property
    def n_sequential(self) -> int:
        """Moves that could not run in wave 0 (paper's sequential metric)."""
        return self.n_moves - (len(self.waves[0]) if self.waves else 0)


def _span(state: ClusterState, gid: str, pl: Placement) -> Set[Tuple[str, int]]:
    device = state.gpus[gid].device
    mem, _ = device.profile(pl.profile_id).span(pl.index, device.n_gpu_slices)
    return {(gid, pos) for pos in mem}


def plan_migration(initial: ClusterState, final: ClusterState) -> MigrationPlan:
    """Topologically order the moves needed to reach ``final`` from ``initial``."""
    moves: Dict[str, Move] = {}
    src_spans: Dict[str, Set[Tuple[str, int]]] = {}
    dst_spans: Dict[str, Set[Tuple[str, int]]] = {}

    for gid, gpu in final.gpus.items():
        for pl in gpu.placements:
            src = initial.placement_of(pl.wid)
            if src is not None:
                src_gid, src_pl = src
                if src_gid == gid and src_pl.index == pl.index:
                    continue  # unmoved
                mv = Move(pl.wid, src_gid, src_pl.index, gid, pl.index, pl.profile_id)
                src_spans[pl.wid] = _span(initial, src_gid, src_pl)
            else:
                mv = Move(pl.wid, None, None, gid, pl.index, pl.profile_id)
                src_spans[pl.wid] = set()
            moves[pl.wid] = mv
            dst_spans[pl.wid] = _span(final, gid, pl)

    # Slices occupied in the initial state by workloads that are NOT moving
    # (and not being removed) permanently block their span.
    moving = set(moves)
    final_wids = {p.wid for g in final.gpus.values() for p in g.placements}
    blocked: Set[Tuple[str, int]] = set()
    for gid, gpu in initial.gpus.items():
        for pl in gpu.placements:
            if pl.wid not in moving and pl.wid in final_wids:
                blocked |= _span(initial, gid, pl)

    # Dependency edges: move a depends on move b iff a's destination overlaps
    # b's initial span (b must vacate before a lands).
    deps: Dict[str, Set[str]] = {w: set() for w in moves}
    for a in moves:
        if dst_spans[a] & blocked:
            # Destination overlaps an immovable placement: infeasible final
            # state; treat as disruptive (should not happen for valid plans).
            pass
        for b in moves:
            if a != b and dst_spans[a] & src_spans[b]:
                deps[a].add(b)

    # Kahn's algorithm into waves; break cycles disruptively.
    waves: List[List[Move]] = []
    disruptive: List[Move] = []
    remaining = dict(deps)
    done: Set[str] = set()
    while remaining:
        ready = sorted(w for w, d in remaining.items() if d <= done)
        if not ready:
            # cycle: evict the workload with the smallest footprint (cheapest
            # to drain) and retry.
            victim = min(
                remaining,
                key=lambda w: (len(dst_spans[w]), w),
            )
            disruptive.append(dataclasses.replace(moves[victim], disruptive=True))
            done.add(victim)
            del remaining[victim]
            continue
        waves.append([moves[w] for w in ready])
        for w in ready:
            done.add(w)
            del remaining[w]
    if not waves:
        waves = [[]]
    return MigrationPlan(waves=waves, disruptive=disruptive)
