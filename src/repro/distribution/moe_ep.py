"""Expert-parallel MoE over the 'model' mesh axis (production path).

The baseline "dispatch" implementation (models/moe.py) pays two dense
(T x E*C x D) one-hot einsums per MoE layer — O(T * T*k*cf * D) FLOPs, which
is why dispatch-MoE cells show useful-FLOPs ratios under 0.1.  This module
replaces dispatch/combine with sort + scatter/gather bookkeeping inside a
``jax.shard_map`` over the model axis:

  * activations enter replicated across 'model' (the TP convention between
    blocks), token-sharded across the data axes;
  * each device builds capacity-bounded buffers for the experts IT OWNS
    (argsort by expert id, positions via searchsorted — O(T k log(Tk))
    bookkeeping, zero matmul FLOPs);
  * per-device expert FFN on (E_local, C, D) — the only dense compute;
  * combine = scatter-add back to token slots + ``psum`` over 'model'
    (one (T_local, D) all-reduce, the same wire cost as a TP MLP).

Expert/mesh shape handling:
  * E >= m ("model" size): E_local = E/m experts per device (DeepSeek-V3:
    256 experts over 16 -> 16/device);
  * E <  m: each expert is REPLICATED over rep = m/E devices with its FFN
    hidden dim F split rep ways (expert+tensor hybrid; Mixtral: 8 experts
    over 16 -> every expert on 2 devices with F/2 each).  The closing psum
    sums the TP partials and the EP combine in one collective.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ._shardmap import shard_map

__all__ = ["apply_moe_alltoall"]


def _mesh_info():
    from . import sharding

    ctx = sharding.current()
    if ctx is None:
        return None, (), 1, 1
    mesh = ctx["mesh"]
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = 1
    for a in daxes:
        dp *= mesh.shape[a]
    m = mesh.shape.get("model", 1)
    return mesh, daxes, dp, m


def _local_moe(xt, gates, eidx, wg, wu, wo, *, e_local: int, rep: int,
               cap: int, k: int):
    """Per-device EP MoE: xt (Tl,D) replicated over 'model', token-sharded
    over data; wg/wu/wo are THIS device's expert slices (E_local, D, Fl)."""
    t, d = xt.shape
    r = jax.lax.axis_index("model")
    e_lo = (r // rep) * e_local  # first global expert owned here

    # ---- dispatch bookkeeping (sort + positions; no matmuls) -------------
    ef = eidx.reshape(-1)  # (T*k,) global expert ids
    mine = (ef >= e_lo) & (ef < e_lo + e_local)
    key = jnp.where(mine, ef - e_lo, e_local)  # foreign -> sentinel bucket
    order = jnp.argsort(key, stable=True)
    se = key[order]  # sorted local-expert ids (sentinel last)
    seg_start = jnp.searchsorted(se, jnp.arange(e_local + 1))
    pos = jnp.arange(t * k) - seg_start[jnp.clip(se, 0, e_local)]
    keep = (se < e_local) & (pos < cap)
    src_tok = order // k

    # scatter tokens into (E_local, C, D); out-of-bounds rows are dropped
    e_idx = jnp.where(keep, se, e_local)
    c_idx = jnp.where(keep, pos, 0)
    buf = jnp.zeros((e_local, cap, d), xt.dtype)
    buf = buf.at[e_idx, c_idx].set(
        jnp.where(keep[:, None], xt[src_tok], 0).astype(xt.dtype),
        mode="drop",
    )

    # ---- expert FFN (the only dense compute) ------------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    a = jax.nn.silu(g.astype(jnp.float32)).astype(u.dtype) * u
    out = jnp.einsum("ecf,efd->ecd", a, wo)  # (E_local, C, D)

    # ---- combine: gather back + weighted scatter-add by token -------------
    vals = out[jnp.clip(e_idx, 0, e_local - 1), c_idx]  # (T*k, D)
    gsort = gates.reshape(-1)[order]
    w = jnp.where(keep, gsort, 0.0).astype(jnp.float32)
    y = jnp.zeros((t, d), jnp.float32).at[src_tok].add(vals.astype(jnp.float32) * w[:, None])
    return jax.lax.psum(y, "model").astype(xt.dtype)


def apply_moe_alltoall(
    p: Dict[str, Any], xt: jnp.ndarray, gates: jnp.ndarray,
    eidx: jnp.ndarray, cfg: ArchConfig
) -> jnp.ndarray:
    mesh, daxes, dp, m = _mesh_info()
    e, k = cfg.n_experts, cfg.experts_per_token
    experts = p["experts"]
    if mesh is None or "model" not in mesh.axis_names or (e % m and m % e):
        # no EP mesh (or incompatible expert count): grouped dispatch
        from ..models.moe import _apply_dispatch

        return _apply_dispatch(p, xt, gates, eidx, cfg)

    t = xt.shape[0]
    if t % dp:
        dp, daxes = 1, ()  # tiny batch (e.g. long-context decode): replicate
    t_local = max(1, t // dp)
    e_local = max(1, e // m)
    rep = max(1, m // e)
    cap = max(4, int(math.ceil(t_local * k / e * cfg.capacity_factor)))
    cap = min(cap, t_local * k)

    wg, wu, wo = experts["w_gate"], experts["w_up"], experts["w_out"]
    if rep > 1:  # expert+tensor hybrid: split F over rep replicas
        ef, d_, f_ = wg.shape
        wg = wg.reshape(ef, d_, rep, f_ // rep).transpose(0, 2, 1, 3).reshape(ef * rep, d_, f_ // rep)
        wu = wu.reshape(ef, d_, rep, f_ // rep).transpose(0, 2, 1, 3).reshape(ef * rep, d_, f_ // rep)
        wo = wo.reshape(ef, rep, f_ // rep, d_).reshape(ef * rep, f_ // rep, d_)

    tok_spec = P(daxes if len(daxes) > 1 else (daxes[0] if daxes else None))
    fn = partial(_local_moe, e_local=e_local, rep=rep, cap=cap, k=k)
    return shard_map(
        fn,
        mesh=mesh,
        in_specs=(
            P(*tok_spec, None), P(*tok_spec, None), P(*tok_spec, None),
            P("model", None, None), P("model", None, None), P("model", None, None),
        ),
        out_specs=P(*tok_spec, None),
        check_vma=False,
    )(xt, gates, eidx, wg, wu, wo)
