"""Analytic collective sizing: expected wire bytes per collective on the
production meshes.

Used two ways:
  * cross-check of the HLO-derived collective term (tests/test_distribution
    asserts the analyzer's per-kind totals are within a factor of the
    analytic prediction for known patterns);
  * napkin math for §Perf hypotheses (predict the delta of a sharding
    change before paying a re-lower).

Conventions: ``nbytes`` is the LOGICAL (unsharded) tensor size; ``n`` is the
participant count along the collective's mesh axis.  Returned numbers are
bytes ENTERING the wire per device (ring algorithms), matching the roofline
term's ``collective_bytes / link_bw`` definition.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

__all__ = ["ring_all_reduce", "all_gather", "reduce_scatter", "all_to_all",
           "CollectiveModel"]


def ring_all_reduce(nbytes: float, n: int) -> float:
    """Ring AR = reduce-scatter + all-gather: 2 * (n-1)/n * N per device."""
    return 2.0 * (n - 1) / n * nbytes if n > 1 else 0.0


def all_gather(nbytes: float, n: int) -> float:
    """Each device receives the other shards: (n-1)/n * N."""
    return (n - 1) / n * nbytes if n > 1 else 0.0


def reduce_scatter(nbytes: float, n: int) -> float:
    return (n - 1) / n * nbytes if n > 1 else 0.0


def all_to_all(nbytes: float, n: int) -> float:
    """Each device keeps 1/n locally, sends the rest: (n-1)/n * N_local."""
    return (n - 1) / n * nbytes / n if n > 1 else 0.0


@dataclasses.dataclass(frozen=True)
class CollectiveModel:
    """Per-step analytic collective volume for a TP(+FSDP) transformer."""

    n_layers: int
    d_model: int
    d_ff: int
    params_bytes: float
    tp: int
    dp: int
    act_bytes_per_layer: float  # (tokens_local * d_model * dtype) unsharded

    def tp_all_reduce_bytes(self) -> float:
        """2 row-parallel matmul partial-sums per layer (attn out + MLP out)."""
        per = ring_all_reduce(self.act_bytes_per_layer, self.tp)
        return 2.0 * self.n_layers * per

    def fsdp_gather_bytes(self) -> float:
        """Weight all-gather over dp, once per use (fwd; x2 more for bwd)."""
        return all_gather(self.params_bytes / max(self.tp, 1), self.dp)

    def grad_reduce_bytes(self) -> float:
        """Gradient reduce-scatter over dp (ZeRO) per step."""
        return reduce_scatter(self.params_bytes / max(self.tp, 1), self.dp)

    def summary(self) -> Dict[str, float]:
        return {
            "tp_all_reduce": self.tp_all_reduce_bytes(),
            "fsdp_gather": self.fsdp_gather_bytes(),
            "grad_reduce": self.grad_reduce_bytes(),
        }
