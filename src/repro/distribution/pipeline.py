"""GPipe pipeline parallelism over the 'pod' mesh axis.

The multi-pod mesh's ``pod`` axis is the DCN boundary: inter-pod links are
an order of magnitude slower than intra-pod ICI, so the only traffic that
belongs on them is (a) data-parallel gradient reduction or (b) pipeline
activations.  This module provides (b): layers are split into one stage per
pod; microbatches stream through stages with ``ppermute`` handoffs (the
GPipe fill/drain schedule).

``jax.shard_map`` is manual over ONLY the stage axis — inside a stage the
usual GSPMD data/model sharding still applies, so PP composes with DP/TP.

  y = gpipe(stage_fn, stage_params, x, mesh=mesh, n_micro=4)

stage_params: pytree whose leaves have a leading ``n_stages`` dim (sharded
over 'pod').  stage_fn(params_one_stage, x_mb) -> y_mb applies ONE stage.
x: (n_micro, mb, ...) microbatched inputs, replicated over 'pod'.
Bubble fraction is the GPipe (S-1)/(S-1+M); pick n_micro >> n_stages.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ._shardmap import shard_map

__all__ = ["gpipe"]


def gpipe(
    stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
    stage_params: Any,
    x: jnp.ndarray,
    *,
    mesh,
    n_micro: int,
    stage_axis: str = "pod",
) -> jnp.ndarray:
    n_stages = mesh.shape[stage_axis]
    assert x.shape[0] == n_micro, "x must be (n_micro, mb, ...)"
    if n_stages == 1:
        def seq(params, xs):
            def body(h, p):
                return jax.vmap(stage_fn, in_axes=(None, 0))(p, h), None
            # params leaves: (1, ...) -> apply the single stage per microbatch
            p0 = jax.tree.map(lambda a: a[0], params)
            return jax.vmap(stage_fn, in_axes=(None, 0))(p0, xs)
        return seq(stage_params, x)

    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def local(params_st, xs):
        # params_st leaves: (1, ...) — this rank's stage
        p_local = jax.tree.map(lambda a: a[0], params_st)
        r = jax.lax.axis_index(stage_axis)
        total = n_micro + n_stages - 1
        buf = jnp.zeros_like(xs[0])
        y = jnp.zeros_like(xs)

        def step(t, carry):
            buf, y = carry
            # stage 0 ingests microbatch t (while available); others use buf
            feed_idx = jnp.clip(t, 0, n_micro - 1)
            inp = jnp.where(r == 0, xs[feed_idx], buf)
            out = stage_fn(p_local, inp)
            # hand off to the next stage over the DCN link
            nxt = jax.lax.ppermute(out, stage_axis, perm)
            # last stage emits microbatch t-(S-1)
            oidx = t - (n_stages - 1)
            upd = jax.lax.dynamic_update_slice_in_dim(
                y, out[None], jnp.clip(oidx, 0, n_micro - 1), axis=0
            )
            y = jnp.where((r == n_stages - 1) & (oidx >= 0), upd, y)
            return nxt, y

        buf, y = jax.lax.fori_loop(0, total, step, (buf, y))
        # results live on the last stage; broadcast so out_specs can be
        # replicated over the stage axis (callers usually reduce right after)
        return jax.lax.psum(
            jnp.where(r == n_stages - 1, y, jnp.zeros_like(y)), stage_axis
        )

    pspec = jax.tree.map(lambda _: P(stage_axis), stage_params)
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(pspec, P()),
        out_specs=P(),
        axis_names={stage_axis},
        check_vma=False,
    )(stage_params, x)
