"""Static analyzer for post-SPMD optimized HLO text.

Why: ``compiled.cost_analysis()`` on the CPU backend does NOT multiply
``while``-loop bodies by their trip count, so a scan-over-layers model
reports one layer's FLOPs.  This analyzer parses the optimized HLO module,
walks the call graph (entry -> fusions/whiles/calls) with trip-count
multipliers recovered from loop conditions, and accumulates:

  * flops             — 2*M*N*K for dots (+ conv), 1/elem for arithmetic
  * bytes             — operands+result of top-level (post-fusion) ops,
                        fusion interiors excluded (VMEM-resident)
  * collective bytes  — per collective kind, operand sizes summed

Because the input is the post-partitioning module, every quantity is
PER-DEVICE; multiply by device count for cluster totals.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0,
    "s2": 1, "u2": 1,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_ARITH = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "power", "negate",
    "cosine", "sine", "logistic", "select", "compare", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "clamp", "remainder",
    "exponential-minus-one", "log-plus-one", "sign", "atan2", "erf",
}

_SKIP_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
}


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_bytes: int
    result_elems: int
    operands: List[str]
    called: List[str]
    attrs: str
    shape_str: str
    args_text: str = ""


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    #: bytes inside ``jax.named_scope("pallas_*")`` regions — intermediates
    #: (attention scores/probs, SSD chunk products) that the real Pallas
    #: kernel keeps in VMEM.  On TPU these never touch HBM; the kernelized
    #: memory roofline term is (bytes - kernel_bytes) / HBM_bw.
    kernel_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Totals", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.kernel_bytes += other.kernel_bytes * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + v * mult

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _parse_instr_line(line: str):
    """'%name = TYPE opcode(operands), attrs' -> (name, type, opcode, rest)."""
    s = _COMMENT_RE.sub("", line).strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%") and not s[:1].isalpha():
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[:eq].strip().lstrip("%")
    rhs = s[eq + 3 :].lstrip()
    # TYPE: tuple '(...)' or single token
    if rhs.startswith("("):
        depth = 0
        for i, c in enumerate(rhs):
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str = rhs[: i + 1]
        rest = rhs[i + 1 :].lstrip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        type_str = rhs[:sp]
        rest = rhs[sp + 1 :].lstrip()
    par = rest.find("(")
    if par < 0:
        return None
    opcode = rest[:par].strip()
    if not re.fullmatch(r"[\w\-]+", opcode or ""):
        return None
    return name, type_str, opcode, rest[par + 1 :]


def _shape_bytes(type_str: str) -> Tuple[int, int]:
    """(bytes, elements) of a possibly-tuple HLO type string."""
    total_b = 0
    total_e = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        elems = 1
        if dims:
            for d in dims.split(","):
                if d:
                    elems *= int(d)
        total_b += elems * _DTYPE_BYTES[dt]
        total_e += elems
    return total_b, total_e


def _split_operands(argstr: str) -> Tuple[List[str], str, str]:
    """Operand names from the call parens; remainder = attribute string."""
    depth = 1
    i = 0
    while i < len(argstr) and depth:
        c = argstr[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
        i += 1
    inner = argstr[: i - 1] if depth == 0 else argstr
    attrs = argstr[i:] if depth == 0 else ""
    ops = re.findall(r"%([\w\.\-]+)", inner)
    return ops, attrs, inner


class HloModule:
    def __init__(self, text: str):
        self.comps: Dict[str, List[Instr]] = {}
        self._parse(text)
        self._memo: Dict[str, Totals] = {}

    # ---- parsing ------------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for line in text.splitlines():
            # computation headers start at column 0 and end with '{'
            if (
                line
                and not line[0].isspace()
                and line.rstrip().endswith("{")
                and "->" in line
            ):
                hm = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", line)
                if hm:
                    cur = hm.group(1)
                    self.comps[cur] = []
                    continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            parsed = _parse_instr_line(line)
            if parsed is None:
                continue
            name, type_str, opcode, rest = parsed
            rb, re_ = _shape_bytes(type_str)
            operands, attrs, inner = _split_operands(rest)
            called = re.findall(
                r"(?:calls|body|condition|to_apply)=\{?%?([\w\.\-]+)", attrs
            )
            if "branch_computations" in attrs:
                called += re.findall(
                    r"%([\w\.\-]+)",
                    attrs.split("branch_computations=")[1].split("}")[0],
                )
            self.comps[cur].append(
                Instr(name, opcode, rb, re_, operands, called, attrs, type_str, inner)
            )

    # ---- trip counts ----------------------------------------------------------
    def _trip_count_from_config(self, ins: Instr) -> Optional[int]:
        m = re.search(r'known_trip_count[^0-9]*"n"[^0-9]*(\d+)', ins.attrs)
        return int(m.group(1)) if m else None

    def _trip_count(self, cond_comp: str) -> int:
        """Fallback: constant operand of the loop-condition compare."""
        instrs = self.comps.get(cond_comp, [])
        consts: Dict[str, int] = {}
        for ins in instrs:
            if ins.opcode == "constant":
                cm = re.search(r"^\s*(-?\d+)\s*$", ins.args_text.strip())
                if cm:
                    consts[ins.name] = int(cm.group(1))
        trip = 1
        for ins in instrs:
            if ins.opcode in ("compare", "fusion"):
                for op in ins.operands:
                    if op in consts and consts[op] > 0:
                        trip = max(trip, consts[op])
        return trip

    # ---- cost walk -------------------------------------------------------------
    def _instr_flops(self, ins: Instr, defs: Dict[str, Instr]) -> float:
        if ins.opcode == "dot":
            k = 1
            cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
            if cm and ins.operands:
                lhs = defs.get(ins.operands[0])
                if lhs is not None:
                    dims_m = _SHAPE_RE.findall(lhs.shape_str)
                    if dims_m:
                        lhs_dims = [int(d) for d in dims_m[0][1].split(",") if d]
                        for c in cm.group(1).split(","):
                            if c and int(c) < len(lhs_dims):
                                k *= lhs_dims[int(c)]
            return 2.0 * ins.result_elems * k
        if ins.opcode == "convolution":
            # depthwise k-tap convs only in this codebase
            return 2.0 * ins.result_elems * 4
        if ins.opcode in _ARITH:
            return float(ins.result_elems)
        if ins.opcode == "reduce":
            return float(ins.result_elems)
        return 0.0

    _PASSTHROUGH = ("copy", "bitcast", "transpose", "convert", "reshape")

    def _root_def(self, name: str, defs: Dict[str, Instr]) -> Optional[Instr]:
        """Follow single-input pass-through ops back to the real producer."""
        seen = 0
        d = defs.get(name)
        while d is not None and d.opcode in self._PASSTHROUGH and d.operands and seen < 8:
            d = defs.get(d.operands[0])
            seen += 1
        return d

    def _fusion_bytes(self, ins: Instr, defs: Dict[str, Instr]) -> float:
        """Fusion boundary traffic with loop-carry awareness.

        A scan body's cache/state update fuses a dynamic-update-slice over a
        loop-carried buffer: XLA aliases the buffer in place, so the step
        touches only the written region — charging the full stacked KV cache
        per layer would inflate decode traffic ~1000x.  Similarly a fusion
        that slice-READS a big carried buffer touches at most result-size
        bytes of it."""
        res = ins.result_bytes
        infos = []
        for o in ins.operands:
            d = defs.get(o)
            if d is None:
                continue
            root = self._root_def(o, defs)
            carried = root is not None and root.opcode in (
                "parameter", "get-tuple-element",
            )
            infos.append((d, carried))
        aliased = False
        for d, carried in infos:
            if carried and d.shape_str == ins.shape_str:
                aliased = True  # in-place update of the carried buffer
                break
        upd = max((d.result_bytes for d, c in infos if not c), default=res)
        clamp = upd if aliased else res  # DUS fusions touch ~update-size
        ob = 0.0
        skipped_alias = False
        for d, carried in infos:
            b = d.result_bytes
            if carried and d.shape_str == ins.shape_str and not skipped_alias:
                skipped_alias = True
                continue
            if carried and clamp and b > clamp:
                b = clamp  # slice-read of a larger carried buffer
            ob += b
        # aliased in-place update writes ~the update region (~other operands)
        return ob + (ob if aliased else res)

    def _instr_bytes(self, ins: Instr, defs: Dict[str, Instr]) -> float:
        """HBM traffic model per op.  In-place updates (XLA aliases donated
        buffers) touch only the written region; gathers read only the rows
        they fetch — counting the full backing buffer per op would charge a
        32k-token KV cache per appended token."""
        op_bytes = [defs[o].result_bytes for o in ins.operands if o in defs]
        if ins.opcode == "copy" and ins.operands:
            root = self._root_def(ins.operands[0], defs)
            if root is not None and root.opcode in ("parameter", "get-tuple-element"):
                return 0.0  # alias copy of a donated/carried buffer
        if ins.opcode == "dynamic-update-slice":
            upd = op_bytes[1] if len(op_bytes) > 1 else 0
            return 2.0 * upd  # read update + write region (in-place)
        if ins.opcode == "scatter":
            upd = op_bytes[2] if len(op_bytes) > 2 else ins.result_bytes
            idx = op_bytes[1] if len(op_bytes) > 1 else 0
            return 2.0 * upd + idx
        if ins.opcode == "gather":
            idx = op_bytes[1] if len(op_bytes) > 1 else 0
            return 2.0 * ins.result_bytes + idx  # read rows + write result
        if ins.opcode == "dynamic-slice":
            return 2.0 * ins.result_bytes
        return ins.result_bytes + sum(op_bytes)

    def _comp_totals(self, comp: str) -> Totals:
        if comp in self._memo:
            return self._memo[comp]
        t = Totals()
        self._memo[comp] = t  # guards recursion
        instrs = self.comps.get(comp, [])
        defs = {i.name: i for i in instrs}

        # Scope-mark bookkeeping: compiler-synthesized ops (layout copies,
        # transposed dots) drop the named_scope metadata.  If the majority of
        # a computation's direct bytes carry the pallas_* mark, the stripped
        # siblings in the same loop body are kernel-interior too.
        direct: list = []  # (bytes, marked) per direct op
        sub_marked: list = []  # deferred subtree kernel-bytes adjustments

        for ins in instrs:
            marked = "pallas_" in ins.attrs  # inside a kernel named_scope
            if ins.opcode == "fusion":
                # boundary bytes; interior flops
                fb = self._fusion_bytes(ins, defs)
                t.bytes += fb
                direct.append((fb, marked))
                if marked:
                    t.kernel_bytes += fb
                for callee in ins.called:
                    t.add(self._comp_totals_flops_only(callee))
                continue
            if ins.opcode == "while":
                body_cond = ins.called
                trip = self._trip_count_from_config(ins)
                if trip is None:
                    trip = 1
                    for c in body_cond:
                        trip = max(trip, self._trip_count(c))
                for c in body_cond:
                    sub = self._comp_totals(c)
                    t.add(sub, mult=trip)
                    if marked:
                        # whole loop lives inside the kernel scope
                        t.kernel_bytes += (sub.bytes - sub.kernel_bytes) * trip
                continue
            if ins.opcode in ("call", "conditional", "custom-call", "map", "sort", "reduce", "scatter", "select-and-scatter", "reduce-window"):
                for callee in ins.called:
                    sub = self._comp_totals(callee)
                    t.add(sub)
                    if marked:
                        t.kernel_bytes += sub.bytes - sub.kernel_bytes
            # flops + bytes for this op
            t.flops += self._instr_flops(ins, defs)
            if ins.opcode not in _SKIP_BYTES:
                ob = self._instr_bytes(ins, defs)
                t.bytes += ob
                direct.append((ob, marked))
                if marked:
                    t.kernel_bytes += ob
            base = ins.opcode.replace("-start", "").replace("-done", "")
            if base in COLLECTIVES and not ins.opcode.endswith("-done"):
                ob = sum(defs[o].result_bytes for o in ins.operands if o in defs)
                if ob == 0:
                    ob = ins.result_bytes
                t.collective_bytes[base] = t.collective_bytes.get(base, 0.0) + ob

        tot_direct = sum(b for b, _ in direct)
        mk_direct = sum(b for b, m in direct if m)
        if tot_direct and mk_direct >= 0.5 * tot_direct:
            t.kernel_bytes += tot_direct - mk_direct  # claim stripped siblings
        return t

    def _comp_totals_flops_only(self, comp: str) -> Totals:
        full = self._comp_totals(comp)
        return Totals(flops=full.flops, bytes=0.0, collective_bytes=dict(full.collective_bytes))

    def entry_totals(self) -> Totals:
        # entry computation: the one never called by others, or named 'main'
        called = set()
        for comp, instrs in self.comps.items():
            for ins in instrs:
                called.update(ins.called)
        entries = [c for c in self.comps if c not in called]
        main = [c for c in entries if "main" in c] or entries
        t = Totals()
        for comp in main[:1] if main else []:
            t.add(self._comp_totals(comp))
        return t


def analyze(hlo_text: str) -> Totals:
    return HloModule(hlo_text).entry_totals()
