"""``jax.shard_map`` compatibility shim.

The distribution layer is written against the modern top-level
``jax.shard_map`` signature (``axis_names=...``, ``check_vma=...``).  Older
jax releases (< 0.5) only ship ``jax.experimental.shard_map.shard_map``,
whose equivalents are ``auto`` (the complement of the manual axis set) and
``check_rep``.  This module exposes one ``shard_map`` callable with the
modern keyword surface that dispatches to whichever implementation the
installed jax provides, so kernels and tests run unmodified on both.
"""
from __future__ import annotations

from typing import Optional, Set

import jax

__all__ = ["shard_map"]


if hasattr(jax, "shard_map"):

    def shard_map(
        f,
        *,
        mesh,
        in_specs,
        out_specs,
        axis_names: Optional[Set[str]] = None,
        check_vma: Optional[bool] = None,
    ):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
        )

else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(
        f,
        *,
        mesh,
        in_specs,
        out_specs,
        axis_names: Optional[Set[str]] = None,
        check_vma: Optional[bool] = None,
    ):
        # ``axis_names`` is intentionally ignored: the legacy ``auto=`` form
        # cannot lower ``axis_index`` under SPMD partitioning (PartitionId is
        # ambiguous there), so we run fully manual instead.  That is
        # semantically identical for this repo's callers: specs over the
        # non-manual axes are replicated and stage bodies use no cross-axis
        # collectives outside the declared axis set.
        del axis_names
        kw = {}
        if check_vma is not None:
            kw["check_rep"] = check_vma
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
        )
