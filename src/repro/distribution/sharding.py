"""Sharding rules: logical activation axes + path-based parameter specs.

The model code annotates activations with *logical* axis names via
``constrain`` (no-op outside a mesh context).  A ``ShardingContext`` binds a
mesh plus logical->mesh rules; parameter shardings are derived from the
parameter path with ``param_specs`` (MaxText-style rules, computed rather
than declared per layer).

Modes:
  * tp     : tensor parallel over 'model' only; params replicated over data
  * fsdp   : tp + params/optimizer fully sharded over ('pod','data') too
             (ZeRO-3 style; required to fit the 100B+ archs on v5e)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

#: logical activation axis -> mesh axes (None = replicated)
DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,  # flipped to 'model' when sequence parallelism is on
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
}


def _mesh_axes(mesh: Mesh, want) -> Optional[Any]:
    if want is None:
        return None
    if isinstance(want, str):
        return want if want in mesh.axis_names else None
    present = tuple(a for a in want if a in mesh.axis_names)
    return present if present else None


@contextlib.contextmanager
def use_mesh(
    mesh: Mesh,
    rules: Optional[Dict[str, Any]] = None,
    *,
    sequence_parallel: bool = False,
    fsdp: bool = True,
):
    r = dict(DEFAULT_RULES)
    if rules:
        r.update(rules)
    if sequence_parallel:
        r["seq"] = "model"
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = {"mesh": mesh, "rules": r, "fsdp": fsdp}
    try:
        with mesh:
            yield
    finally:
        _STATE.ctx = prev


def current() -> Optional[dict]:
    return getattr(_STATE, "ctx", None)


def constrain(x, logical_axes: Sequence[Optional[str]]):
    ctx = current()
    if ctx is None:
        return x
    if getattr(x, "ndim", None) != len(logical_axes):
        return x  # rank mismatch: caller's annotation doesn't apply here
    mesh, rules = ctx["mesh"], ctx["rules"]
    spec = []
    used: set = set()
    for i, ax in enumerate(logical_axes):
        m = _mesh_axes(mesh, rules.get(ax)) if ax else None
        # a mesh axis may appear once per spec; first logical axis wins
        if m is not None:
            flat = (m,) if isinstance(m, str) else tuple(m)
            flat = tuple(a for a in flat if a not in used)
            used.update(flat)
            m = None if not flat else (flat[0] if len(flat) == 1 else flat)
        # dimension must be divisible by the mesh axes' total size
        if m is not None:
            flat = (m,) if isinstance(m, str) else tuple(m)
            total = 1
            for a in flat:
                total *= mesh.shape[a]
            if x.shape[i] % total != 0:
                used.difference_update(flat)
                m = None
        spec.append(m)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


# ---------------------------------------------------------------------------
# parameter specs by path
# ---------------------------------------------------------------------------
_COL_SHARDED = ("wq", "wk", "wv", "w_gate", "w_up", "w_in", "wq_b", "wkv_b")
_ROW_SHARDED = ("wo", "w_out")
_REPLICATED = ("scale", "bias", "q_norm", "kv_norm", "a_log", "dt_bias", "router")


def _spec_for(path: Tuple[str, ...], shape: Tuple[int, ...], mesh: Mesh, fsdp: bool):
    """Map one parameter to a PartitionSpec by its path leaf + shape.

    NOTE: scan-stacked layer params carry a leading L dim, so the tensor-
    parallel rules address the TRAILING dims (row = -2, col = -1) and the
    expert rule finds the expert-count dim among the leading dims.
    """
    leaf = path[-1]
    nd = len(shape)
    parts: list = [None] * nd
    model_ok = "model" in mesh.axis_names
    msize = mesh.shape.get("model", 1)

    def fits(dim: int) -> bool:
        return shape[dim] % msize == 0 and shape[dim] >= msize

    is_expert = any("expert" in p for p in path)
    if is_expert and nd >= 3:
        # (..., E, d_in, d_out): expert-parallel on the expert dim.
        if model_ok:
            for i in range(nd - 2):
                if fits(i):
                    parts[i] = "model"
                    break
    elif leaf == "embedding" or leaf == "patch_proj" or "embed" in leaf:
        if model_ok and fits(0):
            parts[0] = "model"  # vocab-sharded embedding
    elif any(leaf.startswith(k) or leaf == k for k in _ROW_SHARDED):
        if model_ok and nd >= 2 and fits(nd - 2):
            parts[nd - 2] = "model"
    elif any(leaf.startswith(k) or leaf == k for k in _COL_SHARDED):
        if model_ok and nd >= 2 and fits(nd - 1):
            parts[nd - 1] = "model"
    elif any(k in leaf for k in _REPLICATED) or nd <= 1:
        pass
    elif nd >= 2:
        if model_ok and fits(nd - 1):
            parts[nd - 1] = "model"

    if fsdp:
        # ZeRO-3: additionally shard the largest remaining free dim over the
        # data axes so params+grads+optimizer state divide across all chips.
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        if data_axes:
            dsize = int(np.prod([mesh.shape[a] for a in data_axes]))
            free = [
                i
                for i in range(len(shape))
                if parts[i] is None and shape[i] % dsize == 0 and shape[i] >= dsize
            ]
            if free:
                j = max(free, key=lambda i: shape[i])
                parts[j] = data_axes if len(data_axes) > 1 else data_axes[0]
    return P(*parts)


def param_specs(params: Any, mesh: Mesh, fsdp: Optional[bool] = None) -> Any:
    """Pytree of PartitionSpec matching ``params`` (arrays or ShapeDtypeStructs)."""
    if fsdp is None:
        ctx = current()
        fsdp = ctx["fsdp"] if ctx else True

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, path + (str(k),)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [walk(v, path + (str(i),)) for i, v in enumerate(node)]
            return type(node)(t)
        shape = tuple(node.shape)
        return _spec_for(path, shape, mesh, fsdp)

    return walk(params, ())


def named(tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree,
        is_leaf=lambda s: isinstance(s, P),
    )


# ---------------------------------------------------------------------------
# optimizer-state specs: moments inherit the parameter sharding
# ---------------------------------------------------------------------------
def opt_state_specs(params: Any, opt_state: Any, mesh: Mesh, fsdp: bool = True) -> Any:
    pspecs = param_specs(params, mesh, fsdp)

    def is_q8(n):
        return isinstance(n, dict) and set(n) == {"q", "scale"}

    def moment(spec, node):
        if is_q8(node):
            row = spec[0] if len(spec) else None
            scale_rows = node["scale"].shape[0] if node["scale"].ndim else 1
            q_rows = node["q"].shape[0] if node["q"].ndim else 1
            if scale_rows > 1 and scale_rows == q_rows and row is not None:
                return {"q": spec, "scale": P(row)}
            return {"q": spec, "scale": P()}
        return spec

    def map_moments(tree):
        return jax.tree.map(moment, pspecs, tree, is_leaf=lambda n: is_q8(n))

    return {
        "m": map_moments(opt_state["m"]),
        "v": map_moments(opt_state["v"]),
        "step": P(),
    }


# ---------------------------------------------------------------------------
# cache/batch specs (serving): divisibility-driven heuristic
# ---------------------------------------------------------------------------
def batch_specs(batch: Any, mesh: Mesh) -> Any:
    """Shard dim0 (batch) over the data-like axes when divisible."""
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsize = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1

    def spec(x):
        parts = [None] * len(x.shape)
        if daxes and x.shape and x.shape[0] % dsize == 0 and x.shape[0] >= dsize:
            parts[0] = daxes if len(daxes) > 1 else daxes[0]
        return P(*parts)

    return jax.tree.map(spec, batch)


def cache_specs(cache: Any, mesh: Mesh, batch_size: int) -> Any:
    """KV caches / recurrent states: batch dim over data axes when divisible,
    else the longest divisible dim (sequence — flash-decoding style split);
    'model' on the largest remaining divisible dim (heads / latent)."""
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsize = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    msize = mesh.shape.get("model", 1)
    dval = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)

    def spec(x):
        shape = tuple(x.shape)
        parts: list = [None] * len(shape)
        if not shape:
            return P()
        used = set()
        # data axes: prefer the dim that equals batch_size (skip dim 0 which
        # is usually the stacked-layer dim for rank>=3 leaves)
        if daxes and dsize > 1:
            cand = [
                i
                for i in range(len(shape))
                if shape[i] % dsize == 0 and shape[i] >= dsize
            ]
            pref = [i for i in cand if shape[i] == batch_size and i != 0]
            pick = (pref or sorted(cand, key=lambda i: -shape[i]) or [None])[0]
            if pick is not None:
                parts[pick] = dval
                used.add(pick)
        if msize > 1 and "model" in mesh.axis_names:
            cand = [
                i
                for i in range(1, len(shape))
                if i not in used and shape[i] % msize == 0 and shape[i] >= msize
            ]
            if cand:
                pick = sorted(cand, key=lambda i: -shape[i])[0]
                parts[pick] = "model"
        return P(*parts)

    return jax.tree.map(spec, cache)
