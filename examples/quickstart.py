"""Quickstart: the paper's contribution in one page.

Builds an 8-GPU A100 cluster in the Sec-5.1 simulator, then places the same
random workload set with all four approaches (first-fit, load-balanced,
rule-based heuristic, WPM MIP) and prints the Table-3 metrics side by side.

    PYTHONPATH=src python examples/quickstart.py [--verbose]

Output goes through the std `logging` module (stderr); `--verbose` adds
debug-level detail.
"""
import argparse
import logging
import sys

from repro.core import baselines, heuristic, metrics
from repro.core.simulator import generate_test_case
from repro.core.wpm_mip import solve_wpm


log = logging.getLogger("repro.examples.quickstart")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--verbose", "-v", action="store_true")
    args = ap.parse_args()
    logging.basicConfig(
        stream=sys.stderr,
        level=logging.DEBUG if args.verbose else logging.INFO,
        format="%(message)s",
    )

    tc = generate_test_case(seed=7, n_gpus=8)
    n_new = len(tc.new_workloads)
    n_old = len(tc.initial.workloads)
    log.info(f"cluster: 8 x A100-80GB | existing workloads: {n_old} | new: {n_new}\n")

    rows = []
    for name in ("first_fit", "load_balanced", "rule_based", "mip", "joint_mip"):
        st = tc.initial.clone()
        if name == "first_fit":
            baselines.first_fit(st, tc.new_workloads)
        elif name == "load_balanced":
            baselines.load_balanced(st, tc.new_workloads)
        elif name == "rule_based":
            heuristic.initial_deployment(st, tc.new_workloads)
        else:
            res = solve_wpm(
                st, tc.new_workloads,
                movable=(name == "joint_mip"),
                allow_reconfig=(name == "joint_mip"),
                time_limit=10.0,
            )
            st = res.state
        st.validate()
        m = metrics.evaluate(
            st, tc.initial, list(tc.initial.workloads.values()) + tc.new_workloads
        )
        rows.append((name, m))

    hdr = (f"{'approach':14} {'#GPUs':>5} {'pend':>5} {'cWaste':>6} {'mWaste':>6} "
           f"{'avail':>6} {'cUtil':>6} {'mUtil':>6} {'seqMig':>6}")
    log.info(hdr)
    log.info("-" * len(hdr))
    for name, m in rows:
        log.info(f"{name:14} {m.n_gpus:5d} {m.n_pending:5d} {m.compute_wastage:6d} "
              f"{m.memory_wastage:6d} {m.availability:6d} "
              f"{m.compute_utilization:6.2f} {m.memory_utilization:6.2f} "
              f"{m.sequential_migrations:6d}")


if __name__ == "__main__":
    main()
