"""Train a ~100M-class model end to end with checkpoint/restart.

Thin wrapper over the production launcher (repro.launch.train) so the
example exercises the same code path a real job uses: deterministic data,
grad accumulation, auto-resume, atomic checkpoints.

CPU demo (reduced config, seconds):
    PYTHONPATH=src python examples/train_small.py

Full smollm-135m (the assigned ~100M arch; takes hours on CPU, minutes on
a TPU slice):
    PYTHONPATH=src python examples/train_small.py --full --steps 300
"""
import sys

from repro.launch.train import main as train_main


def main() -> int:
    argv = sys.argv[1:]
    full = "--full" in argv
    argv = [a for a in argv if a != "--full"]
    base = ["--arch", "smollm-135m", "--ckpt-dir", "/tmp/repro-train-small",
            "--ckpt-every", "25"]
    if full:
        base += ["--steps", "300", "--batch", "16", "--seq", "512",
                 "--microbatch", "4"]
    else:
        base += ["--reduced", "--steps", "60", "--batch", "8", "--seq", "128",
                 "--microbatch", "4"]
    sys.argv = ["train_small"] + base + argv
    return train_main()


if __name__ == "__main__":
    raise SystemExit(main())
