"""End-to-end serving driver: demand-driven autoscaling of LIVE model
replicas, with real forward passes and batched requests.

The demo closes the full loop of the traffic/autoscaling subsystem on real
engines instead of hand-scripting deploy/scale-down:

  1. deploy one seed replica per model and attach continuous-batching
     Engines (``engine_factory`` auto-attaches engines to scale-ups);
  2. replay a seeded bursty request trace (``core/traffic``) tick by tick:
     submit the tick's requests, pump all engines to completion, and
     measure each request's wall-clock latency;
  3. after every tick, ``ClusterServer.autoscale()`` turns the observed
     offered load + measured SLO attainment into replica targets applied
     through the placement engine (scale-ups get live engines, scale-downs
     drain before teardown);
  4. compaction afterwards, then verify the survivors still serve.

    PYTHONPATH=src python examples/serve_cluster.py [--verbose]

Output goes through the std `logging` module (stderr); `--verbose` adds
per-tick autoscale detail.
"""
import argparse
import logging
import sys
import time

import jax

from repro.configs import get_config, reduced
from repro.core.autoscaler import Autoscaler, AutoscalerConfig
from repro.core.perfmodel import DeviceThroughput, PerfModel
from repro.core.traffic import ConstantRate, FlashCrowd, ModelTraffic, generate_requests
from repro.models import bundle
from repro.serving import Engine, EngineConfig, Request
from repro.serving.cluster import ClusterServer

MODELS = {
    "chat": "smollm-135m",
    "draft": "xlstm-125m",
}
log = logging.getLogger("repro.examples.serve")

TICK = 5.0  # simulated seconds per control tick
HORIZON = 30.0
#: wall-clock latency budget a request must meet to count as attained
#: (generous: CPU forward passes; the burst is what should dent it).
SLO_WALL_SECONDS = 20.0


def make_engine(arch: str, seed: int) -> Engine:
    cfg = reduced(get_config(arch), capacity_factor=8.0)
    mb = bundle(cfg)
    params = mb.init(jax.random.key(seed))
    return Engine(mb, params, EngineConfig(max_slots=3, max_len=96))


def bursty_trace():
    """chat gets a 6x flash crowd mid-trace; draft stays steady."""
    return generate_requests(
        [
            ModelTraffic("chat", FlashCrowd(0.4, flash_at=10.0,
                                            flash_duration=10.0, multiplier=6.0),
                         mean_prompt_len=8, mean_decode_len=5, len_sigma=0.3),
            ModelTraffic("draft", ConstantRate(0.4),
                         mean_prompt_len=6, mean_decode_len=4, len_sigma=0.3),
        ],
        seed=0,
        horizon=HORIZON,
    )


def pump_measuring(srv: ClusterServer, submitted_wall: dict, latencies: dict,
                   max_steps: int = 10_000) -> int:
    """Drive all engines until drained, timestamping completions."""
    seen = {wid: len(e.completed) for wid, e in srv.engines.items()}
    total = 0
    for _ in range(max_steps):
        live = [(w, e) for w, e in srv.engines.items() if e.has_work]
        if not live:
            break
        for wid, eng in live:
            total += eng.step()
            for c in eng.completed[seen.get(wid, 0):]:
                if c.rid in submitted_wall:
                    latencies[c.rid] = time.time() - submitted_wall[c.rid]
            seen[wid] = len(eng.completed)
    return total


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--verbose", "-v", action="store_true")
    args = ap.parse_args()
    logging.basicConfig(
        stream=sys.stderr,
        level=logging.DEBUG if args.verbose else logging.INFO,
        format="%(message)s",
    )

    srv = ClusterServer(
        n_nodes=4,
        policy="heuristic",
        autoscaler=Autoscaler(AutoscalerConfig(
            mode="slo", up_cooldown=0.0, down_cooldown=10.0, min_replicas=1,
            max_replicas=3,
        )),
        # calibrate the perf model DOWN to these tiny CPU engines so the
        # controller's queueing math matches what the replicas really do.
        perf=PerfModel(calibration={
            "TPUv5e-16x16-pod": DeviceThroughput(2_000.0, 50.0),
        }),
        engine_factory=lambda model, arch, wid: make_engine(
            arch, seed=hash(wid) % 2**31
        ),
        autoscale_window=TICK,
    )

    # 1. seed deployment: ONE replica per model; the controller grows it.
    for model, arch in MODELS.items():
        rep = srv.deploy(model, arch, n_replicas=1, profile_id=4)
        log.info(f"deploy {model}: placed={rep.placed} nodes={rep.metrics.n_gpus}")
        for wid in rep.placed:
            srv.attach_engine(wid, make_engine(arch, seed=hash(wid) % 2**31))

    # 2-3. replay the bursty trace tick by tick under autoscale control.
    trace = bursty_trace()
    log.info(f"trace: {trace.n_requests} requests over {HORIZON:.0f}s "
          f"(chat flash crowd at t=10..20)")
    submitted_wall, latencies = {}, {}
    served = 0
    it = iter(trace.requests)
    pending = next(it, None)
    t = 0.0
    while t < HORIZON:
        tick_rids = []
        while pending is not None and pending.time < t + TICK:
            req = Request(rid=pending.rid,
                          prompt=list(range(2, 2 + pending.prompt_len)),
                          max_new_tokens=pending.decode_len)
            submitted_wall[req.rid] = time.time()
            tick_rids.append(req.rid)
            srv.submit(pending.model, req, now=pending.time)
            pending = next(it, None)
        served += pump_measuring(srv, submitted_wall, latencies)
        attain = {}
        for m in MODELS:
            rids = [r for r in tick_rids if r.startswith(m)]
            # a quiet tick is a healthy tick, not a 0% one
            attain[m] = (
                sum(latencies.get(r, 1e9) <= SLO_WALL_SECONDS for r in rids)
                / len(rids)
            ) if rids else 1.0
        rep = srv.autoscale(now=t + TICK, attainment=attain)
        targets = {d.model: f"{d.current}->{d.target}" for d in rep.decisions}
        log.debug(f"  t={t + TICK:4.0f}s offered={{"
              + ", ".join(f"{m}: {r:.2f}rps" for m, r in rep.offered_rps.items())
              + f"}} replicas={targets} slo_attain={attain} "
              f"nodes={srv.utilization()['nodes_used']}")
        t += TICK

    hit = sum(v <= SLO_WALL_SECONDS for v in latencies.values())
    log.info(f"served {served} tokens, {len(latencies)} requests; "
          f"overall SLO attainment {hit / max(len(latencies), 1):.2f}")

    # 4. compaction, then serve again to prove the survivors are live.
    cr = srv.compact()
    log.info(f"compaction: {cr.before.n_gpus} -> {cr.after.n_gpus} nodes "
          f"({cr.plan.n_moves} moves, committed={cr.committed})")
    srv.submit("chat", Request(rid="post-compact", prompt=[5, 4, 3],
                               max_new_tokens=4))
    srv.pump()
    assert any(c.rid == "post-compact"
               for e in srv.engines.values() for c in e.completed)
    srv.state.validate()
    log.info("post-compaction serving OK")


if __name__ == "__main__":
    main()
