"""End-to-end serving driver: the paper's placement engine scheduling LIVE
model replicas, with real forward passes and batched requests.

Flow:
  1. deploy three models onto a pod cluster (initial deployment use case);
  2. attach a continuous-batching Engine to every placed replica;
  3. stream batched requests through the round-robin router and pump all
     engines to completion;
  4. scale down, run compaction, verify the survivors still serve.

    PYTHONPATH=src python examples/serve_cluster.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import bundle
from repro.serving import Engine, EngineConfig, Request
from repro.serving.cluster import ClusterServer

MODELS = {
    "chat": "smollm-135m",
    "draft": "xlstm-125m",
}


def make_engine(arch: str, seed: int) -> Engine:
    cfg = reduced(get_config(arch), capacity_factor=8.0)
    mb = bundle(cfg)
    params = mb.init(jax.random.key(seed))
    return Engine(mb, params, EngineConfig(max_slots=3, max_len=96))


def main() -> None:
    srv = ClusterServer(n_nodes=4, policy="heuristic")

    # 1. initial deployment
    for model, arch in MODELS.items():
        rep = srv.deploy(model, arch, n_replicas=2, profile_id=4)
        print(f"deploy {model}: placed={rep.placed} nodes={rep.metrics.n_gpus}")

    # 2. attach live engines
    for model, arch in MODELS.items():
        for wid in srv.replicas_of(model):
            srv.attach_engine(wid, make_engine(arch, seed=hash(wid) % 2**31))

    # 3. stream requests
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(12):
        model = list(MODELS)[i % len(MODELS)]
        prompt = list(map(int, rng.integers(1, 255, size=int(rng.integers(3, 12)))))
        wid = srv.submit(model, Request(rid=f"{model}-{i}", prompt=prompt,
                                        max_new_tokens=6))
        print(f"  routed {model}-{i} -> {wid}")
    tokens = srv.pump()
    done = [c for e in srv.engines.values() for c in e.completed]
    print(f"served {len(done)} requests, {tokens} tokens "
          f"in {time.time() - t0:.1f}s")

    # 4. scale down + compaction, then serve again
    srv.retire("draft", 1)
    rep = srv.compact()
    print(f"compaction: {rep.before.n_gpus} -> {rep.after.n_gpus} nodes "
          f"({rep.plan.n_moves} moves)")
    srv.submit("chat", Request(rid="post-compact", prompt=[5, 4, 3],
                               max_new_tokens=4))
    srv.pump()
    assert any(c.rid == "post-compact"
               for e in srv.engines.values() for c in e.completed)
    srv.state.validate()
    print("post-compaction serving OK")


if __name__ == "__main__":
    main()
