"""Paper Figures 4 & 5 walked through: a fragmented 3-GPU node is compacted
(one GPU vacated), then reconfigured (wastage eliminated as well), with the
migration plan printed for each step.

    PYTHONPATH=src python examples/compaction_demo.py
"""
from repro.core import heuristic, metrics
from repro.core.migration import plan_migration
from repro.core.state import ClusterState, Workload


def draw(state: ClusterState) -> None:
    for gid in state.ordered_gids():
        gpu = state.gpus[gid]
        occ = gpu.memory_occupancy()
        cells = "".join(f"[{(w or '--'):>4}]" for w in occ)
        waste = gpu.compute_waste() + gpu.memory_waste()
        print(f"  {gid}: {cells}  waste={waste}")


def report(tag: str, state: ClusterState, initial=None) -> None:
    m = metrics.evaluate(state, initial)
    print(f"{tag}: GPUs={m.n_gpus} computeWaste={m.compute_wastage} "
          f"memWaste={m.memory_wastage} cUtil={m.compute_utilization:.0%} "
          f"mUtil={m.memory_utilization:.0%}")
    draw(state)


def build_fig4_state() -> ClusterState:
    """Fragmented initial state in the spirit of paper Fig. 4: three GPUs,
    13/21 compute and 15/24 memory slices used, two compute-wasting
    placements (3g.40gb at index 0)."""
    st = ClusterState.homogeneous(3)
    wl = [
        ("w1", 5, "gpu0", 0),   # 4g.40gb @ 0
        ("w2", 9, "gpu1", 0),   # 3g.40gb @ 0  <- wastes a compute slice
        ("w3", 14, "gpu1", 4),  # 2g.20gb @ 4
        ("w4", 19, "gpu1", 6),  # 1g.10gb @ 6  <- strands m7
        ("w5", 19, "gpu2", 0),  # 1g.10gb
        ("w6", 19, "gpu2", 1),  # 1g.10gb
        ("w7", 15, "gpu2", 4),  # 1g.20gb @ 4  <- wastes a compute slice
    ]
    for wid, pid, gid, idx in wl:
        st.add_workload(Workload(wid=wid, profile_id=pid))
        st.place(wid, gid, idx)
    return st


def main() -> None:
    initial = build_fig4_state()
    report("initial   ", initial)

    # --- compaction (Fig. 4): vacate underutilized GPUs, one-shot moves only
    compacted = initial.clone()
    heuristic.compaction(compacted)
    plan = plan_migration(initial, compacted)
    print(f"\ncompaction plan: {plan.n_moves} moves, "
          f"{plan.n_sequential} sequential, waves={[len(w) for w in plan.waves]}")
    report("compacted ", compacted, initial)

    # --- reconfiguration (Fig. 5): re-place everything, kill the wastage too
    reconfigured = initial.clone()
    heuristic.reconfiguration(reconfigured)
    plan = plan_migration(initial, reconfigured)
    print(f"\nreconfiguration plan: {plan.n_moves} moves, "
          f"{plan.n_sequential} sequential")
    report("reconfig  ", reconfigured, initial)

    mc = metrics.evaluate(compacted, initial)
    mr = metrics.evaluate(reconfigured, initial)
    assert mc.n_gpus <= 2, "compaction should vacate a GPU"
    assert mr.compute_wastage <= mc.compute_wastage
    print("\nOK: compaction saved a GPU; reconfiguration also removed wastage")


if __name__ == "__main__":
    main()
