"""Paper Figures 4 & 5 walked through on the migration control plane: a
fragmented 3-GPU node is compacted (one GPU vacated), then reconfigured
(wastage eliminated as well) — each verb returning a *scored* MigrationPlan
(bytes to transfer, downtime, migration-window makespans) and a commit
decision, instead of mutating blindly.

    PYTHONPATH=src python examples/compaction_demo.py [--verbose]

Output goes through the std `logging` module (stderr); `--verbose` adds
debug-level detail (per-GPU occupancy maps).
"""
import argparse
import logging
import sys

from repro.core import metrics
from repro.core.engine import CommitPolicy, PlacementEngine
from repro.core.state import ClusterState, Workload

log = logging.getLogger("repro.examples.compaction")


def draw(state: ClusterState) -> None:
    for gid in state.ordered_gids():
        gpu = state.gpus[gid]
        occ = gpu.memory_occupancy()
        cells = "".join(f"[{(w or '--'):>4}]" for w in occ)
        waste = gpu.compute_waste() + gpu.memory_waste()
        log.debug(f"  {gid}: {cells}  waste={waste}")


def report(tag: str, state: ClusterState, initial=None) -> None:
    m = metrics.evaluate(state, initial)
    log.info(f"{tag}: GPUs={m.n_gpus} computeWaste={m.compute_wastage} "
          f"memWaste={m.memory_wastage} cUtil={m.compute_utilization:.0%} "
          f"mUtil={m.memory_utilization:.0%}")
    draw(state)


def describe_plan(tag: str, res) -> None:
    plan, cost = res.plan, res.cost
    log.info(f"\n{tag} plan: {plan.n_moves} moves ({plan.n_sequential} sequential, "
          f"{len(plan.disruptive)} disruptive), waves={[len(w) for w in plan.waves]}")
    log.info(f"  cost: {cost.total_bytes / 2**30:.0f} GiB to move, "
          f"downtime {cost.downtime_seconds:.1f}s, "
          f"window {cost.duration_seconds:.1f}s "
          f"(makespans {[round(s, 2) for s in cost.wave_makespans]})")
    log.info(f"  gains: {res.gains.gpus_saved} GPU(s) saved, "
          f"{res.gains.waste_saved} wastage slice(s) removed")
    log.info(f"  decision [{res.decision.reason}] -> "
          f"{'COMMIT' if res.committed else 'REJECT'}")


def build_fig4_state() -> ClusterState:
    """Fragmented initial state in the spirit of paper Fig. 4: three GPUs,
    13/21 compute and 15/24 memory slices used, two compute-wasting
    placements (3g.40gb at index 0)."""
    st = ClusterState.homogeneous(3)
    wl = [
        ("w1", 5, "gpu0", 0),   # 4g.40gb @ 0
        ("w2", 9, "gpu1", 0),   # 3g.40gb @ 0  <- wastes a compute slice
        ("w3", 14, "gpu1", 4),  # 2g.20gb @ 4
        ("w4", 19, "gpu1", 6),  # 1g.10gb @ 6  <- strands m7
        ("w5", 19, "gpu2", 0),  # 1g.10gb
        ("w6", 19, "gpu2", 1),  # 1g.10gb
        ("w7", 15, "gpu2", 4),  # 1g.20gb @ 4  <- wastes a compute slice
    ]
    for wid, pid, gid, idx in wl:
        st.add_workload(Workload(wid=wid, profile_id=pid))
        st.place(wid, gid, idx)
    return st


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--verbose", "-v", action="store_true")
    args = ap.parse_args()
    logging.basicConfig(
        stream=sys.stderr,
        level=logging.DEBUG if args.verbose else logging.INFO,
        format="%(message)s",
    )

    initial = build_fig4_state()
    report("initial   ", initial)
    engine = PlacementEngine("rule_based")

    # --- compaction (Fig. 4): vacate underutilized GPUs, one-shot moves only
    compacted = initial.clone()
    res_c = engine.compact(compacted)
    describe_plan("compaction", res_c)
    report("compacted ", compacted, initial)

    # --- reconfiguration (Fig. 5): re-place everything, kill the wastage too
    reconfigured = initial.clone()
    res_r = engine.reconfigure(reconfigured)
    describe_plan("reconfiguration", res_r)
    report("reconfig  ", reconfigured, initial)

    # --- the control plane at work: a net-positive engine rejects a repack
    # whose disruption outweighs its gains (state stays byte-identical).
    frugal = PlacementEngine(
        "rule_based",
        commit=CommitPolicy(mode="net-positive", gpu_seconds_value=0.5,
                            waste_seconds_value=0.1),
    )
    guarded = initial.clone()
    res_g = frugal.reconfigure(guarded)
    describe_plan("guarded reconfiguration", res_g)

    mc = metrics.evaluate(compacted, initial)
    mr = metrics.evaluate(reconfigured, initial)
    assert res_c.committed and res_r.committed
    assert mc.n_gpus <= 2, "compaction should vacate a GPU"
    assert mr.compute_wastage <= mc.compute_wastage
    assert not res_g.committed, "undervalued gains must be rejected"
    assert metrics.evaluate(guarded).n_gpus == metrics.evaluate(initial).n_gpus
    log.info("\nOK: compaction saved a GPU; reconfiguration also removed wastage; "
          "the net-positive policy rejected the undervalued repack")


if __name__ == "__main__":
    main()
