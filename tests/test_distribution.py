"""Distribution-layer tests: collective sizing cross-checks, EP MoE parity
on multi-device meshes (subprocess), elastic checkpoint re-shard."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.distribution import collectives as co

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_with_devices(n, code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# analytic collective model
# ---------------------------------------------------------------------------
def test_ring_identities():
    n, b = 16, 1e9
    assert co.ring_all_reduce(b, n) == co.all_gather(b, n) + co.reduce_scatter(b, n)
    assert co.ring_all_reduce(b, 1) == 0.0
    assert co.all_to_all(b, n) < co.all_gather(b, n)


def test_collective_model_matches_hlo_order_of_magnitude():
    """Analyzer's all-reduce total for mistral prefill ~ analytic TP model.

    CPU lowering upcasts bf16 collectives to f32 (documented 2x), and the
    analyzer counts operand bytes (not ring wire bytes) — assert within a
    factor of 4 to pin the structure, not the constant.
    """
    art = os.path.join(
        os.path.dirname(__file__), "..",
        "artifacts/dryrun/pod16x16/mistral-large-123b__prefill_32k.json",
    )
    if not os.path.exists(art):
        pytest.skip("dry-run artifact not present")
    cell = json.load(open(art))
    if cell.get("status") != "ok" or cell.get("sp"):
        pytest.skip("cell not comparable")
    got = cell["per_device"]["collective_bytes"].get("all-reduce", 0.0)
    # tokens_local = global_batch/dp * seq; bf16 activations
    act = (32 // 16) * 32768 * 12288 * 2
    model = co.CollectiveModel(
        n_layers=88, d_model=12288, d_ff=28672,
        params_bytes=2 * 123e9, tp=16, dp=16, act_bytes_per_layer=act,
    )
    want = model.tp_all_reduce_bytes() / 2  # analyzer counts operand, not 2x ring
    assert want / 4 <= got <= want * 4, (got, want)


# ---------------------------------------------------------------------------
# EP MoE parity on real multi-device meshes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mesh_shape,n_dev", [("(2, 4)", 8), ("(1, 8)", 8)])
def test_moe_ep_matches_dispatch_multidevice(mesh_shape, n_dev):
    out = _run_with_devices(n_dev, f"""
        import jax, numpy as np
        from repro.configs import get_config, reduced
        from repro.models import bundle, moe as moe_mod
        from repro.distribution import sharding as shd
        cfg = reduced(get_config('mixtral-8x7b'), capacity_factor=8.0)
        mb = bundle(cfg)
        params = mb.init(jax.random.key(0))
        batch = {{'tokens': jax.random.randint(jax.random.key(1), (4, 16), 1, 255)}}
        mesh = jax.make_mesh({mesh_shape}, ('data', 'model'))
        with shd.use_mesh(mesh, fsdp=True):
            moe_mod.set_moe_impl('dispatch')
            l1, _ = jax.jit(mb.loss_fn)(params, batch)
            moe_mod.set_moe_impl('alltoall')
            l2, _ = jax.jit(mb.loss_fn)(params, batch)
        np.testing.assert_allclose(float(l1), float(l2), rtol=2e-2)
        print('OK', float(l1), float(l2))
    """)
    assert "OK" in out


# ---------------------------------------------------------------------------
# fault tolerance: elastic re-shard (save on N devices, restore on M)
# ---------------------------------------------------------------------------
def test_checkpoint_elastic_reshard(tmp_path):
    ck = str(tmp_path / "ck")
    save_code = f"""
        import jax, numpy as np
        from repro.configs import get_config, reduced
        from repro.models import bundle
        from repro.distribution import sharding as shd
        from repro.training import optimizer as opt
        from repro.training.checkpoint import CheckpointManager
        cfg = reduced(get_config('smollm-135m'))
        mb = bundle(cfg)
        mesh = jax.make_mesh((4,), ('data',))
        with shd.use_mesh(mesh, fsdp=True):
            params = mb.init(jax.random.key(7))
            ocfg = opt.AdamWConfig()
            state = opt.init(params, ocfg)
            pn = shd.named(shd.param_specs(params, mesh, True), mesh)
            params = jax.tree.map(jax.device_put, params, pn)
            CheckpointManager('{ck}').save(3, params, state, blocking=True)
        print('saved', float(jax.tree.leaves(params)[0].sum()))
    """
    out1 = _run_with_devices(4, save_code)
    ref = float(out1.split("saved")[1].strip())

    restore_code = f"""
        import jax, numpy as np
        from repro.configs import get_config, reduced
        from repro.models import bundle
        from repro.distribution import sharding as shd
        from repro.training import optimizer as opt
        from repro.training.checkpoint import CheckpointManager
        cfg = reduced(get_config('smollm-135m'))
        mb = bundle(cfg)
        mesh = jax.make_mesh((3, 2), ('data', 'model'))  # DIFFERENT topology
        with shd.use_mesh(mesh, fsdp=True):
            tmpl_p = mb.param_shapes()
            ocfg = opt.AdamWConfig()
            tmpl_o = jax.eval_shape(lambda p: opt.init(p, ocfg), tmpl_p)
            pn = shd.named(shd.param_specs(tmpl_p, mesh, True), mesh)
            on = shd.named(shd.opt_state_specs(tmpl_p, tmpl_o, mesh, True), mesh)
            mgr = CheckpointManager('{ck}')
            assert mgr.latest_step() == 3
            params, state = mgr.restore(3, tmpl_p, tmpl_o, shardings=(pn, on))
        leaf = jax.tree.leaves(params)[0]
        assert len(leaf.sharding.device_set) >= 1
        print('restored', float(leaf.sum()))
    """
    out2 = _run_with_devices(6, restore_code)
    got = float(out2.split("restored")[1].strip())
    np.testing.assert_allclose(got, ref, rtol=1e-2)
