"""Migration control plane: plan_migration cycle-breaking, cost-model edge
cases, CommitPolicy decisions, and transactional plan rejection."""
import dataclasses

import pytest

from repro.core.engine import PlacementEngine
from repro.core.events import Event, OnlineSimulator, Trace
from repro.core.migration import (
    CommitPolicy,
    MigrationCostModel,
    MigrationPlan,
    Move,
    PlanGains,
    plan_migration,
)
from repro.core.state import ClusterState, Workload


def _state(placements, n_gpus=3):
    st = ClusterState.homogeneous(n_gpus)
    for wid, pid, gid, idx in placements:
        if wid not in st.workloads:
            st.add_workload(Workload(wid=wid, profile_id=pid))
        st.place(wid, gid, idx)
    return st


def _placements(state):
    return {
        (gid, p.wid, p.profile_id, p.index)
        for gid, g in state.gpus.items()
        for p in g.placements
    }


# ---------------------------------------------------------------------------
# plan_migration cycle-breaking
# ---------------------------------------------------------------------------
class TestCycleBreaking:
    def test_two_workload_swap_breaks_one_disruptively(self):
        """A<->B swap on one full GPU: no free destination span exists, so
        exactly one move is drained (the smaller footprint) and the other
        lands in a wave afterwards."""
        initial = _state([("a", 14, "gpu0", 0), ("b", 14, "gpu0", 4)], n_gpus=1)
        final = _state([("a", 14, "gpu0", 4), ("b", 14, "gpu0", 0)], n_gpus=1)
        plan = plan_migration(initial, final)
        assert plan.n_moves == 2
        assert len(plan.disruptive) == 1
        assert plan.disruptive[0].disruptive
        # victim choice is deterministic: smallest span, then wid order
        assert plan.disruptive[0].wid == "a"
        surviving = [mv for w in plan.waves for mv in w]
        assert [mv.wid for mv in surviving] == ["b"]
        assert not surviving[0].disruptive

    def test_cross_gpu_cycle(self):
        """Full GPUs exchanging workloads force a drain too."""
        initial = _state([("a", 0, "gpu0", 0), ("b", 0, "gpu1", 0)], n_gpus=2)
        final = _state([("a", 0, "gpu1", 0), ("b", 0, "gpu0", 0)], n_gpus=2)
        plan = plan_migration(initial, final)
        assert plan.n_moves == 2
        assert len(plan.disruptive) == 1
        assert plan.n_sequential >= 1

    def test_chain_into_free_space_is_not_disruptive(self):
        """A shift chain with a free landing spot resolves in waves only."""
        initial = _state([("a", 14, "gpu0", 0), ("b", 14, "gpu0", 2)], n_gpus=1)
        final = _state([("a", 14, "gpu0", 2), ("b", 14, "gpu0", 4)], n_gpus=1)
        plan = plan_migration(initial, final)
        assert plan.n_moves == 2
        assert not plan.disruptive
        # b must vacate before a lands: two waves, b first
        assert [[mv.wid for mv in w] for w in plan.waves] == [["b"], ["a"]]

    def test_unmoved_workloads_produce_empty_plan(self):
        st = _state([("a", 5, "gpu0", 0)])
        plan = plan_migration(st, st.clone())
        assert plan.n_moves == 0 and plan.n_sequential == 0
        assert plan.n_migrations == 0


# ---------------------------------------------------------------------------
# cost model edge cases
# ---------------------------------------------------------------------------
class TestCostModel:
    def _swap_plan_and_state(self):
        initial = _state([("a", 14, "gpu0", 0), ("b", 14, "gpu0", 4)], n_gpus=1)
        final = _state([("a", 14, "gpu0", 4), ("b", 14, "gpu0", 0)], n_gpus=1)
        return plan_migration(initial, final), final

    def test_zero_kv_workloads_cost_no_bytes(self):
        plan, final = self._swap_plan_and_state()
        cm = MigrationCostModel()
        cost = cm.price(plan, final, bytes_for=lambda wid: 0)
        assert cost.total_bytes == 0
        # downtime survives: drains and cutovers are not transfer-bound
        assert cost.downtime_seconds == pytest.approx(
            cm.cutover_seconds + cm.drain_seconds + cm.resume_seconds
        )
        assert cost.n_disruptive == 1

    def test_fresh_deployments_are_free(self):
        empty = ClusterState.homogeneous(1)
        final = _state([("a", 5, "gpu0", 0)], n_gpus=1)
        plan = plan_migration(empty, final)
        cost = MigrationCostModel().price(plan, final)
        assert plan.n_moves == 1 and plan.n_migrations == 0
        assert cost.total_bytes == 0 and cost.downtime_seconds == 0.0
        assert cost.duration_seconds == 0.0

    def test_single_wave_plan_duration_is_its_makespan(self):
        initial = _state([("a", 14, "gpu0", 0), ("b", 19, "gpu0", 2)], n_gpus=2)
        final = _state([("a", 14, "gpu1", 0), ("b", 19, "gpu1", 2)], n_gpus=2)
        plan = plan_migration(initial, final)
        assert len(plan.waves) == 1 and not plan.disruptive
        cm = MigrationCostModel()
        cost = cm.price(plan, final)
        slowest = max(
            cm.move_cost(mv, final).transfer_seconds for mv in plan.waves[0]
        )
        assert cost.duration_seconds == pytest.approx(slowest)
        assert cost.wave_makespans == (pytest.approx(slowest),)
        # parallel copies: the wave is NOT the sum of its transfers
        total = sum(
            cm.move_cost(mv, final).transfer_seconds for mv in plan.waves[0]
        )
        assert cost.duration_seconds < total

    def test_per_wave_makespan_monotonicity(self):
        """Adding a move to a wave can only extend its makespan, and doubling
        the bandwidth halves every transfer-bound duration."""
        st = _state(
            [("a", 14, "gpu0", 0), ("b", 9, "gpu0", 4), ("c", 19, "gpu1", 0)],
            n_gpus=2,
        )
        mv_small = Move("a", "gpu0", 0, "gpu1", 4, 14)
        mv_big = Move("b", "gpu0", 4, "gpu1", 0, 9)
        cm = MigrationCostModel()
        solo = cm.price(MigrationPlan(waves=[[mv_small]], disruptive=[]), st)
        both = cm.price(MigrationPlan(waves=[[mv_small, mv_big]], disruptive=[]), st)
        assert both.wave_makespans[0] >= solo.wave_makespans[0]
        assert both.wave_makespans[0] == pytest.approx(
            cm.move_cost(mv_big, st).transfer_seconds
        )
        fast = dataclasses.replace(cm, link_gbps=cm.link_gbps * 2)
        both_fast = fast.price(
            MigrationPlan(waves=[[mv_small, mv_big]], disruptive=[]), st
        )
        assert both_fast.duration_seconds == pytest.approx(
            both.duration_seconds / 2
        )

    def test_disruptive_moves_serialize_into_the_window(self):
        plan, final = self._swap_plan_and_state()
        cm = MigrationCostModel()
        cost = cm.price(plan, final)
        drain = next(
            cm.move_cost(mv, final).downtime_seconds for mv in plan.disruptive
        )
        wave = sum(cost.wave_makespans)
        assert cost.duration_seconds == pytest.approx(wave + drain)

    def test_bytes_per_memory_slice_override_beats_device_estimate(self):
        plan, final = self._swap_plan_and_state()
        default = MigrationCostModel().price(plan, final)
        tuned = MigrationCostModel(bytes_per_memory_slice=1 << 30).price(plan, final)
        # A100 memory slices are 10 GiB; the explicit 1 GiB override must win.
        assert default.total_bytes == 2 * 2 * (10 << 30)
        assert tuned.total_bytes == 2 * 2 * (1 << 30)

    def test_slo_disruption_scales_with_migration_cost_weight(self):
        plan, final = self._swap_plan_and_state()
        heavy = final.clone()
        for wid in list(heavy.workloads):
            heavy.workloads[wid] = dataclasses.replace(
                heavy.workloads[wid], migration_cost=3.0
            )
        cm = MigrationCostModel()
        assert cm.price(plan, heavy).slo_disruption == pytest.approx(
            3.0 * cm.price(plan, final).slo_disruption
        )


# ---------------------------------------------------------------------------
# commit policy decisions
# ---------------------------------------------------------------------------
class TestCommitPolicy:
    def _cost(self, plan_state):
        plan, final = plan_state
        return MigrationCostModel().price(plan, final)

    def test_noop_plans_always_commit(self):
        st = _state([("a", 5, "gpu0", 0)])
        cost = MigrationCostModel().price(plan_migration(st, st.clone()), st)
        for mode in ("always", "net-positive", "budgeted"):
            assert CommitPolicy(mode=mode).decide(PlanGains(), cost).commit

    def test_net_positive_rejects_zero_gain_reshuffles(self):
        initial = _state([("a", 14, "gpu0", 0), ("b", 14, "gpu0", 4)], n_gpus=1)
        final = _state([("a", 14, "gpu0", 4), ("b", 14, "gpu0", 0)], n_gpus=1)
        cost = MigrationCostModel().price(plan_migration(initial, final), final)
        dec = CommitPolicy(mode="net-positive").decide(PlanGains(0, 0), cost)
        assert not dec.commit and dec.price > 0

    def test_budgeted_move_and_downtime_budgets(self):
        initial = _state([("a", 14, "gpu0", 0), ("b", 14, "gpu0", 4)], n_gpus=1)
        final = _state([("a", 14, "gpu0", 4), ("b", 14, "gpu0", 0)], n_gpus=1)
        cost = MigrationCostModel().price(plan_migration(initial, final), final)
        gains = PlanGains(1, 0)
        assert not CommitPolicy(mode="budgeted", move_budget=1).decide(gains, cost).commit
        assert CommitPolicy(
            mode="budgeted", move_budget=5, downtime_budget_seconds=None
        ).decide(gains, cost).commit
        assert not CommitPolicy(
            mode="budgeted", move_budget=5, downtime_budget_seconds=0.1
        ).decide(gains, cost).commit

    def test_move_budget_is_a_hard_cap_in_every_mode(self):
        """The legacy migration_budget contract: a set move budget binds even
        when the mode is net-positive or always."""
        initial = _state([("a", 14, "gpu0", 0), ("b", 14, "gpu0", 4)], n_gpus=1)
        final = _state([("a", 14, "gpu0", 4), ("b", 14, "gpu0", 0)], n_gpus=1)
        cost = MigrationCostModel().price(plan_migration(initial, final), final)
        huge_gain = PlanGains(gpus_saved=100, waste_saved=100)
        for mode in ("always", "net-positive", "budgeted"):
            dec = CommitPolicy(mode=mode, move_budget=1).decide(huge_gain, cost)
            assert not dec.commit, mode

    def test_mode_normalization_and_validation(self):
        assert CommitPolicy(mode="net_positive").mode == "net-positive"
        with pytest.raises(ValueError, match="commit mode"):
            CommitPolicy(mode="sometimes")


# ---------------------------------------------------------------------------
# engine plan/score/commit integration
# ---------------------------------------------------------------------------
class TestEngineControlPlane:
    def _fragmented(self):
        return _state(
            [
                ("w1", 5, "gpu0", 0),
                ("w2", 9, "gpu1", 0),
                ("w3", 19, "gpu2", 0),
                ("w4", 19, "gpu2", 1),
            ],
            n_gpus=3,
        )

    @pytest.mark.parametrize("policy", ["first_fit", "rule_based", "frag_aware"])
    def test_compact_returns_scored_plan(self, policy):
        st = self._fragmented()
        res = PlacementEngine(policy).compact(st)
        assert res.committed and res.plan is not None and res.cost is not None
        assert res.plan.cost is res.cost
        assert res.gains is not None and res.decision is not None
        st.validate()

    def test_mip_compact_returns_scored_plan(self):
        pytest.importorskip("scipy")
        st = self._fragmented()
        res = PlacementEngine("mip", time_limit=5).compact(st)
        assert res.committed and res.plan is not None and res.cost is not None

    @pytest.mark.parametrize("policy", ["rule_based", "frag_aware", "first_fit"])
    def test_rejection_is_byte_identical_rollback(self, policy):
        st = self._fragmented()
        before = _placements(st)
        order_before = {g: [p.wid for p in st.gpus[g].placements] for g in st.gpus}
        reject_all = CommitPolicy(
            mode="net-positive", gpu_seconds_value=0.0, waste_seconds_value=0.0
        )
        res = PlacementEngine(policy, commit=reject_all).compact(st)
        assert not res.committed
        assert res.plan.n_moves > 0  # the policy DID find a compaction
        assert _placements(st) == before
        assert {
            g: [p.wid for p in st.gpus[g].placements] for g in st.gpus
        } == order_before
        assert res.pending == []
        st.validate()

    def test_reconfigure_rejection_rolls_back_adopted_layout(self):
        st = self._fragmented()
        before = _placements(st)
        reject_all = CommitPolicy(
            mode="net-positive", gpu_seconds_value=0.0, waste_seconds_value=0.0
        )
        res = PlacementEngine("rule_based", commit=reject_all).reconfigure(st)
        assert not res.committed
        assert _placements(st) == before
        st.validate()

    def test_deploy_plans_when_enabled(self):
        st = ClusterState.homogeneous(2)
        eng = PlacementEngine("rule_based", plan_deploys=True)
        res = eng.deploy(st, [Workload("n1", 14), Workload("n2", 19)])
        assert res.plan is not None and res.plan.n_moves == 2
        assert res.plan.n_migrations == 0  # all fresh, wave-0
        assert res.cost.total_bytes == 0
        eng2 = PlacementEngine("rule_based")
        res2 = eng2.deploy(st, [Workload("n3", 19)])
        assert res2.plan is None  # hot path stays plan-free by default


# ---------------------------------------------------------------------------
# online simulator integration
# ---------------------------------------------------------------------------
class TestOnlineControlPlane:
    def _trace(self):
        events = [
            Event(time=1.0, kind="arrival", workloads=(
                Workload("w0", 5), Workload("w1", 9),
                Workload("w2", 14), Workload("w3", 15),
            )),
            Event(time=5.0, kind="departure", wids=("w0", "w2")),
            Event(time=6.0, kind="compact"),
        ]
        return Trace(events=events, horizon=10.0)

    def test_committed_plan_accrues_cost_stats(self):
        st = ClusterState.homogeneous(3)
        sim = OnlineSimulator(st, PlacementEngine("rule_based"))
        stats = sim.run(self._trace())
        assert stats.n_compactions == 1
        assert stats.bytes_moved > 0
        assert stats.disruption_seconds > 0
        assert stats.migration_window_seconds > 0
        d = stats.as_dict()
        assert "n_plans_rejected" in d and "disruption_minutes" in d
        assert d["disruption_minutes"] == pytest.approx(
            stats.disruption_seconds / 60.0
        )

    def test_legacy_migration_budget_maps_to_budgeted_commit(self):
        st = ClusterState.homogeneous(3)
        eng = PlacementEngine("rule_based")
        sim = OnlineSimulator(st, eng, migration_budget=0)
        # the override is simulator-local: the shared engine keeps its policy
        assert eng.commit_policy.mode == "always"
        assert sim._commit_override.mode == "budgeted"
        assert sim._commit_override.move_budget == 0
        stats = sim.run(self._trace())
        assert eng.commit_policy.mode == "always"  # restored after every verb
        assert stats.n_compactions == 0
        assert stats.n_compactions_skipped == 1
        assert stats.n_plans_rejected == 1
        assert stats.bytes_moved == 0

    def test_periodic_reconfigure_injection(self):
        st = ClusterState.homogeneous(3)
        trace = Trace(
            events=[
                Event(time=1.0, kind="arrival", workloads=(Workload("a", 15),)),
                Event(time=2.0, kind="arrival", workloads=(Workload("b", 15),)),
            ],
            horizon=20.0,
        )
        sim = OnlineSimulator(
            st, PlacementEngine("rule_based"), reconfigure_every=6.0
        )
        stats = sim.run(trace)
        st.validate()
        assert stats.n_reconfigures + stats.n_plans_rejected + \
            stats.n_reconfigures_deferred == 3  # t=6,12,18
        assert stats.n_compactions_deferred == 0  # no compact triggers ran
