"""Traffic / perf-model / autoscaler subsystem tests (the demand loop)."""
import dataclasses

import pytest

from repro.core.autoscaler import SLO, Autoscaler, AutoscalerConfig, ModelLoad
from repro.core.engine import PlacementEngine
from repro.core.events import DemandSimulator, ModelServiceSpec
from repro.core.fleetgen import build_fleet
from repro.core.perfmodel import DEVICE_THROUGHPUT, DeviceThroughput, PerfModel
from repro.core.profiles import A100_80GB, H100_96GB
from repro.core.tpu_profiles import TPU_V5E_POD
from repro.core.traffic import (
    ConstantRate,
    DiurnalRate,
    FlashCrowd,
    ModelTraffic,
    generate_requests,
    replay_rows,
)


# ---------------------------------------------------------------------------
# traffic determinism
# ---------------------------------------------------------------------------
class TestTrafficDeterminism:
    def _specs(self):
        return [
            ModelTraffic("chat", DiurnalRate(4.0, period=100.0)),
            ModelTraffic("embed", FlashCrowd(2.0, 30.0, 20.0, 5.0),
                         mean_prompt_len=128, mean_decode_len=8),
            ModelTraffic("bot", ConstantRate(1.0)),
        ]

    def test_same_seed_byte_identical(self):
        a = generate_requests(self._specs(), seed=7, horizon=120.0)
        b = generate_requests(self._specs(), seed=7, horizon=120.0)
        assert repr(a.requests) == repr(b.requests)  # byte-identical
        assert a.n_requests > 0

    def test_different_seed_differs(self):
        a = generate_requests(self._specs(), seed=7, horizon=120.0)
        b = generate_requests(self._specs(), seed=8, horizon=120.0)
        assert repr(a.requests) != repr(b.requests)

    def test_appending_a_model_keeps_existing_streams(self):
        base = self._specs()
        a = generate_requests(base, seed=3, horizon=80.0)
        b = generate_requests(
            base + [ModelTraffic("new", ConstantRate(2.0))], seed=3, horizon=80.0
        )
        keep = {"chat", "embed", "bot"}
        assert [r for r in a.requests if r.model in keep] == [
            r for r in b.requests if r.model in keep
        ]

    def test_requests_inside_horizon_and_sorted(self):
        tr = generate_requests(self._specs(), seed=1, horizon=50.0)
        times = [r.time for r in tr.requests]
        assert times == sorted(times)
        assert all(0.0 <= t < 50.0 for t in times)
        assert all(r.prompt_len >= 1 and r.decode_len >= 1 for r in tr.requests)

    def test_flash_crowd_raises_rate_in_window(self):
        tr = generate_requests(
            [ModelTraffic("m", FlashCrowd(2.0, 100.0, 50.0, 6.0))],
            seed=0, horizon=300.0,
        )
        assert tr.offered_rps("m", 100.0, 150.0) > 2.5 * tr.offered_rps("m", 0.0, 100.0)

    def test_replay_rows_roundtrip(self):
        tr = replay_rows({"m": [(1.0, 10, 4), (2.5, 20, 8)]}, horizon=5.0)
        assert tr.n_requests == 2
        assert tr.requests[0].prompt_len == 10
        with pytest.raises(ValueError):
            replay_rows({"m": [(9.0, 1, 1)]}, horizon=5.0)


# ---------------------------------------------------------------------------
# perf model monotonicity
# ---------------------------------------------------------------------------
class TestPerfModel:
    @pytest.mark.parametrize("device", [A100_80GB, H100_96GB, TPU_V5E_POD])
    @pytest.mark.parametrize("efficiency", [1.0, 0.8])
    def test_bigger_slice_never_slower(self, device, efficiency):
        pm = PerfModel(parallel_efficiency=efficiency)
        for a in device.profiles:
            for b in device.profiles:
                if (a.compute_slices >= b.compute_slices
                        and a.memory_slices >= b.memory_slices):
                    ra, rb = pm.rates(device, a.profile_id), pm.rates(device, b.profile_id)
                    assert ra[0] >= rb[0] and ra[1] >= rb[1]
                    assert pm.capacity_rps(device, a.profile_id, 512, 64) >= (
                        pm.capacity_rps(device, b.profile_id, 512, 64)
                    )

    def test_whole_device_matches_table(self):
        pm = PerfModel()
        tp = DEVICE_THROUGHPUT["A100-80GB"]
        assert pm.rates(A100_80GB, 0) == (
            tp.prefill_tokens_per_s, tp.decode_tokens_per_s
        )

    def test_calibration_overrides_table(self):
        pm = PerfModel(calibration={"A100-80GB": DeviceThroughput(70.0, 7.0)})
        assert pm.rates(A100_80GB, 0) == (70.0, 7.0)

    def test_calibrator_hook_used_for_unknown_device(self):
        calls = []
        exotic = dataclasses.replace(A100_80GB, name="B300-288GB")

        def hook(device):
            calls.append(device.name)
            return DeviceThroughput(100.0, 10.0)

        pm = PerfModel(calibrator=hook)
        assert pm.rates(exotic, 0) == (100.0, 10.0)
        pm.rates(exotic, 9)
        assert calls == ["B300-288GB"]  # cached after the first consult

    def test_service_seconds_compose(self):
        pm = PerfModel()
        pre, dec = pm.service_seconds(A100_80GB, 0, 1000, 100)
        tp = DEVICE_THROUGHPUT["A100-80GB"]
        assert pre == pytest.approx(1000 / tp.prefill_tokens_per_s)
        assert dec == pytest.approx(100 / tp.decode_tokens_per_s)


# ---------------------------------------------------------------------------
# autoscaler hysteresis: no flapping under steady load
# ---------------------------------------------------------------------------
class TestAutoscalerHysteresis:
    def _drive(self, scaler, offered_seq, cap=2.0, dt=5.0):
        """Apply decisions back onto the replica count each tick."""
        replicas, history = 0, []
        for i, offered in enumerate(offered_seq):
            obs = ModelLoad("m", offered_rps=offered, capacity_rps=cap,
                            replicas=replicas)
            (dec,) = scaler.tick(i * dt, [obs])
            replicas = dec.target
            history.append(replicas)
        return history

    def test_steady_load_converges_and_holds(self):
        scaler = Autoscaler(AutoscalerConfig(up_cooldown=0.0))
        history = self._drive(scaler, [10.0] * 40)
        # ceil(10 / (0.7 * 2)) = 8; reached quickly, then dead flat.
        assert history[-1] == 8
        settle = history.index(8)
        assert settle <= 2
        assert set(history[settle:]) == {8}

    def test_noisy_load_inside_band_never_scales_down(self):
        scaler = Autoscaler(AutoscalerConfig(up_cooldown=0.0))
        base = [10.0] * 5
        # +-8% noise keeps desired within the 20% hysteresis band.
        noisy = [10.0 * (1 + (0.08 if i % 2 else -0.08)) for i in range(40)]
        history = self._drive(scaler, base + noisy)
        peak = max(history)
        assert history[-1] == peak
        assert history.count(peak) >= len(history) - 3  # no flapping

    def test_sustained_drop_scales_down_after_cooldown(self):
        cfg = AutoscalerConfig(up_cooldown=0.0, down_cooldown=20.0)
        scaler = Autoscaler(cfg)
        history = self._drive(scaler, [10.0] * 5 + [2.0] * 20, dt=5.0)
        assert history[4] == 8
        assert history[-1] == 2  # ceil(2 / 1.4)
        # the drop is delayed by the down-cooldown, not instantaneous:
        assert history[6] == 8

    def test_slo_mode_scales_up_on_missed_attainment(self):
        scaler = Autoscaler(AutoscalerConfig(mode="slo", up_cooldown=0.0))
        obs = ModelLoad("m", offered_rps=1.0, capacity_rps=2.0, replicas=4,
                        slo_attainment=0.80, slo=SLO(attainment_target=0.95))
        (dec,) = scaler.tick(0.0, [obs])
        assert dec.target > 4  # utilization looked fine; the tail did not

    def test_min_max_replica_clamps(self):
        cfg = AutoscalerConfig(min_replicas=2, max_replicas=5, up_cooldown=0.0)
        scaler = Autoscaler(cfg)
        lo = ModelLoad("m", offered_rps=0.0, capacity_rps=2.0, replicas=0)
        hi = ModelLoad("m", offered_rps=1e4, capacity_rps=2.0, replicas=2)
        assert scaler.desired_replicas(lo) == 2
        assert scaler.desired_replicas(hi) == 5


# ---------------------------------------------------------------------------
# closed loop: DemandSimulator end to end
# ---------------------------------------------------------------------------
def _slo():
    return SLO(ttft_seconds=2.0, tpot_seconds=0.05)


def _spec(model="chat", pid=9, **kw):
    return ModelServiceSpec(model=model, profile_id=pid, slo=_slo(), **kw)


class TestDemandSimulator:
    def _run(self, specs, traffic_specs, n_gpus=8, horizon=150.0, seed=0,
             scaler=None, **kw):
        fleet = build_fleet([(A100_80GB, n_gpus)])
        traffic = generate_requests(traffic_specs, seed=seed, horizon=horizon)
        sim = DemandSimulator(
            fleet, PlacementEngine("rule_based"), specs,
            autoscaler=scaler, **kw,
        )
        stats = sim.run(traffic)
        fleet.validate()
        return fleet, stats

    def test_all_requests_accounted(self):
        fleet, stats = self._run(
            [_spec(initial_replicas=2)],
            [ModelTraffic("chat", ConstantRate(2.0))],
            scaler=Autoscaler(AutoscalerConfig(up_cooldown=0.0)),
        )
        assert stats.n_requests > 0
        assert stats.n_completed + stats.n_unserved == stats.n_requests
        assert 0.0 <= stats.slo_attainment <= 1.0
        assert stats.slo_attainment_by_model.keys() == {"chat"}

    def test_static_mode_never_scales(self):
        fleet, stats = self._run(
            [_spec(initial_replicas=3)],
            [ModelTraffic("chat", ConstantRate(2.0))],
            scaler=None,
        )
        assert stats.n_scale_ups == stats.n_scale_downs == 0
        assert len(fleet.workloads) == 3

    def test_flash_crowd_triggers_scale_up_then_down(self):
        fleet, stats = self._run(
            [_spec(initial_replicas=1)],
            [ModelTraffic("chat", FlashCrowd(0.5, 40.0, 30.0, 8.0),
                          mean_prompt_len=2048, mean_decode_len=256)],
            horizon=200.0,
            scaler=Autoscaler(AutoscalerConfig(
                up_cooldown=0.0, down_cooldown=20.0
            )),
        )
        assert stats.n_scale_ups > 0
        assert stats.n_scale_downs > 0
        assert stats.n_autoscale_ticks > 0

    def test_deterministic_replay(self):
        kw = dict(
            specs=[_spec(initial_replicas=1)],
            traffic_specs=[ModelTraffic("chat", DiurnalRate(2.0, period=80.0))],
            scaler=Autoscaler(AutoscalerConfig(up_cooldown=0.0)),
        )
        _, a = self._run(**kw)
        kw["scaler"] = Autoscaler(AutoscalerConfig(up_cooldown=0.0))
        _, b = self._run(**kw)
        da, db = a.as_dict(), b.as_dict()
        da.pop("engine_seconds"), db.pop("engine_seconds")  # wall-clock
        assert da == db

    def test_resize_right_sizes_on_ladder(self):
        fleet, stats = self._run(
            [_spec(pid=9, profile_ladder=(9, 15, 19), initial_replicas=2)],
            [ModelTraffic("chat", ConstantRate(0.2),
                          mean_prompt_len=64, mean_decode_len=8)],
            scaler=Autoscaler(AutoscalerConfig(up_cooldown=0.0)),
        )
        # trickle load on a 3g profile: the loop converts replicas down the
        # ladder (make-before-break) instead of just shedding them.
        assert stats.n_resizes > 0
        for w in fleet.workloads.values():
            assert w.profile_id in (9, 15, 19)

    def test_unknown_traffic_model_rejected(self):
        fleet = build_fleet([(A100_80GB, 2)])
        sim = DemandSimulator(fleet, PlacementEngine("rule_based"), [_spec()])
        bad = generate_requests(
            [ModelTraffic("ghost", ConstantRate(1.0))], seed=0, horizon=10.0
        )
        with pytest.raises(ValueError, match="ghost"):
            sim.run(bad)

    def test_migrations_flow_through_commit_policy(self):
        fleet, stats = self._run(
            [_spec(initial_replicas=4)],
            [ModelTraffic("chat", DiurnalRate(3.0, period=100.0))],
            scaler=Autoscaler(AutoscalerConfig(
                up_cooldown=0.0, down_cooldown=10.0
            )),
            compact_every=20.0,
        )
        # churn from scale-down plus periodic compaction: every migration
        # was planned/priced (counted) or rejected by the CommitPolicy.
        assert stats.n_compactions + stats.n_compactions_skipped > 0
        if stats.n_migrations:
            assert stats.bytes_moved > 0
