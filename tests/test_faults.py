"""Fault injection & recovery control plane (core/faults.py + plumbing)."""
import dataclasses

import pytest

from repro.core.autoscaler import SLO
from repro.core.engine import PlacementEngine
from repro.core.events import (
    DemandSimulator,
    Event,
    ModelServiceSpec,
    OnlineSimulator,
    Trace,
)
from repro.core.faults import FaultInjector, FaultSpec
from repro.core.fleetgen import build_fleet
from repro.core.migration import CommitPolicy, MigrationPlan, Move
from repro.core.profiles import A100_80GB
from repro.core.state import ClusterState, Workload
from repro.core.traffic import ConstantRate, ModelTraffic, generate_requests
from repro.serving.cluster import (
    ClusterServer,
    NoReplicaError,
    PlanExecutionError,
    StepPolicy,
)


def snap(state):
    """Byte-identity fingerprint of a cluster state."""
    return (
        {gid: (tuple(g.placements), g.health) for gid, g in state.gpus.items()},
        dict(state.workloads),
    )


def stats_dict(stats):
    """Stats as a dict, minus wall-clock fields (never deterministic)."""
    d = dataclasses.asdict(stats)
    d.pop("engine_seconds")
    return d


# ---------------------------------------------------------------------------
# the injector
# ---------------------------------------------------------------------------
class TestFaultInjector:
    def _fleet(self, n=4):
        return ClusterState.homogeneous(n, A100_80GB)

    def test_schedule_is_deterministic(self):
        specs = [
            FaultSpec("gpu_failure", rate=0.05),
            FaultSpec("node_drain", at=(10.0, 20.0), duration=5.0),
        ]
        fleet = self._fleet()
        a = FaultInjector(specs, seed=3).schedule(fleet, 100.0)
        b = FaultInjector(specs, seed=3).schedule(fleet, 100.0)
        assert a == b
        assert a != FaultInjector(specs, seed=4).schedule(fleet, 100.0)

    def test_substreams_are_independent(self):
        """Adding a spec never perturbs another spec's events."""
        a = FaultSpec("gpu_failure", rate=0.05)
        b = FaultSpec("slice_failure", rate=0.1)
        fleet = self._fleet()
        solo = FaultInjector([a], seed=7).schedule(fleet, 200.0)
        both = FaultInjector([a, b], seed=7).schedule(fleet, 200.0)
        assert [e for e in both if e.spec == "gpu_failure"] == solo

    def test_targets_repairs_and_horizon(self):
        fleet = self._fleet(3)
        events = FaultInjector(
            [FaultSpec("node_drain", at=(5.0, 500.0), duration=7.0, count=2)],
            seed=0,
        ).schedule(fleet, 100.0)
        drains = [e for e in events if e.kind == "node_drain"]
        repairs = [e for e in events if e.kind == "repair"]
        assert len(drains) == 2  # t=500 is past the horizon
        assert len(repairs) == 2  # one paired repair per incident
        assert {e.gid for e in events} <= set(fleet.gpus)
        assert all(r.time == pytest.approx(5.0 + 7.0) for r in repairs)
        assert len({d.gid for d in drains}) == 2  # count=2, no replacement

    def test_slice_failure_index_in_range(self):
        fleet = self._fleet()
        events = FaultInjector(
            [FaultSpec("slice_failure", at=(1.0, 2.0, 3.0))], seed=1
        ).schedule(fleet, 10.0)
        assert events
        n = A100_80GB.n_memory_slices
        assert all(0 <= e.index < n for e in events)

    def test_empty_and_unknown_gids(self):
        fleet = self._fleet()
        assert FaultInjector([], seed=0).schedule(fleet, 100.0) == []
        # gids not in the fleet are skipped, not crashed on
        events = FaultInjector(
            [FaultSpec("gpu_failure", at=(1.0,), gids=("nope",))], seed=0
        ).schedule(fleet, 10.0)
        assert events == []

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            FaultSpec("meteor_strike")
        with pytest.raises(ValueError):
            FaultSpec("gpu_failure", rate=-1.0)
        with pytest.raises(ValueError):
            FaultSpec("gpu_failure", count=0)


# ---------------------------------------------------------------------------
# state: health marks under the journal
# ---------------------------------------------------------------------------
class TestHealthJournal:
    def test_health_and_forget_roll_back_byte_identical(self):
        state = ClusterState.homogeneous(2, A100_80GB)
        state.add_workload(Workload("w", 9))
        state.place("w", "gpu0", 4)
        before = snap(state)
        with state.transaction() as txn:
            state.remove("w", "gpu0")
            state.forget_workload("w")
            state.set_health("gpu0", "failed")
            assert state.gpus["gpu0"].health == "failed"
            txn.rollback()
        assert snap(state) == before
        state.validate()

    def test_unhealthy_gpu_rejects_new_placements(self):
        state = ClusterState.homogeneous(1, A100_80GB)
        state.set_health("gpu0", "draining")
        prof = A100_80GB.profile(9)
        assert not state.gpus["gpu0"].can_place_at(prof, 4)
        state.set_health("gpu0", "healthy")
        assert state.gpus["gpu0"].can_place_at(prof, 4)

    def test_set_health_validates(self):
        state = ClusterState.homogeneous(1, A100_80GB)
        with pytest.raises(ValueError):
            state.set_health("gpu0", "on-fire")


class TestCommitEscalation:
    def test_bypass_lifts_gating_and_budgets(self):
        cp = CommitPolicy(mode="net-positive", move_budget=1, bytes_budget=10)
        esc = cp.escalate()
        assert esc is not None
        assert esc.mode == "always"
        assert esc.move_budget is None
        assert esc.bytes_budget is None
        assert esc.downtime_budget_seconds is None

    def test_gated_disables_escalation(self):
        assert CommitPolicy(emergency="gated").escalate() is None

    def test_invalid_tier_rejected(self):
        with pytest.raises(ValueError):
            CommitPolicy(emergency="sometimes")


# ---------------------------------------------------------------------------
# OnlineSimulator: eviction, recovery, accounting
# ---------------------------------------------------------------------------
def _arrivals(*workloads, t=1.0):
    return Event(time=t, kind="arrival", workloads=tuple(workloads))


class TestOnlineSimulatorFaults:
    def test_spare_capacity_recovers_immediately(self):
        state = ClusterState.homogeneous(4, A100_80GB)
        sim = OnlineSimulator(
            state,
            PlacementEngine("rule_based"),
            faults=FaultInjector(
                [FaultSpec("gpu_failure", at=(10.0,), gids=("gpu0",))], seed=0
            ),
        )
        stats = sim.run(Trace(
            events=[_arrivals(Workload("a", 9), Workload("b", 9))],
            horizon=50.0,
        ))
        assert stats.n_gpu_failures == 1
        assert stats.n_fault_evictions == 2  # rule_based packs both on gpu0
        assert stats.n_fault_recovered == 2
        assert stats.n_recovery_pending == 0
        assert stats.recovery_seconds_max == 0.0  # re-placed the same instant
        # 1 whole GPU down for the remaining 40s of the horizon
        assert stats.capacity_lost_gpu_seconds == pytest.approx(40.0)
        assert state.gpus["gpu0"].health == "failed"
        assert all(state.gpu_of(w) not in (None, "gpu0") for w in ("a", "b"))
        state.validate()

    def test_full_fleet_recovers_after_repair(self):
        state = ClusterState.homogeneous(2, A100_80GB)
        sim = OnlineSimulator(
            state,
            PlacementEngine("rule_based"),
            faults=FaultInjector(
                [FaultSpec("gpu_failure", at=(10.0,), duration=20.0,
                           gids=("gpu0",))],
                seed=0,
            ),
        )
        # 4 x 3g.40gb fills both GPUs: nowhere to recover until the repair.
        stats = sim.run(Trace(
            events=[_arrivals(*(Workload(f"w{i}", 9) for i in range(4)))],
            horizon=60.0,
        ))
        assert stats.n_fault_evictions == 2
        assert stats.n_repairs == 1
        assert stats.n_fault_recovered == 2
        assert stats.n_recovery_pending == 0
        # evicted at t=10, capacity only back at the t=30 repair
        assert stats.recovery_seconds_max == pytest.approx(20.0)
        assert stats.recovery_seconds_total == pytest.approx(20.0)
        assert stats.capacity_lost_gpu_seconds == pytest.approx(20.0)
        assert state.gpus["gpu0"].health == "healthy"
        state.validate()

    def test_permanent_failure_leaves_recovery_pending(self):
        state = ClusterState.homogeneous(1, A100_80GB)
        sim = OnlineSimulator(
            state,
            PlacementEngine("rule_based"),
            faults=FaultInjector(
                [FaultSpec("gpu_failure", at=(10.0,))], seed=0
            ),
        )
        stats = sim.run(Trace(
            events=[_arrivals(Workload("a", 9))], horizon=50.0
        ))
        assert stats.n_fault_evictions == 1
        assert stats.n_fault_recovered == 0
        assert stats.n_recovery_pending == 1
        assert stats.recovery_seconds_total == 0.0  # incident never closed
        assert stats.capacity_lost_gpu_seconds == pytest.approx(40.0)

    def test_ghost_departure_noops_with_counter(self):
        state = ClusterState.homogeneous(1, A100_80GB)
        sim = OnlineSimulator(
            state,
            PlacementEngine("rule_based"),
            faults=FaultInjector(
                [FaultSpec("gpu_failure", at=(10.0,))], seed=0
            ),
        )
        stats = sim.run(Trace(
            events=[
                _arrivals(Workload("a", 9)),
                Event(time=30.0, kind="departure", wids=("a",)),
            ],
            horizon=50.0,
        ))
        assert stats.n_ghost_departures == 1
        assert stats.n_departed == 0  # the ghost is not a real departure
        assert stats.n_recovery_pending == 0  # its lifetime ended

    def test_slice_failure_kills_only_covering_placement(self):
        state = ClusterState.homogeneous(2, A100_80GB)
        for wid, idx in (("lo", 0), ("hi", 4)):
            state.add_workload(Workload(wid, 9))
            state.place(wid, "gpu0", idx)
        sim = OnlineSimulator(
            state,
            PlacementEngine("rule_based"),
            faults=FaultInjector(
                [FaultSpec("slice_failure", at=(5.0,), gids=("gpu0",))],
                seed=0,
            ),
        )
        stats = sim.run(Trace(events=[], horizon=40.0))
        assert stats.n_slice_failures == 1
        assert stats.n_fault_evictions == 1  # exactly one covers the slice
        assert stats.n_fault_recovered == 1  # gpu1 had room
        assert state.gpus["gpu0"].health == "degraded"
        # the survivor kept serving in place on the degraded GPU
        assert len(state.gpus["gpu0"].placements) == 1
        # capacity loss is the slice fraction, not the whole GPU
        assert stats.capacity_lost_gpu_seconds == pytest.approx(
            35.0 / A100_80GB.n_memory_slices
        )
        state.validate()

    def test_overlapping_fault_is_noop(self):
        state = ClusterState.homogeneous(2, A100_80GB)
        sim = OnlineSimulator(
            state,
            PlacementEngine("rule_based"),
            faults=FaultInjector(
                [FaultSpec("gpu_failure", at=(10.0, 20.0), gids=("gpu0",))],
                seed=0,
            ),
        )
        stats = sim.run(Trace(events=[], horizon=50.0))
        assert stats.n_gpu_failures == 1
        assert stats.n_fault_noops == 1

    def test_disabled_injector_is_byte_identical(self):
        def run(faults):
            from repro.core.events import generate_trace
            fleet = build_fleet([(A100_80GB, 6)])
            trace = generate_trace(11, fleet, horizon=80.0)
            sim = OnlineSimulator(
                fleet, PlacementEngine("rule_based"), compact_every=20.0,
                faults=faults,
            )
            return stats_dict(sim.run(trace)), snap(fleet)

        a_stats, a_state = run(None)
        b_stats, b_state = run(FaultInjector([]))
        assert a_stats == b_stats
        assert a_state == b_state


# ---------------------------------------------------------------------------
# emergency escalation: recovery must repack to make room
# ---------------------------------------------------------------------------
def _blocked_fleet():
    """gpu0 carries two 1g.10gb blockers at memory 1 and 4, so no 3g.40gb
    (allowed at 0 or 4) fits without repacking; gpu1 hosts the victim."""
    state = ClusterState.homogeneous(2, A100_80GB)
    for wid, idx in (("b1", 1), ("b2", 4)):
        state.add_workload(Workload(wid, 19))
        state.place(wid, "gpu0", idx)
    state.add_workload(Workload("v", 9))
    state.place("v", "gpu1", 4)
    return state


class TestEmergencyEscalation:
    def _run(self, commit):
        state = _blocked_fleet()
        sim = OnlineSimulator(
            state,
            PlacementEngine("heuristic", commit=commit),
            faults=FaultInjector(
                [FaultSpec("gpu_failure", at=(10.0,), gids=("gpu1",))],
                seed=0,
            ),
        )
        stats = sim.run(Trace(events=[], horizon=50.0))
        return state, stats

    def test_bypass_repacks_and_recovers(self):
        state, stats = self._run(CommitPolicy(mode="net-positive"))
        assert stats.n_fault_evictions == 1
        assert stats.n_emergency_commits >= 1
        assert stats.n_fault_recovered == 1
        assert stats.n_recovery_pending == 0
        assert state.gpu_of("v") == "gpu0"
        state.validate()

    def test_gated_stays_pending(self):
        state, stats = self._run(
            CommitPolicy(mode="net-positive", emergency="gated")
        )
        assert stats.n_emergency_commits == 0
        assert stats.n_fault_recovered == 0
        assert stats.n_recovery_pending == 1
        state.validate()


# ---------------------------------------------------------------------------
# DemandSimulator: requeue, brownout, warmup
# ---------------------------------------------------------------------------
def _slo():
    return SLO(ttft_seconds=2.0, tpot_seconds=0.05)


class TestDemandSimulatorFaults:
    def _run(self, faults, horizon=120.0, rate=30.0, n_gpus=2):
        fleet = build_fleet([(A100_80GB, n_gpus)])
        specs = [
            ModelServiceSpec(model="chat", profile_id=9, slo=_slo(),
                             initial_replicas=3),
            ModelServiceSpec(model="bot", profile_id=19, slo=_slo(),
                             initial_replicas=1, best_effort=True),
        ]
        traffic = generate_requests(
            [ModelTraffic("chat", ConstantRate(rate)),
             ModelTraffic("bot", ConstantRate(2.0))],
            seed=0, horizon=horizon,
        )
        sim = DemandSimulator(
            fleet, PlacementEngine("rule_based"), specs, faults=faults
        )
        stats = sim.run(traffic)
        fleet.validate()
        return fleet, stats

    def test_eviction_requeues_and_brownout_sheds(self):
        fleet, stats = self._run(FaultInjector(
            [FaultSpec("gpu_failure", at=(30.0,), gids=("a100-0",))], seed=0
        ))
        assert stats.n_gpu_failures == 1
        assert stats.n_fault_evictions >= 1
        # chat load (rate 30 on 3 replicas) keeps replicas busy: the evicted
        # replica's in-flight request went back to the front of the queue.
        assert stats.n_requeued_requests >= 1
        # 2 tight GPUs cannot host all evictions -> brownout until horizon,
        # shedding the best-effort model's arrivals.
        if stats.n_recovery_pending:
            assert stats.brownout_seconds > 0.0
            assert stats.n_shed_requests >= 1
        assert stats.n_requests == (
            stats.n_completed + stats.n_unserved + stats.n_shed_requests
        )

    def test_recovered_replica_restores_cold(self):
        # plenty of room to recover into: warmup delay dominates recovery
        fleet, stats = self._run(
            FaultInjector(
                [FaultSpec("gpu_failure", at=(30.0,), gids=("a100-0",))],
                seed=0,
            ),
            n_gpus=4, rate=5.0,
        )
        assert stats.n_fault_recovered >= 1
        assert stats.n_recovery_pending == 0
        # recovery closes at serving-ready (transfer + cold resume), not at
        # placement time
        assert stats.recovery_seconds_max > 0.0

    def test_disabled_injector_is_byte_identical(self):
        a_fleet, a = self._run(None, rate=5.0)
        b_fleet, b = self._run(FaultInjector([]), rate=5.0)
        assert stats_dict(a) == stats_dict(b)
        assert snap(a_fleet) == snap(b_fleet)


# ---------------------------------------------------------------------------
# ClusterServer: step machine, rollback/resume, NoReplicaError, fail_node
# ---------------------------------------------------------------------------
def _fragmented_server(**kw):
    """4 single-replica models, 2 retired -> compaction has real moves."""
    srv = ClusterServer(
        4, device=A100_80GB,
        step_policy=StepPolicy(backoff_seconds=0.0), **kw,
    )
    srv._sleep = lambda s: None  # no real backoff sleeps in tests
    for m in ("a", "b", "c", "d"):
        srv.deploy(m, "unused-arch", n_replicas=1, profile_id=9)
    srv.retire("a", 1)
    srv.retire("d", 1)
    return srv


def _server_snap(srv):
    return snap(srv.state)


class TestClusterStepMachine:
    def test_transient_failure_retries_and_commits(self):
        srv = _fragmented_server()
        srv.inject_step_failure("copy", times=1)
        rep = srv.compact()
        assert rep.committed
        assert rep.execution.completed
        assert rep.execution.n_retries == 1
        srv.state.validate()

    @pytest.mark.parametrize("kind", ["copy", "cutover"])
    def test_exhausted_retries_roll_back_byte_identical(self, kind):
        srv = _fragmented_server()
        before = _server_snap(srv)
        srv.inject_step_failure(kind, times=99)
        rep = srv.compact()
        assert not rep.committed
        assert rep.execution is not None
        assert not rep.execution.completed
        assert rep.execution.rolled_back
        assert rep.execution.failed_step == kind
        assert _server_snap(srv) == before
        srv.state.validate()

    @pytest.mark.parametrize("kind", ["drain", "copy", "resume"])
    def test_disruptive_plan_fails_at_each_step(self, kind):
        """Drive _execute_plan directly with a disruptive move so the
        drain/resume phases exist, and crash each step kind."""
        srv = _fragmented_server()
        gid = srv.state.gpu_of("b/r1")
        plan = MigrationPlan(
            waves=[[]],
            disruptive=[Move(
                wid="b/r1", src_gid=gid, src_index=4,
                dst_gid=gid, dst_index=4, profile_id=9, disruptive=True,
            )],
        )
        srv.inject_step_failure(kind, times=99)
        with pytest.raises(PlanExecutionError) as ei:
            srv._execute_plan(plan)
        assert ei.value.step == kind
        assert ei.value.report.failed_step == kind
        # steps before the failed one are journaled for resume
        if kind == "resume":
            assert ("drain", "b/r1", -1) in ei.value.journal
            assert ("copy", "b/r1", -1) in ei.value.journal

    def test_resume_mode_journals_and_resumes(self):
        srv = _fragmented_server(on_execution_failure="resume")
        srv.inject_step_failure("cutover", times=99)
        rep = srv.compact()
        assert rep.committed  # layout kept: the engine's commit stands
        assert rep.execution.resumable
        assert srv._pending_plan is not None
        done_before = set(srv._pending_plan[1])
        srv._failpoints.clear()
        out = srv.resume_execution()
        assert out.completed
        assert srv._pending_plan is None
        # the resumed run only executed steps missing from the journal
        assert all(
            (s.kind, s.wid, s.wave) not in done_before for s in out.steps
        )
        srv.state.validate()

    def test_resume_without_pending_is_noop(self):
        srv = _fragmented_server()
        assert srv.resume_execution() is None

    def test_step_policy_validation(self):
        with pytest.raises(ValueError):
            StepPolicy(max_attempts=0)
        with pytest.raises(ValueError):
            ClusterServer(1, device=A100_80GB, on_execution_failure="panic")


class TestClusterFaultAPI:
    def test_route_raises_typed_error(self):
        srv = ClusterServer(2, device=A100_80GB)
        with pytest.raises(NoReplicaError) as ei:
            srv.route("ghost-model")
        assert ei.value.model == "ghost-model"
        assert isinstance(ei.value, LookupError)  # old callers still work

    def test_submit_backlogs_and_deploy_flushes(self):
        srv = ClusterServer(2, device=A100_80GB)
        assert srv.submit("m", object()) is None
        assert len(srv._backlog["m"]) == 1
        srv.deploy("m", "unused-arch", n_replicas=1, profile_id=9)
        assert len(srv._backlog["m"]) == 0

    def test_fail_node_recovers_elsewhere(self):
        srv = _fragmented_server()
        gid = srv.state.gpu_of("b/r1")
        report = srv.fail_node(gid)
        assert report["evicted"] == ["b/r1"]
        assert report["recovered"] == ["b/r1"]
        assert report["lost"] == []
        assert srv.state.gpus[gid].health == "failed"
        new_gid = srv.state.gpu_of("b/r1")
        assert new_gid is not None and new_gid != gid
        srv.state.validate()
        srv.repair_node(gid)
        assert srv.state.gpus[gid].health == "healthy"

    def test_fail_node_with_no_capacity_loses_replica(self):
        srv = ClusterServer(1, device=A100_80GB)
        srv.deploy("m", "unused-arch", n_replicas=1, profile_id=9)
        gid = srv.state.gpu_of("m/r0")
        report = srv.fail_node(gid)
        assert report["lost"] == ["m/r0"]
        assert "m/r0" not in srv.replicas
        assert srv.replicas_of("m") == []
        srv.state.validate()
