"""Unit tests for the paper's placement library (core/)."""
import pytest

from repro.core.profiles import A100_80GB, H100_96GB
from repro.core.state import ClusterState, GPUState, Workload
from repro.core.preprocess import determine_free_partitions, merge_partitions
from repro.core.indexing import assign_indexes, enumerate_feasible_multisets
from repro.core import baselines, heuristic, metrics
from repro.core.simulator import generate_test_case


# ---------------------------------------------------------------------------
# Table 1 / geometry
# ---------------------------------------------------------------------------
class TestProfiles:
    def test_table1_allowed_indexes(self):
        t = {p.profile_id: p.allowed_indexes for p in A100_80GB.profiles}
        assert t[0] == (0,)
        assert t[5] == (0,)
        assert t[9] == (4, 0)
        assert t[14] == (4, 0, 2)
        assert t[15] == (6, 4, 0, 2)
        assert t[19] == (6, 4, 5, 0, 1, 2, 3)
        assert t[20] == (6, 4, 5, 0, 1, 2, 3)

    def test_table1_slice_counts(self):
        p = A100_80GB.by_id
        assert (p[0].compute_slices, p[0].memory_slices) == (7, 8)
        assert (p[5].compute_slices, p[5].memory_slices) == (4, 4)
        assert (p[9].compute_slices, p[9].memory_slices) == (3, 4)
        assert (p[14].compute_slices, p[14].memory_slices) == (2, 2)
        assert (p[15].compute_slices, p[15].memory_slices) == (1, 2)
        assert (p[19].compute_slices, p[19].memory_slices) == (1, 1)

    def test_profile_names_track_memory(self):
        assert A100_80GB.profile(9).name == "3g.40gb"
        assert H100_96GB.profile(9).name == "3g.48gb"
        assert A100_80GB.profile(15).name == "1g.20gb"

    def test_compute_waste_semantics(self):
        """Table 3 notes: p9@0 wastes 1 compute; p15 wastes 1 unless at 6."""
        p9 = A100_80GB.profile(9)
        assert p9.compute_waste_at(0) == 1
        assert p9.compute_waste_at(4) == 0
        p15 = A100_80GB.profile(15)
        assert p15.compute_waste_at(6) == 0
        assert p15.compute_waste_at(4) == 1
        assert p15.compute_waste_at(0) == 1


class TestGPUState:
    def test_place_and_occupancy(self):
        g = GPUState("g0")
        g.place("a", 9, 4)  # 3g.40gb at index 4 -> mem {4,5,6,7}
        occ = g.memory_occupancy()
        assert occ == [None] * 4 + ["a"] * 4
        assert g.free_gpu_slices() == [0, 1, 2, 3]

    def test_overlap_rejected(self):
        g = GPUState("g0")
        g.place("a", 14, 4)  # 2g at 4 -> mem {4,5}
        assert not g.can_place_at(A100_80GB.profile(9), 4)
        with pytest.raises(ValueError):
            g.place("b", 9, 4)

    def test_illegal_index_rejected(self):
        g = GPUState("g0")
        assert not g.can_place_at(A100_80GB.profile(5), 3)  # 4g only at 0

    def test_memory_waste_p19_at_6(self):
        g = GPUState("g0")
        g.place("a", 19, 6)  # strands m7
        assert g.memory_waste() == 1
        g2 = GPUState("g1")
        g2.place("a", 15, 6)  # 1g.20gb claims m7
        assert g2.memory_waste() == 0

    def test_full_pack_no_waste(self):
        """Placement 2 of Fig. 6: 4g@0, 2g@4, 1g.20gb@6 -> zero waste."""
        g = GPUState("g0")
        g.place("a", 5, 0)
        g.place("b", 14, 4)
        g.place("c", 15, 6)
        assert g.compute_waste() == 0
        assert g.memory_waste() == 0
        assert g.free_gpu_slices() == []


# ---------------------------------------------------------------------------
# Assumption 1 + indexing
# ---------------------------------------------------------------------------
class TestAssumption1:
    def test_every_binfeasible_multiset_is_indexable(self):
        """The paper validated Assumption 1 exhaustively; so do we."""
        profs = A100_80GB.profiles_sorted_desc()

        def rec(i, counts):
            if i == len(profs):
                if counts:
                    yield dict(counts)
                return
            p = profs[i]
            limit = min(
                A100_80GB.n_gpu_slices // p.compute_slices,
                A100_80GB.n_memory_slices // p.memory_slices,
            )
            for n in range(limit + 1):
                if n:
                    counts[p.profile_id] = n
                trial = dict(counts)
                if A100_80GB.fits(trial):
                    yield from rec(i + 1, counts)
                if n:
                    del counts[p.profile_id]

        n_checked = 0
        for counts in rec(0, {}):
            flat = [pid for pid, n in counts.items() for _ in range(n)]
            g = GPUState("probe")
            assert assign_indexes(g, flat, optimize=False) is not None, counts
            n_checked += 1
        assert n_checked > 100  # the lattice is non-trivial

    def test_catalog_size(self):
        cat = enumerate_feasible_multisets(A100_80GB)
        assert len(cat) == 127

    def test_indexing_prefers_low_waste(self):
        # one 3g.40gb alone: optimal index is 4 (no compute waste)
        g = GPUState("g0")
        (pl,) = assign_indexes(g, [9], ["w"])
        assert pl.index == 4


# ---------------------------------------------------------------------------
# Algorithm 1 (paper Fig. 7 examples)
# ---------------------------------------------------------------------------
class TestAlgorithm1:
    def test_fig7_g1(self):
        g1 = GPUState("g1")
        g1.place("a", 19, 0)
        g1.place("b", 19, 5)
        g1.place("c", 19, 6)
        parts = determine_free_partitions(g1)
        got = [(p.start, p.compute_capacity, p.memory_capacity) for p in parts]
        assert got == [(1, 1, 1), (2, 2, 2), (4, 1, 1)]

    def test_fig7_g2_and_merge(self):
        g2 = GPUState("g2")
        g2.place("a", 15, 6)  # 1g.20gb in the last slice
        parts = determine_free_partitions(g2)
        got = [(p.start, p.compute_capacity, p.memory_capacity) for p in parts]
        assert got == [(0, 4, 4), (4, 2, 2)]
        merged = merge_partitions(parts, A100_80GB)
        assert len(merged) == 1
        assert merged[0].compute_capacity == 6
        assert merged[0].memory_capacity == 6

    def test_partition_admits(self):
        g1 = GPUState("g1")
        g1.place("a", 19, 0)
        g1.place("b", 19, 5)
        g1.place("c", 19, 6)
        parts = determine_free_partitions(g1)
        two_g = next(p for p in parts if p.compute_capacity == 2)
        assert two_g.admits(A100_80GB.profile(14), A100_80GB)  # 2g.20gb@2
        assert two_g.admits(A100_80GB.profile(15), A100_80GB)  # 1g.20gb@2
        assert two_g.admits(A100_80GB.profile(19), A100_80GB)
        assert not two_g.admits(A100_80GB.profile(9), A100_80GB)
        assert not two_g.admits(A100_80GB.profile(5), A100_80GB)


# ---------------------------------------------------------------------------
# Use-case heuristics (paper Sec 4.2) + Fig. 3 behaviour
# ---------------------------------------------------------------------------
def _fig3_state():
    st = ClusterState.homogeneous(2)
    st.add_workload(Workload("e1", 9))
    st.gpus["gpu0"].place("e1", 9, 4)  # GPU1: slices 0-3 free
    st.add_workload(Workload("e2", 5))
    st.gpus["gpu1"].place("e2", 5, 0)  # GPU2: slices 4-7 free
    return st


class TestInitialDeployment:
    def test_fig3_first_fit_blocks_the_4g(self):
        st = _fig3_state()
        w1 = Workload("w1", 9)
        w2 = Workload("w2", 5)
        pending = baselines.first_fit(st, [w1, w2])
        assert [w.wid for w in pending] == ["w2"]  # stuck pending

    def test_fig3_rule_based_avoids_blocking(self):
        st = _fig3_state()
        w1 = Workload("w1", 9)
        w2 = Workload("w2", 5)
        pending = heuristic.initial_deployment(st, [w1, w2])
        assert pending == []
        assert st.gpu_of("w1") == "gpu1"  # 3g lands next to the 4g
        assert st.gpu_of("w2") == "gpu0"
        m = metrics.evaluate(st)
        assert m.compute_wastage == 0

    def test_descending_size_order(self):
        st = ClusterState.homogeneous(1)
        ws = [Workload("s", 19), Workload("b", 5), Workload("m", 14)]
        pending = heuristic.initial_deployment(st, ws)
        assert pending == []
        st.validate()


class TestCompaction:
    def test_vacates_underutilized_gpu(self):
        st = ClusterState.homogeneous(3)
        for gid, wid, pid, idx in [
            ("gpu0", "a", 5, 0),  # 4g
            ("gpu1", "b", 9, 4),  # 3g
            ("gpu2", "c", 14, 4),  # 2g on its own GPU
        ]:
            st.add_workload(Workload(wid, pid))
            st.gpus[gid].place(wid, pid, idx)
        init = st.clone()
        heuristic.compaction(st)
        m = metrics.evaluate(st, init)
        assert m.n_gpus == 2
        assert m.sequential_migrations == 0

    def test_no_compaction_when_full(self):
        st = ClusterState.homogeneous(2)
        for gid in ("gpu0", "gpu1"):
            st.add_workload(Workload(f"w{gid}", 0))
            st.gpus[gid].place(f"w{gid}", 0, 0)
        init = st.clone()
        heuristic.compaction(st)
        assert metrics.evaluate(st, init).n_gpus == 2

    def test_free_gpu_fallback_saves_net_one(self):
        """Paper Fig. 8: direct vacate impossible, but 1 borrowed free GPU
        lets two GPUs be vacated."""
        st = ClusterState.homogeneous(4)
        # gpu0: 3g@0 (waste) + 3g@4 ; gpu1: same -> each has 0 free slices
        for gid in ("gpu0", "gpu1"):
            for i, idx in enumerate((0, 4)):
                wid = f"{gid}w{i}"
                st.add_workload(Workload(wid, 9))
                st.gpus[gid].place(wid, 9, idx)
        # gpu2, gpu3 free
        init = st.clone()
        heuristic.compaction(st)
        m = metrics.evaluate(st, init)
        assert m.n_gpus <= 2


class TestReconfiguration:
    def test_zero_waste_after_reconfig(self):
        """Fig. 5: reconfiguration eliminates all wastage."""
        st = ClusterState.homogeneous(6)
        # Deliberately wasteful initial layout on 3 GPUs.
        layout = [
            ("gpu0", "w1", 5, 0),
            ("gpu1", "w2", 9, 0),  # wastes a compute slice
            ("gpu1", "w3", 15, 4),  # wastes a compute slice
            ("gpu2", "w4", 14, 4),
            ("gpu2", "w5", 19, 6),  # strands m7
        ]
        for gid, wid, pid, idx in layout:
            st.add_workload(Workload(wid, pid))
            st.gpus[gid].place(wid, pid, idx)
        init = st.clone()
        heuristic.reconfiguration(st)
        m = metrics.evaluate(st, init)
        assert m.n_gpus == 2
        assert m.compute_wastage == 0
        assert m.memory_wastage == 0

    def test_min_gpus_eq3(self):
        ws = [Workload(f"w{i}", 19) for i in range(15)]  # 15 mem slices
        assert heuristic.min_gpus_needed(A100_80GB, ws) == 3  # ceil(15/7)=3


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------
class TestMetrics:
    def test_sequential_migration_detection(self):
        init = ClusterState.homogeneous(2)
        init.add_workload(Workload("a", 5))
        init.gpus["gpu0"].place("a", 5, 0)
        init.add_workload(Workload("b", 9))
        init.gpus["gpu1"].place("b", 9, 4)
        # final: a moved to gpu1@0 (free in initial -> one-shot),
        #        b moved to gpu0@4 (was free in initial -> one-shot)
        final = init.clone()
        final.gpus["gpu0"].remove("a")
        final.gpus["gpu1"].remove("b")
        final.gpus["gpu1"].place("a", 5, 0)
        final.gpus["gpu0"].place("b", 9, 4)
        m = metrics.evaluate(final, init)
        assert m.n_migrations == 2
        assert m.sequential_migrations == 0
        # now a move into a spot that was occupied initially
        final2 = init.clone()
        final2.gpus["gpu1"].remove("b")
        final2.gpus["gpu0"].place("b", 9, 4)
        m2 = metrics.evaluate(final2, init)
        assert m2.sequential_migrations == 0  # gpu0@4 was free initially
        final3 = init.clone()
        final3.gpus["gpu0"].remove("a")
        final3.gpus["gpu1"].remove("b")
        final3.gpus["gpu1"].place("a", 5, 0)  # where b sat (overlaps mem 4-7? no: 4g@0 covers 0-3)
        final3.gpus["gpu1"].place("b", 9, 4)
        m3 = metrics.evaluate(final3, init)
        # a->gpu1@0 one-shot (0-3 free initially); b stays (same gpu+index)
        assert m3.n_migrations == 1 and m3.sequential_migrations == 0

    def test_utilization_over_used_gpus_only(self):
        st = ClusterState.homogeneous(3)
        st.add_workload(Workload("a", 0))
        st.gpus["gpu0"].place("a", 0, 0)
        m = metrics.evaluate(st)
        assert m.n_gpus == 1
        assert m.memory_utilization == 1.0
        assert m.compute_utilization == 1.0

    def test_pending_reduces_availability(self):
        st = ClusterState.homogeneous(1)
        st.add_workload(Workload("a", 0))
        st.gpus["gpu0"].place("a", 0, 0)
        missing = Workload("zz", 14)
        m = metrics.evaluate(st, None, [st.workloads["a"], missing])
        assert m.pending_model_size == 2
        assert m.availability == -2


# ---------------------------------------------------------------------------
# Simulator
# ---------------------------------------------------------------------------
class TestSimulator:
    def test_deterministic(self):
        a = generate_test_case(42, n_gpus=8)
        b = generate_test_case(42, n_gpus=8)
        assert [w.wid for w in a.new_workloads] == [w.wid for w in b.new_workloads]
        assert {g.gid: len(g.placements) for g in a.initial.gpus.values()} == {
            g.gid: len(g.placements) for g in b.initial.gpus.values()
        }

    def test_allocation_fraction(self):
        tc = generate_test_case(7, n_gpus=80)
        used = len(tc.initial.used_gpus())
        assert 40 <= used <= 56  # ~60% of 80
        tc.initial.validate()
