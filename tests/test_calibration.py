"""Kernel calibration observatory tests.

Covers the measure -> model -> plan loop:

- ``PerfModel`` throughput-source precedence (calibration dict beats
  calibrator hook beats built-in table) and one-call-per-device caching of
  the calibrator;
- ``PerfModel.from_calibration`` round-tripping the profiler's
  ``CALIBRATION.json`` schema (and rejecting malformed artifacts);
- the ``repro.obs.profile`` sweep: schema-valid artifact, per-rep
  ``kernel_wall_seconds`` observations, slice-shaped problem scaling;
- ``benchmarks.kernel_bench._timeit`` invoking the op exactly once per
  rep (the historical double-invoke bug) and emitting strict JSON;
- the host-contention guard;
- the ``validate_bench`` schema dispatch and ``--baseline`` regression
  gate exiting non-zero on drift (the PR's acceptance demonstration);
- ``placement_bench --autoscale --calibrated`` end-to-end on an artifact
  produced by ``benchmarks.calibrate``.
"""
import json
import math
import sys

import pytest

from repro import obs
from repro.core.perfmodel import DEVICE_THROUGHPUT, DeviceThroughput, PerfModel
from repro.core.profiles import A100_80GB, H100_96GB

jax = pytest.importorskip("jax")

from benchmarks import calibrate, kernel_bench, validate_bench  # noqa: E402
from repro.obs import profile  # noqa: E402


@pytest.fixture(autouse=True)
def _isolated_telemetry():
    yield
    obs.disable()


# ---------------------------------------------------------------------------
# PerfModel precedence + caching
# ---------------------------------------------------------------------------
class TestPerfModelPrecedence:
    def test_builtin_table_is_the_default(self):
        pm = PerfModel()
        assert pm.device_throughput(A100_80GB) == DEVICE_THROUGHPUT["A100-80GB"]

    def test_calibrator_beats_builtin_table(self):
        measured = DeviceThroughput(123.0, 45.0)
        pm = PerfModel(calibrator=lambda d: measured)
        assert pm.device_throughput(A100_80GB) == measured

    def test_calibration_dict_beats_calibrator(self):
        explicit = DeviceThroughput(999.0, 99.0)
        pm = PerfModel(
            calibration={"A100-80GB": explicit},
            calibrator=lambda d: DeviceThroughput(1.0, 1.0),
        )
        assert pm.device_throughput(A100_80GB) == explicit
        # the hook still wins for devices the dict doesn't cover
        assert pm.device_throughput(H100_96GB) == DeviceThroughput(1.0, 1.0)

    def test_calibrator_consulted_once_per_device(self):
        calls = []

        def hook(device):
            calls.append(device.name)
            return DeviceThroughput(100.0, 10.0)

        pm = PerfModel(calibrator=hook)
        for _ in range(5):
            pm.device_throughput(A100_80GB)
            pm.rates(A100_80GB, 9)
        pm.device_throughput(H100_96GB)
        pm.device_throughput(H100_96GB)
        assert calls == ["A100-80GB", "H100-96GB"]

    def test_unknown_device_falls_back_to_per_gb_estimate(self):
        import dataclasses
        ghost = dataclasses.replace(A100_80GB, name="GHOST-1")
        tp = PerfModel().device_throughput(ghost)
        assert tp.prefill_tokens_per_s > 0 and tp.decode_tokens_per_s > 0


class TestFromCalibration:
    def _report(self, prefill=50_000.0, decode=4_000.0, eff=0.8):
        return {
            "schema": "calibration/v1",
            "devices": {
                "A100-80GB": {
                    "whole_device": {
                        "prefill_tokens_per_s": prefill,
                        "decode_tokens_per_s": decode,
                    },
                    "parallel_efficiency": eff,
                    "profiles": {"0": {"name": "7g.80gb"}},
                }
            },
        }

    def test_loads_rates_and_fitted_exponent(self):
        pm = PerfModel.from_calibration(self._report())
        assert pm.device_throughput(A100_80GB) == DeviceThroughput(50_000.0, 4_000.0)
        assert pm.parallel_efficiency == pytest.approx(0.8)
        # the exponent shapes sub-device rates: 3g gets (3/7)^0.8 of prefill
        prefill, _ = pm.rates(A100_80GB, 9)
        assert prefill == pytest.approx(50_000.0 * (3 / 7) ** 0.8)

    def test_explicit_exponent_overrides_fitted(self):
        pm = PerfModel.from_calibration(self._report(eff=0.5),
                                        parallel_efficiency=1.0)
        assert pm.parallel_efficiency == 1.0

    def test_rejects_wrong_schema_and_bad_rates(self):
        with pytest.raises(ValueError, match="schema"):
            PerfModel.from_calibration({"schema": "placement_bench/v1"})
        with pytest.raises(ValueError, match="devices"):
            PerfModel.from_calibration({"schema": "calibration/v1",
                                        "devices": {}})
        bad = self._report(prefill=0.0)
        with pytest.raises(ValueError, match="non-positive"):
            PerfModel.from_calibration(bad)

    def test_reads_from_file(self, tmp_path):
        path = tmp_path / "CALIBRATION.json"
        path.write_text(json.dumps(self._report()))
        pm = PerfModel.from_calibration(path)
        assert pm.device_throughput(A100_80GB).prefill_tokens_per_s == 50_000.0


# ---------------------------------------------------------------------------
# the profiler sweep (tiny preset, 1 rep: structure over statistics)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny_artifact(tmp_path_factory):
    """One tiny calibration sweep shared by the round-trip tests."""
    out = tmp_path_factory.mktemp("cal") / "CALIBRATION.json"
    rc = calibrate.main(
        ["--preset", "tiny", "--reps", "1", "--warmup", "0",
         "--out", str(out)]
    )
    assert rc == 0
    return out


class TestProfilerSweep:
    def test_artifact_is_schema_valid(self, tiny_artifact):
        assert validate_bench.validate(str(tiny_artifact)) == []

    def test_round_trip_into_perfmodel(self, tiny_artifact):
        rep = json.loads(tiny_artifact.read_text())
        pm = PerfModel.from_calibration(tiny_artifact)
        whole = rep["devices"]["A100-80GB"]["whole_device"]
        tp = pm.device_throughput(A100_80GB)
        assert tp.prefill_tokens_per_s == pytest.approx(
            whole["prefill_tokens_per_s"])
        assert tp.decode_tokens_per_s == pytest.approx(
            whole["decode_tokens_per_s"])
        assert 0.0 < pm.parallel_efficiency <= 1.0
        # monotone through the model: bigger profiles never serve slower
        ladder = [0, 5, 9, 14, 15, 19]
        rates = [pm.rates(A100_80GB, pid) for pid in ladder]
        for (p_big, d_big), (p_small, d_small) in zip(rates, rates[1:]):
            assert p_big >= p_small and d_big >= d_small

    def test_sweep_covers_distinct_profiles_and_kernels(self, tiny_artifact):
        rep = json.loads(tiny_artifact.read_text())
        rows = rep["kernels"]
        kernels = {r["kernel"] for r in rows}
        assert kernels == {"flash_attention", "decode_attention", "ssd_scan"}
        # A100 ladder has 6 distinct (compute, memory) footprints
        profiles = {r["profile_id"] for r in rows}
        assert profiles == {0, 5, 9, 14, 15, 19}
        for r in rows:
            assert r["wall_s"]["p50"] > 0
            assert r["flops"] > 0 and r["bytes"] > 0

    def test_problem_sizes_scale_with_slice_budget(self, tiny_artifact):
        rep = json.loads(tiny_artifact.read_text())
        by_prof = {
            r["profile_id"]: r for r in rep["kernels"]
            if r["kernel"] == "flash_attention"
        }
        # prefill batch shrinks with the compute fraction: 7g does 2x256
        # tokens per call at the tiny preset, 1g does 1x256
        assert by_prof[0]["tokens"] == 2 * 256
        assert by_prof[19]["tokens"] == 1 * 256

    def test_measure_records_obs_histograms(self):
        with obs.enabled() as tel:
            timing = profile.measure(
                lambda x: x + 1.0, 1.0, reps=3, warmup=1,
                labels={"kernel": "dummy", "device": "t", "profile": "p"},
            )
        assert len(timing.wall_s) == 3
        hist = tel.metrics.get(
            "kernel_wall_seconds",
            labels={"kernel": "dummy", "device": "t", "profile": "p"},
        )
        assert hist is not None and hist.count == 3


# ---------------------------------------------------------------------------
# kernel_bench: the _timeit fix + strict JSON report
# ---------------------------------------------------------------------------
class TestKernelBench:
    def test_timeit_invokes_exactly_once_per_rep(self):
        calls = []

        def fn(x):
            calls.append(x)
            return float(x)

        walls = kernel_bench._timeit(fn, 7, n=3, warmup=1)
        assert len(calls) == 4  # 1 warm-up + 3 timed — not double-invoked
        assert len(walls) == 3 and all(w >= 0 for w in walls)

    def test_emits_schema_valid_json(self, tmp_path, capsys):
        out = tmp_path / "BENCH_kernels.json"
        rc = kernel_bench.main(
            ["--preset", "tiny", "--reps", "1", "--warmup", "0",
             "--json", str(out)]
        )
        assert rc == 0
        assert validate_bench.validate(str(out)) == []
        rep = json.loads(out.read_text())
        assert rep["schema"] == "kernel_bench/v1"
        assert isinstance(rep["host"]["contended"], bool)
        assert len(rep["kernels"]) == 3
        for row in rep["kernels"].values():
            assert row["p50_us"] <= row["p95_us"]
        # human CSV still lands on stdout
        assert "kernel,shape,us_per_call" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# host-contention guard
# ---------------------------------------------------------------------------
class TestHostGuard:
    def test_high_load_flags_contended(self, monkeypatch):
        import os

        from repro.obs import host
        monkeypatch.setattr(os, "getloadavg", lambda: (999.0, 0.0, 0.0))
        monkeypatch.setattr(host, "competing_processes", lambda **kw: [])
        snap = host.host_snapshot(warn=False)
        assert snap["contended"] is True
        assert snap["load1"] == 999.0

    def test_competitor_process_flags_contended(self, monkeypatch):
        from repro.obs import host
        monkeypatch.setattr(
            host, "competing_processes",
            lambda **kw: [{"pid": 4242, "cmdline": "python -m pytest"}],
        )
        snap = host.host_snapshot(warn=False)
        assert snap["contended"] is True
        assert snap["competing"][0]["pid"] == 4242

    def test_snapshot_shape(self):
        snap = obs.host_snapshot(warn=False)
        assert set(snap) >= {"load1", "n_cpus", "competing", "contended"}
        assert isinstance(snap["contended"], bool)


# ---------------------------------------------------------------------------
# validate_bench: schema dispatch + the regression gate
# ---------------------------------------------------------------------------
def _kernel_report(tmp_path, name, p50=100.0, p95=120.0):
    rep = {
        "schema": "kernel_bench/v1",
        "generated_unix": 1.0,
        "args": {},
        "host": {"contended": False},
        "kernels": {
            "flash_attention@B8xS2048": {
                "p50_us": p50, "p95_us": p95, "reps": 5,
            },
        },
    }
    path = tmp_path / name
    path.write_text(json.dumps(rep))
    return str(path)


def _placement_report(tmp_path, name, p50=0.01, p95=0.02):
    rep = {
        "schema": "placement_bench/v1",
        "generated_unix": 1.0,
        "args": {},
        "trace": {"rule_based": {"avg_gpus": 3.0}},
        "planner_latency": {
            "deploy@rule_based": {
                "count": 10, "total_s": 0.2,
                "p50_s": p50, "p95_s": p95, "p99_s": p95 * 1.1,
            },
        },
    }
    path = tmp_path / name
    path.write_text(json.dumps(rep))
    return str(path)


class TestValidateBench:
    def test_schema_dispatch_rejects_unknown(self, tmp_path):
        p = tmp_path / "x.json"
        p.write_text(json.dumps({"schema": "mystery/v9"}))
        errs = validate_bench.validate(str(p))
        assert errs and "schema" in errs[0]

    def test_nan_token_rejected(self, tmp_path):
        p = tmp_path / "nan.json"
        p.write_text('{"schema": "kernel_bench/v1", "x": NaN}')
        errs = validate_bench.validate(str(p))
        assert errs and "non-strict" in errs[0]

    def test_kernel_schema_checks_percentile_order(self, tmp_path):
        path = _kernel_report(tmp_path, "k.json", p50=200.0, p95=100.0)
        errs = validate_bench.validate(path)
        assert any("p50 > p95" in e for e in errs)

    def test_gate_passes_within_tolerance_and_fails_on_drift(self, tmp_path):
        base_rep = _kernel_report(tmp_path, "base.json")
        baseline = str(tmp_path / "BENCH_baseline.json")
        assert validate_bench.main(
            [base_rep, "--baseline", baseline, "--write-baseline"]
        ) == 0
        # identical numbers: gate OK
        assert validate_bench.main([base_rep, "--baseline", baseline]) == 0
        # 3x p50/p95 drift: gate exits non-zero (acceptance criterion)
        drifted = _kernel_report(tmp_path, "drift.json", p50=300.0, p95=360.0)
        assert validate_bench.main([drifted, "--baseline", baseline]) == 1
        # ... unless warn-only (the CI mode before a baseline is trusted)
        assert validate_bench.main(
            [drifted, "--baseline", baseline, "--warn-only"]
        ) == 0
        # tighter explicit tolerance flips a small drift into a failure
        small = _kernel_report(tmp_path, "small.json", p50=120.0, p95=144.0)
        assert validate_bench.main([small, "--baseline", baseline]) == 0
        assert validate_bench.main(
            [small, "--baseline", baseline, "--tolerance", "0.1"]
        ) == 1

    def test_gate_covers_planner_latency(self, tmp_path):
        base_rep = _placement_report(tmp_path, "pb.json")
        baseline = str(tmp_path / "BENCH_baseline.json")
        assert validate_bench.main(
            [base_rep, "--baseline", baseline, "--write-baseline"]
        ) == 0
        drift = _placement_report(tmp_path, "pb2.json", p50=0.05, p95=0.10)
        assert validate_bench.main([drift, "--baseline", baseline]) == 1

    def test_missing_baseline_skips_gate(self, tmp_path):
        rep = _kernel_report(tmp_path, "k2.json")
        assert validate_bench.main(
            [rep, "--baseline", str(tmp_path / "nope.json")]
        ) == 0


# ---------------------------------------------------------------------------
# end-to-end: calibrate.py artifact -> placement_bench --autoscale --calibrated
# ---------------------------------------------------------------------------
class TestCalibratedBenchEndToEnd:
    def test_autoscale_calibrated_runs_and_reports_deltas(
        self, tiny_artifact, tmp_path, monkeypatch
    ):
        from benchmarks import placement_bench

        out = tmp_path / "BENCH_autoscale.json"
        monkeypatch.setattr(sys, "argv", [
            "placement_bench", "--autoscale", "--gpus", "4",
            "--horizon", "20", "--rate-scale", "0.02",
            "--policies", "rule_based", "--commit", "always",
            "--controller", "slo", "--compact-every", "0",
            "--calibrated", str(tiny_artifact), "--json", str(out),
        ])
        placement_bench.main()
        assert validate_bench.validate(str(out)) == []
        rep = json.loads(out.read_text())
        rows = rep["autoscale"]
        assert "slo@r0.02@always" in rows
        assert "slo@r0.02@always@cal" in rows
        delta = rep["calibration_delta"]["slo@r0.02@always"]
        assert set(delta) >= {"slo_attainment", "time_avg_gpus_used"}
        assert all(math.isfinite(v) for v in delta.values())
        assert rep["calibration_source"] == str(tiny_artifact)
        assert isinstance(rep["host"]["contended"], bool)
