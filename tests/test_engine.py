"""PlacementEngine parity with the old call paths + transaction invariants.

The refactor promise: every policy produces *identical* placements through
``PlacementEngine`` as through the pre-engine call paths (direct module
functions), and the transactional state's apply/undo journal restores
byte-identical state, so clone-based trial search could be replaced without
behavior change.
"""
import pytest

from repro.core import baselines, heuristic
from repro.core.engine import PlacementEngine, available_policies, get_policy
from repro.core.simulator import generate_test_case
from repro.core.state import ClusterState, GPUState, Workload

SEEDS = (0, 3, 7, 11)


def _placements(state: ClusterState):
    return {
        (gid, p.wid, p.profile_id, p.index)
        for gid, g in state.gpus.items()
        for p in g.placements
    }


def _snapshot(state: ClusterState):
    """Byte-identical view: list order matters, plus occupancy + workloads."""
    return (
        {gid: list(g.placements) for gid, g in state.gpus.items()},
        {gid: g.memory_occupancy() for gid, g in state.gpus.items()},
        dict(state.workloads),
    )


# ---------------------------------------------------------------------------
# engine <-> old call path parity
# ---------------------------------------------------------------------------
class TestDeployParity:
    @pytest.mark.parametrize("seed", SEEDS)
    @pytest.mark.parametrize(
        "policy,old",
        [
            ("first_fit", baselines.first_fit),
            ("load_balanced", baselines.load_balanced),
            ("rule_based", heuristic.initial_deployment),
        ],
    )
    def test_in_place_policies(self, policy, old, seed):
        tc = generate_test_case(seed, n_gpus=8)
        a = tc.initial.clone()
        pending_a = old(a, tc.new_workloads)
        b = tc.initial.clone()
        res = PlacementEngine(policy).deploy(b, tc.new_workloads)
        assert _placements(a) == _placements(b)
        assert [w.wid for w in pending_a] == [w.wid for w in res.pending]

    @pytest.mark.parametrize("seed", (0, 3))
    def test_mip(self, seed):
        from repro.core.wpm_mip import solve_wpm

        tc = generate_test_case(seed, n_gpus=8)
        ref = solve_wpm(
            tc.initial.clone(), tc.new_workloads, movable=False,
            allow_reconfig=False,
        )
        st = tc.initial.clone()
        res = PlacementEngine("mip").deploy(st, tc.new_workloads)
        assert _placements(ref.state) == _placements(st)
        assert {w.wid for w in ref.pending} == {w.wid for w in res.pending}


class TestCompactionParity:
    @pytest.mark.parametrize("seed", SEEDS)
    def test_rule_based(self, seed):
        tc = generate_test_case(seed, n_gpus=8)
        a = tc.initial.clone()
        heuristic.compaction(a)
        b = tc.initial.clone()
        PlacementEngine("rule_based").compact(b)
        assert _placements(a) == _placements(b)

    @pytest.mark.parametrize("seed", SEEDS)
    @pytest.mark.parametrize("policy", ["first_fit", "load_balanced"])
    def test_baselines_match_clone_reference(self, policy, seed):
        """The txn-based baseline compaction == the seed's clone-based replay."""
        from repro.core.engine import _spot_first_fit, _spot_load_balanced

        spot = _spot_first_fit if policy == "first_fit" else _spot_load_balanced

        def reference(state):  # the seed implementation, clones and all
            progress = True
            while progress:
                progress = False
                used = sorted(
                    state.used_gpus(),
                    key=lambda g: (g.joint_slice_utilization(), g.gid),
                )
                for gpu in used:
                    others = [g.gid for g in state.used_gpus() if g.gid != gpu.gid]
                    trial = state.clone()
                    moves, ok = [], True
                    for pl in list(trial.gpus[gpu.gid].placements):
                        w = trial.workloads[pl.wid]
                        trial.gpus[gpu.gid].remove(pl.wid)
                        s = spot(trial, w, others)
                        if s is None:
                            ok = False
                            break
                        trial.place(w.wid, *s)
                        moves.append((w.wid, *s))
                    if ok:
                        for wid, dst, idx in moves:
                            prof = state.gpus[dst].device.profile(
                                state.workloads[wid].profile_id
                            )
                            if not state.gpus[dst].can_place_at(prof, idx):
                                ok = False
                                break
                    if ok:
                        for wid, dst, idx in moves:
                            state.gpus[gpu.gid].remove(wid)
                            state.place(wid, dst, idx)
                        progress = True
                        break

        tc = generate_test_case(seed, n_gpus=8)
        a = tc.initial.clone()
        reference(a)
        b = tc.initial.clone()
        PlacementEngine(policy).compact(b)
        assert _placements(a) == _placements(b)


class TestReconfigurationParity:
    @pytest.mark.parametrize("seed", (0, 5))
    def test_rule_based(self, seed):
        tc = generate_test_case(seed, n_gpus=8)
        a = tc.initial.clone()
        heuristic.reconfiguration(a)
        b = tc.initial.clone()
        PlacementEngine("rule_based").reconfigure(b)
        assert _placements(a) == _placements(b)

    def test_patterns(self):
        from repro.core.patterns import reconfigure_patterns

        tc = generate_test_case(1, n_gpus=8)
        ref = reconfigure_patterns(tc.initial.clone())
        st = tc.initial.clone()
        PlacementEngine("patterns").reconfigure(st)
        assert _placements(ref.state) == _placements(st)


class TestEngineSurface:
    def test_registry(self):
        assert set(available_policies()) == {
            "first_fit", "load_balanced", "rule_based", "frag_aware", "mip",
            "joint_mip", "patterns",
        }
        assert get_policy("heuristic").name == "rule_based"  # legacy alias
        with pytest.raises(ValueError):
            get_policy("nope")

    def test_unsupported_verb(self):
        st = ClusterState.homogeneous(2)
        with pytest.raises(ValueError, match="does not support"):
            PlacementEngine("patterns").compact(st)

    def test_mixed_fleet_requires_device_kind(self):
        from repro.core.profiles import A100_80GB
        from repro.core.tpu_profiles import TPU_V5E_POD

        st = ClusterState(
            gpus={
                "a0": GPUState("a0", A100_80GB),
                "t0": GPUState("t0", TPU_V5E_POD),
            }
        )
        with pytest.raises(ValueError, match="device_kind"):
            PlacementEngine("first_fit").deploy(st, [Workload("w", 19)])

    def test_mixed_fleet_routes_by_kind(self):
        from repro.core.profiles import A100_80GB
        from repro.core.tpu_profiles import TPU_V5E_POD

        st = ClusterState(
            gpus={
                "a0": GPUState("a0", A100_80GB),
                "t0": GPUState("t0", TPU_V5E_POD),
            }
        )
        ws = [
            Workload("wa", 9, device_kind="A100-80GB"),
            Workload("wt", 3, device_kind="TPUv5e-16x16-pod"),
        ]
        res = PlacementEngine("rule_based").deploy(st, ws)
        assert not res.pending
        assert st.gpu_of("wa") == "a0" and st.gpu_of("wt") == "t0"
        st.validate()


# ---------------------------------------------------------------------------
# transaction invariants
# ---------------------------------------------------------------------------
class TestTransactions:
    def _seed_state(self):
        st = ClusterState.homogeneous(3)
        for wid, pid, gid, idx in [
            ("a", 5, "gpu0", 0), ("b", 14, "gpu0", 4),
            ("c", 9, "gpu1", 4), ("d", 19, "gpu2", 6),
        ]:
            st.add_workload(Workload(wid, pid))
            st.place(wid, gid, idx)
        return st

    def test_rollback_restores_byte_identical_state(self):
        st = self._seed_state()
        before = _snapshot(st)
        with st.transaction() as txn:
            st.remove("b", "gpu0")
            st.remove("a", "gpu0")
            st.add_workload(Workload("e", 15))
            st.place("e", "gpu0", 6)
            st.place("a", "gpu1", 0)
            txn.rollback()
        assert _snapshot(st) == before
        st.validate()

    def test_remove_in_middle_restores_list_order(self):
        st = self._seed_state()
        # gpu0 has [a, b]; remove the first, roll back, order must hold.
        order_before = [p.wid for p in st.gpus["gpu0"].placements]
        with st.transaction() as txn:
            st.remove("a", "gpu0")
            txn.rollback()
        assert [p.wid for p in st.gpus["gpu0"].placements] == order_before

    def test_commit_keeps_mutations(self):
        st = self._seed_state()
        with st.transaction():
            st.remove("d", "gpu2")
            st.place("d", "gpu1", 0)
        assert st.gpu_of("d") == "gpu1"
        st.validate()

    def test_mutation_after_inner_rollback_journals_to_outer(self):
        """Ops after an inner rollback (inner still on the stack) must be
        undone by the outer rollback — journal to the nearest OPEN txn."""
        st = self._seed_state()
        before = _snapshot(st)
        with st.transaction() as outer:
            with st.transaction() as inner:
                st.remove("d", "gpu2")
                inner.rollback()
                st.remove("c", "gpu1")  # after rollback, before inner exits
            outer.rollback()
        assert _snapshot(st) == before

    def test_single_kind_fleet_rejects_mismatched_device_kind(self):
        st = ClusterState.homogeneous(2)
        bad = Workload("w", 2, device_kind="TPUv5e-16x16-pod")
        with pytest.raises(ValueError, match="targets"):
            PlacementEngine("first_fit").deploy(st, [bad])
        assert "w" not in st.workloads  # state untouched

    def test_nested_commit_then_outer_rollback(self):
        st = self._seed_state()
        before = _snapshot(st)
        with st.transaction() as outer:
            with st.transaction():
                st.remove("d", "gpu2")
                st.place("d", "gpu1", 0)
            assert st.gpu_of("d") == "gpu1"  # inner committed
            outer.rollback()
        assert _snapshot(st) == before

    def test_exception_rolls_back(self):
        st = self._seed_state()
        before = _snapshot(st)
        with pytest.raises(RuntimeError):
            with st.transaction():
                st.remove("c", "gpu1")
                raise RuntimeError("boom")
        assert _snapshot(st) == before

    def test_add_workload_overwrite_restored(self):
        st = self._seed_state()
        orig = st.workloads["a"]
        with st.transaction() as txn:
            st.add_workload(Workload("a", 19, model="other"))
            st.add_workload(Workload("z", 15))
            txn.rollback()
        assert st.workloads["a"] is orig
        assert "z" not in st.workloads

    def test_cache_survives_direct_list_mutation(self):
        """Backtracking callers edit .placements directly; caches must follow."""
        from repro.core.profiles import A100_80GB
        from repro.core.state import Placement

        g = GPUState("g0")
        g.place("a", 9, 4)
        assert g.free_gpu_slices() == [0, 1, 2, 3]
        g.placements.append(Placement("b", 14, 0))  # bypasses place()
        assert g.used_memory_slices() == 6
        assert g.free_gpu_slices() == [2, 3]
        g.placements.remove(Placement("b", 14, 0))
        assert g.free_gpu_slices() == [0, 1, 2, 3]
        assert g.used_memory_slices() == 4
        assert g.can_place_at(A100_80GB.profile(5), 0)  # 4g fits again at 0
