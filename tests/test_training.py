"""Training substrate: optimizer, grad accumulation, checkpointing, data."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.kernels import ops as kops
from repro.models import bundle, transformer
from repro.training import checkpoint as ckpt
from repro.training import data as data_lib
from repro.training import optimizer as opt
from repro.training.train_loop import TrainConfig, make_train_step


@pytest.fixture(autouse=True)
def _impl():
    kops.set_impl("ref")
    yield
    kops.set_impl("jnp")
    transformer.set_remat(None)


def _setup(moment_dtype="float32", microbatch=0, remat=False, steps=25):
    cfg = reduced(get_config("smollm-135m"), n_layers=2, d_model=64, vocab_size=128)
    mb = bundle(cfg)
    params = mb.init(jax.random.key(0))
    ocfg = opt.AdamWConfig(
        lr=3e-3, warmup_steps=5, decay_steps=200, moment_dtype=moment_dtype
    )
    state = opt.init(params, ocfg)
    tcfg = TrainConfig(microbatch=microbatch, remat=remat)
    step_fn = jax.jit(make_train_step(mb, ocfg, tcfg))
    dcfg = data_lib.DataConfig(vocab_size=128, seq_len=32, global_batch=8)
    return mb, params, state, step_fn, dcfg, steps


def _run(params, state, step_fn, dcfg, steps):
    losses = []
    for i in range(steps):
        batch = data_lib.get_batch(dcfg, i)
        params, state, m = step_fn(params, state, batch)
        losses.append(float(m["loss"]))
    return params, state, losses


def test_loss_decreases():
    mb, params, state, step_fn, dcfg, steps = _setup()
    _, _, losses = _run(params, state, step_fn, dcfg, steps)
    assert losses[-1] < losses[0] * 0.9
    assert all(np.isfinite(l) for l in losses)


def test_grad_accumulation_matches_full_batch():
    """microbatched grads == full-batch grads (same update trajectory)."""
    mb, params, state, _, dcfg, _ = _setup()
    ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=5, decay_steps=200)
    full = jax.jit(make_train_step(mb, ocfg, TrainConfig(microbatch=0)))
    micro = jax.jit(make_train_step(mb, ocfg, TrainConfig(microbatch=2)))
    batch = data_lib.get_batch(dcfg, 0)
    p1, s1, m1 = full(params, state, batch)
    p2, s2, m2 = micro(params, state, batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-5, rtol=1e-4
        )


def test_remat_matches_no_remat():
    mb, params, state, _, dcfg, _ = _setup()
    ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=5, decay_steps=200)
    batch = data_lib.get_batch(dcfg, 0)
    plain = jax.jit(make_train_step(mb, ocfg, TrainConfig(remat=False)))
    p1, _, _ = plain(params, state, batch)
    rematted = jax.jit(make_train_step(mb, ocfg, TrainConfig(remat=True)))
    p2, _, _ = rematted(params, state, batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-5, rtol=1e-4
        )


def test_int8_optimizer_still_learns():
    mb, params, state, step_fn, dcfg, steps = _setup(moment_dtype="int8", steps=30)
    ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=5, decay_steps=200, moment_dtype="int8")
    state = opt.init(params, ocfg)
    step_fn = jax.jit(make_train_step(mb, ocfg, TrainConfig()))
    _, _, losses = _run(params, state, step_fn, dcfg, 30)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.98  # quantized moments learn (slower)
    # int8 state is actually int8: after jit steps the structure is
    # {"q": int8, "scale": f32}
    flat, _ = jax.tree_util.tree_flatten_with_path(state["m"])
    assert any(np.asarray(l).dtype == np.int8 for _, l in flat)


def test_int8_roundtrip_accuracy():
    x = jax.random.normal(jax.random.key(0), (64, 256)) * 0.03
    enc = opt._encode_moment(x, "int8")
    dec = opt._decode_moment(enc, x.shape, "int8")
    err = float(jnp.max(jnp.abs(dec - x)))
    assert err < float(jnp.max(jnp.abs(x))) / 100  # <1% of range per row


def test_checkpoint_roundtrip_and_resume(tmp_path):
    mb, params, state, step_fn, dcfg, _ = _setup()
    mgr = ckpt.CheckpointManager(str(tmp_path), keep=2)
    params1, state1, losses1 = _run(params, state, step_fn, dcfg, 5)
    mgr.save(5, params1, state1)
    # continue 3 more steps -> reference trajectory
    ref_params, _, ref_losses = _run(params1, state1, step_fn, dcfg, 3)
    # "crash"; restore and resume — identical trajectory
    assert mgr.latest_step() == 5
    p2, s2 = mgr.restore(5, jax.eval_shape(lambda: params1), jax.eval_shape(lambda: state1))
    res_params, _, res_losses = _run(p2, s2, step_fn, dcfg, 3)
    np.testing.assert_allclose(ref_losses, res_losses, rtol=1e-6)
    for a, b in zip(jax.tree.leaves(ref_params), jax.tree.leaves(res_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_and_gc(tmp_path):
    mb, params, state, _, _, _ = _setup()
    mgr = ckpt.CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, params, state)
    assert mgr.all_steps() == [3, 4]  # old ones garbage-collected
    assert not any(n.startswith("tmp-") for n in os.listdir(tmp_path))


def test_checkpoint_async(tmp_path):
    mb, params, state, _, _, _ = _setup()
    mgr = ckpt.CheckpointManager(str(tmp_path))
    mgr.save(7, params, state, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 7


def test_data_deterministic_and_resumable():
    dcfg = data_lib.DataConfig(vocab_size=100, seq_len=16, global_batch=4)
    a = data_lib.get_batch(dcfg, 42)
    b = data_lib.get_batch(dcfg, 42)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = data_lib.get_batch(dcfg, 43)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert int(a["tokens"].max()) < 100


def test_lr_schedule():
    ocfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, decay_steps=100, min_lr_frac=0.1)
    assert float(opt.schedule(jnp.array(5), ocfg)) == pytest.approx(0.5, rel=0.01)
    assert float(opt.schedule(jnp.array(10), ocfg)) == pytest.approx(1.0, rel=0.01)
    assert float(opt.schedule(jnp.array(100), ocfg)) == pytest.approx(0.1, rel=0.01)
