"""Online event-driven simulator tests (core/events.py)."""
import pytest

from repro.core.engine import PlacementEngine
from repro.core.events import (
    Event,
    OnlineSimulator,
    Trace,
    build_fleet,
    generate_trace,
)
from repro.core.profiles import A100_80GB
from repro.core.state import ClusterState, Workload
from repro.core.tpu_profiles import TPU_V5E_POD


def _placed_wids(state: ClusterState):
    return {p.wid for g in state.gpus.values() for p in g.placements}


# ---------------------------------------------------------------------------
# deterministic hand-built trace: arrivals -> departures -> compaction
# ---------------------------------------------------------------------------
class TestDeterministicTrace:
    def _trace(self):
        burst = (
            Workload("w0", 5),   # 4g.40gb
            Workload("w1", 9),   # 3g.40gb
            Workload("w2", 14),  # 2g.20gb
            Workload("w3", 15),  # 1g.20gb
        )
        events = [
            Event(time=1.0, kind="arrival", workloads=burst),
            Event(time=2.0, kind="arrival", workloads=(Workload("w4", 19),)),
            Event(time=5.0, kind="departure", wids=("w0", "w2")),
            Event(time=6.0, kind="compact"),
        ]
        return Trace(events=events, horizon=10.0)

    def test_known_final_layout_and_no_stranded_placements(self):
        state = ClusterState.homogeneous(3)
        sim = OnlineSimulator(state, PlacementEngine("rule_based"))
        stats = sim.run(self._trace())
        state.validate()
        # After the two departures, {w1: 3g, w3: 1g.20gb, w4: 1g.10gb} remain
        # (4 + 2 + 1 memory slices); compaction packs them onto ONE GPU.
        assert len(state.used_gpus()) == 1
        assert _placed_wids(state) == {"w1", "w3", "w4"}
        # zero stranded placements: every registered workload is placed and
        # every placement belongs to a registered workload.
        assert _placed_wids(state) == set(state.workloads)
        assert stats.n_placed == 5 and stats.n_rejected == 0
        assert stats.n_departed == 2
        assert stats.n_compactions == 1
        assert stats.n_migrations == 2  # w3 + w4 moved onto w1's GPU
        # GPUs-used over time: 0 on [0,1), 2 on [1,6), 1 on [6,10).
        assert stats.time_avg_gpus_used == pytest.approx((2 * 5 + 1 * 4) / 10)
        assert stats.peak_gpus_used == 2

    def test_migration_budget_rolls_back_compaction(self):
        state = ClusterState.homogeneous(3)
        sim = OnlineSimulator(
            state, PlacementEngine("rule_based"), migration_budget=1
        )
        stats = sim.run(self._trace())
        state.validate()
        # Compaction needs 2 moves > budget 1 -> rolled back wholesale.
        assert stats.n_compactions == 0
        assert stats.n_compactions_skipped == 1
        assert stats.n_migrations == 0
        assert len(state.used_gpus()) == 2
        assert _placed_wids(state) == {"w1", "w3", "w4"}

    def test_time_averages_clamp_to_horizon(self):
        """Events past the horizon must not perturb time-averaged metrics:
        integration covers exactly [0, horizon], with the final partial
        interval counted once (regression: the last-event-to-horizon tail
        used to go negative when an event landed beyond the horizon)."""
        state = ClusterState.homogeneous(2)
        trace = Trace(
            events=[
                Event(time=2.0, kind="arrival", workloads=(Workload("a", 5),)),
                # departure beyond the horizon: state change, zero weight.
                Event(time=15.0, kind="departure", wids=("a",)),
            ],
            horizon=10.0,
        )
        stats = OnlineSimulator(state, PlacementEngine("rule_based")).run(trace)
        # 0 GPUs on [0,2), 1 on [2,10) -> 0.8; the t=15 departure still ran.
        assert stats.time_avg_gpus_used == pytest.approx(0.8)
        assert stats.time_avg_mem_occupancy == pytest.approx(0.8 * 4 / 16)
        assert stats.n_departed == 1
        assert state.used_gpus() == []

    def test_periodic_compaction_injection(self):
        state = ClusterState.homogeneous(3)
        trace = Trace(
            events=[
                Event(time=1.0, kind="arrival", workloads=(Workload("a", 15),)),
                Event(time=2.0, kind="arrival", workloads=(Workload("b", 15),)),
            ],
            horizon=20.0,
        )
        sim = OnlineSimulator(
            state, PlacementEngine("rule_based"), compact_every=5.0
        )
        stats = sim.run(trace)
        assert stats.n_compactions + stats.n_compactions_skipped == 3  # t=5,10,15


# ---------------------------------------------------------------------------
# generated traces over a mixed fleet
# ---------------------------------------------------------------------------
class TestGeneratedTraces:
    def _fleet(self):
        return build_fleet([(A100_80GB, 4), (TPU_V5E_POD, 2)])

    def test_build_fleet_repeated_entries_do_not_collide(self):
        fleet = build_fleet([(A100_80GB, 2), (A100_80GB, 3), (TPU_V5E_POD, 1)])
        assert len(fleet.gpus) == 6
        assert sorted(g for g in fleet.gpus if g.startswith("a100")) == [
            f"a100-{i}" for i in range(5)
        ]

    def test_trace_generation_is_deterministic(self):
        fleet = self._fleet()
        a = generate_trace(42, fleet, horizon=50.0)
        b = generate_trace(42, fleet, horizon=50.0)
        assert [(e.time, e.kind, e.workloads, e.wids) for e in a.events] == [
            (e.time, e.kind, e.workloads, e.wids) for e in b.events
        ]
        assert a.n_arrivals > 0

    def test_workloads_target_fleet_kinds(self):
        fleet = self._fleet()
        tr = generate_trace(7, fleet, horizon=50.0)
        kinds = {
            w.device_kind for e in tr.events for w in e.workloads
        }
        assert kinds <= {"A100-80GB", "TPUv5e-16x16-pod"}
        # Capacity-weighted routing should exercise both kinds on this horizon.
        assert len(kinds) == 2

    @pytest.mark.parametrize("policy", ["first_fit", "load_balanced", "rule_based"])
    def test_mixed_fleet_trace_completes(self, policy):
        fleet = self._fleet()
        trace = generate_trace(0, fleet, horizon=60.0, arrival_rate=0.8)
        sim = OnlineSimulator(
            fleet, PlacementEngine(policy), compact_every=15.0
        )
        stats = sim.run(trace)
        fleet.validate()
        assert stats.n_arrived == stats.n_placed + stats.n_rejected
        assert _placed_wids(fleet) == set(fleet.workloads)  # no strays
        assert 0.0 <= stats.time_avg_mem_occupancy <= 1.0
        assert stats.time_avg_gpus_used > 0.0
        assert stats.peak_gpus_used <= len(fleet.gpus)

    def test_departures_only_for_generated_arrivals(self):
        fleet = self._fleet()
        tr = generate_trace(3, fleet, horizon=40.0)
        arrived = {w.wid for e in tr.events for w in e.workloads}
        departing = {wid for e in tr.events for wid in e.wids}
        assert departing <= arrived
