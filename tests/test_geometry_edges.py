"""Device-geometry edge cases (paper Table 1, Sec 3.2).

Covers the corners the random pools deliberately skip: the H100 device
model, TPU pod-partition preference orders, m7 stranding semantics for
``1g.10gb`` at index 6, and the ``+me`` media-extension profile 20.
"""
from repro.core.fabric import FleetFabric
from repro.core.profiles import A100_80GB, H100_96GB
from repro.core.simulator import _DEFAULT_PROFILE_POOL
from repro.core.state import ClusterState, GPUState, Workload
from repro.core.tpu_profiles import TPU_V5E_POD, profile_for_chips


# ---------------------------------------------------------------------------
# H100_96GB: same slice geometry as A100, 12 GB memory slices
# ---------------------------------------------------------------------------
class TestH100:
    def test_profile_names_scale_with_memory(self):
        by_id = {p.profile_id: p.name for p in H100_96GB.profiles}
        assert by_id[0] == "7g.96gb"
        assert by_id[9] == "3g.48gb"
        assert by_id[19] == "1g.12gb"
        assert by_id[20] == "1g.12gb+me"
        assert H100_96GB.total_memory_gb == 96

    def test_preference_orders_match_table1(self):
        """H100 keeps the A100 Table-1 allowed-index preference orders."""
        for a, h in zip(A100_80GB.profiles, H100_96GB.profiles):
            assert a.profile_id == h.profile_id
            assert a.allowed_indexes == h.allowed_indexes
            assert a.compute_slices == h.compute_slices
            assert a.memory_slices == h.memory_slices

    def test_preferred_index_placement(self):
        gpu = GPUState("h", H100_96GB)
        # 3g.48gb prefers index 4 (captures m7), falls back to 0.
        assert gpu.first_feasible_index(H100_96GB.profile(9)) == 4
        gpu.place("a", 9, 4)
        assert gpu.first_feasible_index(H100_96GB.profile(9)) == 0
        gpu.place("b", 9, 0)
        assert gpu.memory_waste() == 0
        assert gpu.compute_waste() == 1  # the index-0 copy blocks 4 slices


# ---------------------------------------------------------------------------
# TPU pod partitions: aligned starts, descending preference
# ---------------------------------------------------------------------------
class TestTPUProfiles:
    def test_aligned_descending_preference(self):
        by_id = {p.profile_id: p for p in TPU_V5E_POD.profiles}
        assert by_id[1].allowed_indexes == (8, 0)
        assert by_id[2].allowed_indexes == (12, 8, 4, 0)
        assert by_id[3].allowed_indexes == (14, 12, 10, 8, 6, 4, 2, 0)
        assert by_id[4].allowed_indexes == tuple(range(15, -1, -1))

    def test_buddy_discipline_keeps_low_rows_contiguous(self):
        """Descending preference leaves room for a later full-pod block."""
        gpu = GPUState("t", TPU_V5E_POD)
        gpu.place("a", 3, gpu.first_feasible_index(TPU_V5E_POD.profile(3)))
        gpu.place("b", 2, gpu.first_feasible_index(TPU_V5E_POD.profile(2)))
        # 2-row at 14, 4-row at 8 -> rows 0..7 still contiguous for an 8-row.
        assert gpu.first_feasible_index(TPU_V5E_POD.profile(1)) == 0

    def test_unaligned_start_rejected(self):
        gpu = GPUState("t", TPU_V5E_POD)
        assert not gpu.can_place_at(TPU_V5E_POD.profile(2), 2)  # 4-row at 2
        assert gpu.can_place_at(TPU_V5E_POD.profile(2), 4)

    def test_no_extra_memory_no_media(self):
        assert TPU_V5E_POD.extra_memory is False
        assert TPU_V5E_POD.max_media_extensions == 0
        gpu = GPUState("t", TPU_V5E_POD)
        gpu.place("a", 0, 0)
        assert gpu.memory_waste() == 0

    def test_profile_for_chips_rounds_up(self):
        one_row = 256 * (1 << 30)
        assert profile_for_chips(one_row).profile_id == 4
        assert profile_for_chips(one_row + 1).profile_id == 3
        assert profile_for_chips(17 * one_row).profile_id == 0  # full pod


# ---------------------------------------------------------------------------
# m7 stranding (paper 3.2.3 / Table 3 note)
# ---------------------------------------------------------------------------
class TestM7Stranding:
    def test_1g10gb_at_index6_strands_m7(self):
        gpu = GPUState("g", A100_80GB)
        gpu.place("a", 19, 6)  # covers memory {6} only
        assert gpu.memory_waste() == 1
        # ... until something claims m7 via a 2-memory-slice profile? m7 is
        # only reachable through slice 6, which is taken -> permanently
        # stranded while this placement lives.
        assert gpu.can_place_at(A100_80GB.profile(19), 7) is False

    def test_1g20gb_at_index6_captures_m7(self):
        gpu = GPUState("g", A100_80GB)
        gpu.place("a", 15, 6)  # covers memory {6, 7}
        assert gpu.memory_waste() == 0
        assert gpu.used_memory_slices() == 2

    def test_fabric_scores_m7_stranding(self):
        """The fabric's waste_delta sees the stranding penalty at index 6."""
        state = ClusterState(gpus={"g": GPUState("g", A100_80GB)})
        fab = FleetFabric(state)
        waste, _ = fab.scores_profile(19)
        # profile 19 at 6: strands m7 -> waste 1; at 0..5 it wastes nothing.
        assert int(waste[0, 6]) == 1
        assert all(int(waste[0, i]) == 0 for i in range(6))


# ---------------------------------------------------------------------------
# the +me profile 20 (excluded from random pools; third packing dimension)
# ---------------------------------------------------------------------------
class TestMediaExtensionProfile:
    def test_excluded_from_random_pools(self):
        from repro.core.events import _ARRIVAL_POOLS

        assert 20 not in _DEFAULT_PROFILE_POOL
        for pool in _ARRIVAL_POOLS.values():
            assert 20 not in pool

    def test_one_me_per_gpu(self):
        gpu = GPUState("g", A100_80GB)
        prof20 = A100_80GB.profile(20)
        gpu.place("a", 20, 6)
        assert gpu.media_extensions_used() == 1
        # plenty of free slices, but the ME budget is exhausted
        assert gpu.first_feasible_index(prof20) is None
        # the plain 1g.10gb twin still fits everywhere free
        assert gpu.first_feasible_index(A100_80GB.profile(19)) == 4

    def test_fabric_honors_me_budget(self):
        state = ClusterState(gpus={"g": GPUState("g", A100_80GB)})
        state.add_workload(Workload(wid="a", profile_id=20))
        state.gpus["g"].place("a", 20, 6)
        fab = FleetFabric(state)
        assert not fab.feasible_profile(20).any()
        assert fab.feasible_profile(19).any()

    def test_deploy_me_workloads_spread_across_gpus(self):
        state = ClusterState(
            gpus={f"g{i}": GPUState(f"g{i}", A100_80GB) for i in range(3)}
        )
        from repro.core.engine import PlacementEngine

        news = [Workload(wid=f"me{i}", profile_id=20) for i in range(4)]
        res = PlacementEngine("rule_based").deploy(state, news)
        # one ME per GPU: 3 placed, 1 pending
        assert len(res.pending) == 1
        assert all(g.media_extensions_used() <= 1 for g in state.gpus.values())
