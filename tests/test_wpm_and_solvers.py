"""WPM MIP, pattern solver, B&B fallback, and migration-planner tests."""
import pytest

from repro.core import metrics
from repro.core.migration import plan_migration
from repro.core.patterns import pattern_catalog, reconfigure_patterns
from repro.core.profiles import A100_80GB
from repro.core.simulator import generate_test_case
from repro.core.state import ClusterState, Workload
from repro.core import wpm_mip
from repro.core.wpm_mip import solve_wpm


def _all_wl(tc):
    return list(tc.initial.workloads.values()) + tc.new_workloads


class TestWPM:
    def test_initial_deployment_places_when_capacity_exists(self):
        st = ClusterState.homogeneous(2)
        news = [Workload("a", 5), Workload("b", 9), Workload("c", 14), Workload("d", 15)]
        res = solve_wpm(st, news, movable=False, allow_reconfig=False)
        assert res.pending == []
        res.state.validate()
        m = metrics.evaluate(res.state, st, news)
        assert m.n_gpus <= 2
        assert m.compute_wastage == 0

    def test_respects_existing_partition_geometry(self):
        """New 4g workload cannot land on a GPU whose index-0 span is cut."""
        st = ClusterState.homogeneous(1)
        st.add_workload(Workload("e", 19))
        st.gpus["gpu0"].place("e", 19, 2)  # blocks memory position 2
        res = solve_wpm(st, [Workload("n", 5)], movable=False, allow_reconfig=False)
        assert [w.wid for w in res.pending] == ["n"]  # 4g fits only at idx 0

    @pytest.mark.slow
    def test_joint_mip_beats_or_matches_fixed_mip(self):
        for seed in (0, 1, 2):
            tc = generate_test_case(seed, n_gpus=8)
            fixed = solve_wpm(
                tc.initial.clone(), tc.new_workloads, movable=False, allow_reconfig=False
            )
            joint = solve_wpm(
                tc.initial.clone(), tc.new_workloads, movable=True, allow_reconfig=True
            )
            mf = metrics.evaluate(fixed.state, tc.initial, _all_wl(tc))
            mj = metrics.evaluate(joint.state, tc.initial, _all_wl(tc))
            assert mj.pending_model_size <= mf.pending_model_size

    def test_compaction_mode_reduces_gpus(self):
        st = ClusterState.homogeneous(3)
        for gid, wid, pid, idx in [
            ("gpu0", "a", 5, 0),
            ("gpu1", "b", 9, 4),
            ("gpu2", "c", 14, 4),
        ]:
            st.add_workload(Workload(wid, pid))
            st.gpus[gid].place(wid, pid, idx)
        res = solve_wpm(st.clone(), (), movable=True, allow_reconfig=True)
        m = metrics.evaluate(res.state, st, list(st.workloads.values()))
        assert m.n_gpus == 2
        assert m.n_pending == 0

    def test_migration_only_when_gpu_saved(self):
        """Penalty ordering: a lone full GPU must not shuffle workloads."""
        st = ClusterState.homogeneous(2)
        st.add_workload(Workload("a", 5))
        st.gpus["gpu0"].place("a", 5, 0)
        st.add_workload(Workload("b", 9))
        st.gpus["gpu0"].place("b", 9, 4)  # gpu0 fully packed, zero waste
        res = solve_wpm(st.clone(), (), movable=True, allow_reconfig=True)
        m = metrics.evaluate(res.state, st, list(st.workloads.values()))
        assert m.n_migrations == 0

    @pytest.mark.slow
    def test_all_existing_remain_placed(self):
        for seed in (3, 4):
            tc = generate_test_case(seed, n_gpus=8)
            res = solve_wpm(tc.initial.clone(), (), movable=True, allow_reconfig=True)
            placed = {
                p.wid for g in res.state.gpus.values() for p in g.placements
            }
            assert placed == set(tc.initial.workloads)


class TestPatternSolver:
    def test_catalog(self):
        cat = pattern_catalog(A100_80GB)
        assert len(cat) == 127
        # patterns carry index-accurate waste
        full = next(
            p for p in cat if p.counts == ((5, 1), (14, 1), (15, 1))
        )
        assert full.compute_waste == 0 and full.memory_waste == 0

    @pytest.mark.parametrize("seed", [0, 1, 5])
    def test_never_worse_than_heuristic(self, seed):
        from repro.core import heuristic

        tc = generate_test_case(seed, n_gpus=8)
        pat = reconfigure_patterns(tc.initial.clone())
        hs = tc.initial.clone()
        heuristic.reconfiguration(hs)
        mp = metrics.evaluate(pat.state, tc.initial)
        mh = metrics.evaluate(hs, tc.initial)
        obj_p = 100 * mp.n_gpus + 10 * (mp.compute_wastage + mp.memory_wastage)
        obj_h = 100 * mh.n_gpus + 10 * (mh.compute_wastage + mh.memory_wastage)
        assert obj_p <= obj_h

    def test_scales_independent_of_cluster_size(self):
        tc = generate_test_case(9, n_gpus=80)
        res = reconfigure_patterns(tc.initial.clone())
        assert res.status == "optimal"
        assert res.solve_seconds < 5.0


class TestBBFallback:
    def test_matches_scipy_on_small_instances(self, monkeypatch):
        for seed in (3, 11):
            tc = generate_test_case(seed, n_gpus=3)
            news = tc.new_workloads[:3]
            ref = solve_wpm(
                tc.initial.clone(), news, movable=False, allow_reconfig=False
            )
            monkeypatch.setattr(
                wpm_mip._Model,
                "_solve_scipy",
                lambda self, *a: (_ for _ in ()).throw(ImportError()),
            )
            got = solve_wpm(
                tc.initial.clone(),
                news,
                movable=False,
                allow_reconfig=False,
                time_limit=120,
            )
            monkeypatch.undo()
            assert abs(ref.objective - got.objective) < 1e-6
            assert got.status == "optimal"


class TestMigrationPlanner:
    def _replay(self, initial, plan):
        """Execute the plan wave by wave, asserting feasibility throughout."""
        st = initial.clone()
        # disruptive moves: drain first
        for mv in plan.disruptive:
            if mv.src_gid is not None:
                st.gpus[mv.src_gid].remove(mv.wid)
        for wave in plan.waves:
            # all moves in a wave must be simultaneously executable
            for mv in wave:
                if mv.src_gid is not None:
                    st.gpus[mv.src_gid].remove(mv.wid)
            for mv in wave:
                prof = st.gpus[mv.dst_gid].device.profile(mv.profile_id)
                assert st.gpus[mv.dst_gid].can_place_at(prof, mv.dst_index), mv
                st.gpus[mv.dst_gid].placements.append(
                    __import__("repro.core.state", fromlist=["Placement"]).Placement(
                        mv.wid, mv.profile_id, mv.dst_index
                    )
                )
        for mv in plan.disruptive:
            prof = st.gpus[mv.dst_gid].device.profile(mv.profile_id)
            assert st.gpus[mv.dst_gid].can_place_at(prof, mv.dst_index)
            st.place(mv.wid, mv.dst_gid, mv.dst_index)
        return st

    @pytest.mark.parametrize("seed", [0, 2, 8])
    def test_plan_replays_to_final_state(self, seed):
        tc = generate_test_case(seed, n_gpus=8)
        res = reconfigure_patterns(tc.initial.clone())
        plan = plan_migration(tc.initial, res.state)
        st = self._replay(tc.initial, plan)
        # same placement sets
        want = {
            (gid, p.wid, p.index)
            for gid, g in res.state.gpus.items()
            for p in g.placements
        }
        got = {
            (gid, p.wid, p.index)
            for gid, g in st.gpus.items()
            for p in g.placements
        }
        assert want == got

    def test_swap_needs_disruption(self):
        """Two full GPUs swapping contents cannot be done non-disruptively."""
        init = ClusterState.homogeneous(2)
        init.add_workload(Workload("a", 0))
        init.gpus["gpu0"].place("a", 0, 0)
        init.add_workload(Workload("b", 0))
        init.gpus["gpu1"].place("b", 0, 0)
        final = ClusterState.homogeneous(2)
        final.workloads = dict(init.workloads)
        final.gpus["gpu0"].place("b", 0, 0)
        final.gpus["gpu1"].place("a", 0, 0)
        plan = plan_migration(init, final)
        assert len(plan.disruptive) == 1
        self._replay(init, plan)
