"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, shape + finiteness assertions, and prefill/decode cache consistency."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, get_config, reduced
from repro.kernels import ops as kops
from repro.models import bundle

ARCH_NAMES = sorted(ARCHS)


@pytest.fixture(autouse=True)
def _ref_impl():
    kops.set_impl("ref")
    yield
    kops.set_impl("jnp")


def _batch(cfg, b=2, s=16, seed=0):
    key = jax.random.key(seed)
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size, jnp.int32)
    }
    if cfg.frontend == "vit":
        batch["patch_embeds"] = (
            jax.random.normal(key, (b, cfg.frontend_len, cfg.frontend_dim)) * 0.1
        )
    if cfg.enc_dec:
        batch["frames"] = (
            jax.random.normal(key, (b, cfg.frontend_len, cfg.frontend_dim)) * 0.1
        )
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_and_loss(name):
    cfg = reduced(get_config(name), capacity_factor=4.0)
    mb = bundle(cfg)
    params = mb.init(jax.random.key(1))
    batch = _batch(cfg)
    logits, _, _ = mb.model.forward(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = mb.loss_fn(params, batch)
    assert bool(jnp.isfinite(loss)) and float(loss) > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_grad_step(name):
    """One SGD step decreases the loss on a repeated tiny batch."""
    cfg = reduced(get_config(name), capacity_factor=4.0)
    mb = bundle(cfg)
    params = mb.init(jax.random.key(2))
    batch = _batch(cfg)

    def lf(p):
        return mb.loss_fn(p, batch)[0]

    l0, g = jax.value_and_grad(lf)(params)
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(g))
    )
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
    # descent-direction check: some step along -grad decreases the loss
    for step in (0.5, 0.1, 0.02):
        params2 = jax.tree.map(
            lambda p, gg: p - step / gnorm * gg.astype(p.dtype), params, g
        )
        if float(lf(params2)) < float(l0):
            break
    else:
        raise AssertionError(f"no descent for {name} at any step size")


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_consistency(name):
    """decode(t_{n}) after prefill(t_{0..n-1}) == full forward at position n."""
    cfg = reduced(get_config(name), capacity_factor=8.0)
    mb = bundle(cfg)
    params = mb.init(jax.random.key(3))
    b, s = 2, 12
    batch = _batch(cfg, b, s, seed=4)

    full_logits, _, _ = mb.model.forward(params, batch)

    pre = {k: (v[:, : s - 1] if k == "tokens" else v) for k, v in batch.items()}
    _, cache = mb.prefill_fn(params, pre, max_len=s + 2)
    step_logits, _ = mb.decode_fn(
        params, cache, batch["tokens"][:, s - 1 : s], jnp.array(s - 1, jnp.int32)
    )
    a = full_logits[:, -1]
    bb = step_logits[:, 0]
    # normalize: compare log-softmax (absolute logits can drift in f32 vs f64)
    la = jax.nn.log_softmax(a, -1)
    lb = jax.nn.log_softmax(bb, -1)
    assert bool(jnp.all(jnp.isfinite(lb)))
    diff = float(jnp.max(jnp.abs(la - lb)))
    assert diff < 2e-2, f"{name}: prefill/decode mismatch {diff}"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_multi_step_decode(name):
    cfg = reduced(get_config(name), capacity_factor=8.0)
    mb = bundle(cfg)
    params = mb.init(jax.random.key(5))
    b, s = 2, 8
    batch = _batch(cfg, b, s, seed=6)
    _, cache = mb.prefill_fn(params, batch, max_len=s + 4)
    tok = batch["tokens"][:, -1:]
    for i in range(3):
        logits, cache = mb.decode_fn(params, cache, tok, jnp.array(s + i, jnp.int32))
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


def test_param_counts_match_published():
    expected = {
        "mistral-large-123b": (123e9, 0.03),
        "nemotron-4-340b": (340e9, 0.03),
        "smollm-135m": (135e6, 0.05),
        "chatglm3-6b": (6.2e9, 0.10),
        "mixtral-8x7b": (46.7e9, 0.03),
        "deepseek-v3-671b": (671e9, 0.03),
        "pixtral-12b": (12.4e9, 0.05),
        "zamba2-1.2b": (1.2e9, 0.10),
    }
    for name, (want, tol) in expected.items():
        got = bundle(get_config(name)).param_count()
        assert abs(got - want) / want < tol, f"{name}: {got / 1e9:.2f}B vs {want / 1e9:.2f}B"


def test_active_params_moe():
    mx = bundle(get_config("mixtral-8x7b"))
    assert abs(mx.active_param_count() - 12.9e9) / 12.9e9 < 0.05
    ds = bundle(get_config("deepseek-v3-671b"))
    assert abs(ds.active_param_count() - 37e9) / 37e9 < 0.10


def test_long_decode_support_table():
    """DESIGN.md arch-applicability: exactly these 3 support long_500k."""
    support = {n: bundle(c).supports_shape(SHAPES["long_500k"]) for n, c in ARCHS.items()}
    assert support == {
        "mistral-large-123b": False,
        "nemotron-4-340b": False,
        "smollm-135m": False,
        "chatglm3-6b": False,
        "mixtral-8x7b": True,
        "deepseek-v3-671b": False,
        "pixtral-12b": False,
        "seamless-m4t-large-v2": False,
        "xlstm-125m": True,
        "zamba2-1.2b": True,
    }
