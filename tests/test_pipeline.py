"""GPipe pipeline-parallel tests (subprocess: needs >1 host device)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_with_devices(n, code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.parametrize("mesh_shape,n_dev", [("(2, 2, 2)", 8), ("(4, 2)", 8)])
def test_gpipe_matches_sequential(mesh_shape, n_dev):
    axes = "('pod', 'data', 'model')" if "2, 2, 2" in mesh_shape else "('pod', 'data')"
    out = _run_with_devices(n_dev, f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distribution.pipeline import gpipe
        mesh = jax.make_mesh({mesh_shape}, {axes})
        S = mesh.shape['pod']
        D, L, MB, NM = 16, 8, 4, 6
        w = jax.random.normal(jax.random.key(0), (L, D, D)) * 0.3
        stage_w = w.reshape(S, L // S, D, D)
        def stage_fn(pw, x):
            def body(h, wi):
                return jnp.tanh(h @ wi), None
            return jax.lax.scan(body, x, pw)[0]
        x = jax.random.normal(jax.random.key(1), (NM, MB, D))
        with mesh:
            y = jax.jit(lambda p, x: gpipe(stage_fn, p, x, mesh=mesh, n_micro=NM))(stage_w, x)
        h = x
        for i in range(L):
            h = jnp.tanh(h @ w[i])
        np.testing.assert_allclose(np.asarray(y), np.asarray(h), rtol=1e-5, atol=1e-5)
        print('OK')
    """)
    assert "OK" in out


def test_gpipe_single_stage_fallback():
    out = _run_with_devices(2, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.distribution.pipeline import gpipe
        mesh = jax.make_mesh((1, 2), ('pod', 'data'))
        D, MB, NM = 8, 4, 3
        w = jax.random.normal(jax.random.key(0), (1, 2, D, D)) * 0.3
        def stage_fn(pw, x):
            return jax.lax.scan(lambda h, wi: (jnp.tanh(h @ wi), None), x, pw)[0]
        x = jax.random.normal(jax.random.key(1), (NM, MB, D))
        with mesh:
            y = gpipe(stage_fn, w, x, mesh=mesh, n_micro=NM)
        h = x
        for i in range(2):
            h = jnp.tanh(h @ w[0, i])
        np.testing.assert_allclose(np.asarray(y), np.asarray(h), rtol=1e-5, atol=1e-5)
        print('OK')
    """)
    assert "OK" in out
