"""Per-kernel shape/dtype sweeps: pallas(interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.kernels import ops as kops


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,hq,hkv,d,bq,bk",
    [
        (1, 128, 4, 4, 64, 64, 64),  # MHA
        (2, 256, 8, 2, 64, 128, 64),  # GQA 4:1
        (1, 256, 6, 1, 32, 64, 128),  # MQA, uneven blocks
        (2, 128, 4, 2, 80, 128, 128),  # non-128 head dim (MLA-ish)
    ],
)
def test_flash_attention_sweep(dtype, b, s, hq, hkv, d, bq, bk):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d)).astype(dtype)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=bq, block_k=bk, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("window", [32, 100, 256])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.key(1), 3)
    b, s, hq, hkv, d = 2, 256, 4, 2, 64
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    got = flash_attention_pallas(
        q, k, v, causal=True, sliding_window=window, block_q=64, block_k=64, interpret=True
    )
    want = ref.attention_ref(q, k, v, causal=True, sliding_window=window)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.key(2), 3)
    b, s, h, d = 1, 128, 4, 64
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    got = flash_attention_pallas(q, k, v, causal=False, block_q=64, block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,smax,hq,hkv,d,length,bk",
    [
        (2, 256, 8, 2, 64, 137, 64),
        (1, 512, 4, 4, 64, 512, 128),  # full cache
        (3, 128, 4, 1, 32, 1, 64),  # single valid slot
        (2, 256, 16, 2, 64, 200, 256),  # big GQA group, one block
    ],
)
def test_decode_attention_sweep(dtype, b, smax, hq, hkv, d, length, bk):
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (b, 1, hq, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, smax, hkv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, smax, hkv, d)).astype(dtype)
    ln = jnp.array(length, jnp.int32)
    got = decode_attention_pallas(q, k, v, length=ln, block_k=bk, interpret=True)
    want = ref.decode_attention_ref(q, k, v, length=ln)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **_tol(dtype)
    )


@pytest.mark.parametrize(
    "b,smax,hq,hkv,d,length,bk",
    [
        (2, 256, 8, 2, 64, 137, 64),
        (1, 512, 4, 4, 64, 512, 128),
        (2, 256, 16, 2, 64, 200, 256),
    ],
)
def test_decode_attention_q8_sweep(b, smax, hq, hkv, d, length, bk):
    """int8-KV kernel == int8-KV oracle, and both track fp attention."""
    from repro.kernels.decode_attention import decode_attention_q8_pallas

    ks = jax.random.split(jax.random.key(8), 3)
    q = jax.random.normal(ks[0], (b, 1, hq, d))
    k = jax.random.normal(ks[1], (b, smax, hkv, d))
    v = jax.random.normal(ks[2], (b, smax, hkv, d))
    kq, ksc = ref.quantize_kv(k)
    vq, vsc = ref.quantize_kv(v)
    ln = jnp.array(length, jnp.int32)
    got = decode_attention_q8_pallas(q, kq, ksc, vq, vsc, length=ln,
                                     block_k=bk, interpret=True)
    want = ref.decode_attention_q8_ref(q, kq, ksc, vq, vsc, length=ln)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
    # quantization error vs full-precision attention stays small
    fp = ref.decode_attention_ref(q, k, v, length=ln)
    err = float(jnp.max(jnp.abs(got - fp)))
    assert err < 0.05, f"int8 KV error too large: {err}"


def test_decode_attention_q8_ragged():
    from repro.kernels.decode_attention import decode_attention_q8_pallas

    ks = jax.random.split(jax.random.key(9), 3)
    b, smax, hq, hkv, d = 3, 256, 8, 2, 64
    q = jax.random.normal(ks[0], (b, 1, hq, d))
    k = jax.random.normal(ks[1], (b, smax, hkv, d))
    v = jax.random.normal(ks[2], (b, smax, hkv, d))
    kq, ksc = ref.quantize_kv(k)
    vq, vsc = ref.quantize_kv(v)
    lens = jnp.asarray([7, 256, 100], jnp.int32)
    got = decode_attention_q8_pallas(q, kq, ksc, vq, vsc, length=lens,
                                     block_k=64, interpret=True)
    want = ref.decode_attention_q8_ref(q, kq, ksc, vq, vsc, length=lens)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_decode_attention_ragged_pallas():
    """fp ragged decode: per-slot lengths, pallas vs oracle."""
    ks = jax.random.split(jax.random.key(10), 3)
    b, smax, hq, hkv, d = 4, 256, 8, 2, 64
    q = jax.random.normal(ks[0], (b, 1, hq, d))
    k = jax.random.normal(ks[1], (b, smax, hkv, d))
    v = jax.random.normal(ks[2], (b, smax, hkv, d))
    lens = jnp.asarray([1, 64, 137, 256], jnp.int32)
    got = decode_attention_pallas(q, k, v, length=lens, block_k=64, interpret=True)
    want = ref.decode_attention_ref(q, k, v, length=lens)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,p,n,chunk",
    [
        (1, 128, 2, 16, 8, 32),
        (2, 256, 4, 32, 16, 64),
        (1, 64, 8, 8, 64, 64),  # single chunk
    ],
)
def test_ssd_scan_sweep(dtype, b, s, h, p, n, chunk):
    ks = jax.random.split(jax.random.key(4), 5)
    x = (jax.random.normal(ks[0], (b, s, h, p)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = (jax.random.normal(ks[3], (b, s, n)) * 0.5).astype(dtype)
    Cm = (jax.random.normal(ks[4], (b, s, n)) * 0.5).astype(dtype)
    y1, h1 = ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y2, h2 = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(atol=5e-5, rtol=5e-4)
    np.testing.assert_allclose(y1.astype(jnp.float32), y2.astype(jnp.float32), **tol)
    np.testing.assert_allclose(h1, h2, **tol)


def test_ssd_scan_initial_state_chain():
    """Running two halves with carried state == running the whole sequence."""
    ks = jax.random.split(jax.random.key(5), 5)
    b, s, h, p, n = 1, 128, 2, 8, 8
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, s, n)) * 0.5
    Cm = jax.random.normal(ks[4], (b, s, n)) * 0.5
    y_full, h_full = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    half = s // 2
    y1, h1 = ssd_scan_pallas(
        x[:, :half], dt[:, :half], A, Bm[:, :half], Cm[:, :half], chunk=32, interpret=True
    )
    y2, h2 = ssd_scan_pallas(
        x[:, half:], dt[:, half:], A, Bm[:, half:], Cm[:, half:],
        chunk=32, initial_state=h1, interpret=True,
    )
    np.testing.assert_allclose(
        jnp.concatenate([y1, y2], 1), y_full, atol=5e-5, rtol=5e-4
    )
    np.testing.assert_allclose(h2, h_full, atol=5e-5, rtol=5e-4)


# ---- ops.py dispatch layer (jnp fast paths vs oracle) -----------------------
def test_chunked_attention_matches_ref():
    ks = jax.random.split(jax.random.key(6), 3)
    b, s, hq, hkv, d = 2, 1024, 4, 2, 32
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    kops.set_impl("jnp")
    got = kops.flash_attention(q, k, v, causal=True, q_chunk=256)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_chunked_ssd_matches_ref():
    ks = jax.random.split(jax.random.key(7), 5)
    b, s, h, p, n = 1, 512, 2, 8, 8
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, s, n)) * 0.5
    Cm = jax.random.normal(ks[4], (b, s, n)) * 0.5
    kops.set_impl("jnp")
    y1, h1 = kops.ssd_scan(x, dt, A, Bm, Cm, chunk=128)
    y2, h2 = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y1, y2, atol=5e-5, rtol=5e-4)
    np.testing.assert_allclose(h1, h2, atol=5e-5, rtol=5e-4)


def test_pallas_impl_through_ops():
    """ops dispatch honors set_impl('pallas', interpret=True)."""
    ks = jax.random.split(jax.random.key(8), 3)
    b, s, hq, hkv, d = 1, 128, 4, 2, 64
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    try:
        kops.set_impl("pallas", interpret=True)
        got = kops.flash_attention(q, k, v, causal=True)
        want = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
    finally:
        kops.set_impl("jnp")
