"""System tests: serving engine, KV-cache surgery, placement-integrated
cluster.  Models are reduced configs executing REAL forward passes on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.tpu_profiles import TPU_V5E_POD
from repro.models import bundle
from repro.serving import Engine, EngineConfig, Request
from repro.serving.cluster import ClusterServer, replica_profile
from repro.serving.kvcache import BlockAllocator, PagedKVCache, paged_decode_attention
from repro.kernels import ref as kref


def _mk(name, **over):
    cfg = reduced(get_config(name), capacity_factor=8.0, **over)
    mb = bundle(cfg)
    params = mb.init(jax.random.key(0))
    return mb, params


def _naive_generate(mb, params, prompt, n_new, extras=None):
    """Oracle: full forward over the growing sequence, greedy argmax."""
    toks = list(prompt)
    out = []
    for _ in range(n_new):
        batch = {"tokens": jnp.asarray([toks], jnp.int32), **(extras or {})}
        logits, _, _ = mb.model.forward(params, batch)
        nxt = int(jnp.argmax(logits[0, -1]))
        toks.append(nxt)
        out.append(nxt)
    return out


# ---------------------------------------------------------------------------
# engine == naive generation, across architecture families
# ---------------------------------------------------------------------------
ENGINE_ARCHS = [
    "smollm-135m",      # dense GQA
    "mixtral-8x7b",     # MoE + sliding-window ring cache
    "deepseek-v3-671b", # MLA latent cache
    "xlstm-125m",       # pure recurrent
    "zamba2-1.2b",      # hybrid mamba2 + shared attention
]


@pytest.mark.parametrize("name", ENGINE_ARCHS)
def test_engine_matches_naive_generation(name):
    mb, params = _mk(name)
    eng = Engine(mb, params, EngineConfig(max_slots=3, max_len=64))
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(1, 255, size=n))) for n in (5, 3, 7, 4)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=f"r{i}", prompt=p, max_new_tokens=5))
    done = {c.rid: c for c in eng.run()}
    assert len(done) == len(prompts)
    for i, p in enumerate(prompts):
        want = _naive_generate(mb, params, p, 5)
        got = done[f"r{i}"].tokens
        assert got == want, f"{name} r{i}: {got} != {want}"


def test_engine_vlm_extras():
    """Pixtral: prefill with patch embeddings routed through extras."""
    mb, params = _mk("pixtral-12b")
    cfg = mb.cfg
    pe = jax.random.normal(
        jax.random.key(1), (1, cfg.frontend_len, cfg.frontend_dim), jnp.bfloat16
    )
    prompt = list(range(1, 9))
    eng = Engine(mb, params, EngineConfig(max_slots=2, max_len=64,
                                          bucket_prefill=False))
    eng.submit(Request(rid="v0", prompt=prompt, max_new_tokens=4,
                       extras={"patch_embeds": pe}))
    done = eng.run()
    want = _naive_generate(mb, params, prompt, 4, extras={"patch_embeds": pe})
    assert done[0].tokens == want


def test_engine_slot_reuse_and_stats():
    mb, params = _mk("smollm-135m")
    eng = Engine(mb, params, EngineConfig(max_slots=2, max_len=32))
    for i in range(5):
        eng.submit(Request(rid=f"q{i}", prompt=[1 + i, 2, 3], max_new_tokens=3))
    done = eng.run()
    assert len(done) == 5
    assert eng.stats["prefills"] == 5
    assert eng.n_active == 0 and not eng.queue
    # 5 requests through 2 slots => slots were recycled
    assert eng.stats["tokens"] == sum(len(c.tokens) for c in done)


def test_engine_eos_stops_early():
    mb, params = _mk("smollm-135m")
    # discover what token the model greedily emits, then use it as EOS
    probe = _naive_generate(mb, params, [5, 6, 7], 1)[0]
    eng = Engine(mb, params, EngineConfig(max_slots=1, max_len=32))
    eng.submit(Request(rid="e", prompt=[5, 6, 7], max_new_tokens=8, eos_id=probe))
    done = eng.run()
    assert done[0].finish_reason == "eos"
    assert done[0].tokens[-1] == probe and len(done[0].tokens) < 8


def test_ragged_equals_uniform_when_lengths_equal():
    """All slots at the same position: ragged decode == uniform decode_fn."""
    mb, params = _mk("smollm-135m")
    B, P = 3, 6
    toks = jax.random.randint(jax.random.key(2), (B, P), 1, 255)
    # uniform path
    logits_u, cache_u = mb.prefill_fn(params, {"tokens": toks}, max_len=32)
    nxt_u = jnp.argmax(logits_u[:, -1], -1)
    logits2_u, _ = mb.decode_fn(params, cache_u, nxt_u[:, None], jnp.int32(P))
    # ragged path
    from repro.serving.kvcache import insert_prefix

    cache_r = mb.model.init_cache(B, 32, ragged=True)
    for b in range(B):
        _, pref = mb.prefill_fn(params, {"tokens": toks[b:b + 1]}, max_len=32)
        cache_r = insert_prefix(cache_r, pref, jnp.int32(b), jnp.int32(P))
    lengths = jnp.full((B,), P, jnp.int32)
    logits2_r, _, _ = mb.model.forward(
        params, {"tokens": nxt_u[:, None]}, cache=cache_r,
        positions=lengths[:, None],
    )
    np.testing.assert_allclose(
        np.asarray(logits2_r, np.float32), np.asarray(logits2_u, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_engine_int8_kv_cache():
    """int8-KV serving: generation matches fp within greedy-token agreement
    on a tiny model (quantization noise can flip rare near-ties, so compare
    the first decode step's logits instead of demanding token equality)."""
    from repro.models import layers as L

    mb, params = _mk("smollm-135m")
    prompt = [3, 1, 4, 1, 5]
    # fp reference step
    logits_fp, cache_fp = mb.prefill_fn(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)}, max_len=32
    )
    nxt = jnp.argmax(logits_fp[0, -1])[None, None]
    step_fp, _ = mb.decode_fn(params, cache_fp, nxt, jnp.int32(len(prompt)))
    # int8-KV step
    L.set_kv_quant(True)
    try:
        logits_q8, cache_q8 = mb.prefill_fn(
            params, {"tokens": jnp.asarray([prompt], jnp.int32)}, max_len=32
        )
        assert cache_q8["groups"][0]["attn"]["k"].dtype == jnp.int8
        step_q8, _ = mb.decode_fn(params, cache_q8, nxt, jnp.int32(len(prompt)))
    finally:
        L.set_kv_quant(False)
    np.testing.assert_allclose(
        np.asarray(step_q8, np.float32), np.asarray(step_fp, np.float32),
        atol=0.15, rtol=0.15,
    )
    # and the full engine path still completes with a quantized cache
    L.set_kv_quant(True)
    try:
        eng = Engine(mb, params, EngineConfig(max_slots=2, max_len=32))
        eng.submit(Request(rid="q", prompt=prompt, max_new_tokens=4))
        done = eng.run()
    finally:
        L.set_kv_quant(False)
    assert len(done) == 1 and len(done[0].tokens) == 4


# ---------------------------------------------------------------------------
# paged KV cache
# ---------------------------------------------------------------------------
def test_block_allocator_roundtrip():
    a = BlockAllocator(8)
    t0 = a.allocate(0, 3)
    t1 = a.allocate(1, 2)
    assert len(set(t0) | set(t1)) == 5 and a.n_free == 3
    a.free(0)
    assert a.n_free == 6
    t2 = a.allocate(2, 6)
    assert len(set(t2) | set(t1)) == 8 and a.n_free == 0
    with pytest.raises(MemoryError):
        a.allocate(3, 1)


def test_paged_decode_matches_contiguous():
    """Paged gather + ragged mask == contiguous decode attention oracle."""
    key = jax.random.key(3)
    B, H, HKV, D, BS, NB = 2, 4, 2, 16, 4, 8  # pool: 8 blocks of 4 tokens
    max_blocks = 4
    cache = PagedKVCache.create(NB, BS, HKV, D, jnp.float32)
    alloc = BlockAllocator(NB)
    lengths = [13, 7]
    kv = {}
    for b, L in enumerate(lengths):
        n_blocks = -(-L // BS)
        alloc.allocate(b, n_blocks)
        ks = jax.random.normal(jax.random.fold_in(key, b), (L, HKV, D))
        vs = jax.random.normal(jax.random.fold_in(key, 10 + b), (L, HKV, D))
        kv[b] = (ks, vs)
        for t in range(L):
            blk = alloc.table(b)[t // BS]
            cache = cache.append(jnp.int32(blk), jnp.int32(t % BS), ks[t], vs[t])
    tables = np.zeros((B, max_blocks), np.int32)
    for b in range(B):
        tb = alloc.table(b)
        tables[b, : len(tb)] = tb
    q = jax.random.normal(jax.random.fold_in(key, 99), (B, 1, H, D))
    got = paged_decode_attention(
        q, cache, jnp.asarray(tables), jnp.asarray(lengths, jnp.int32)
    )
    # contiguous oracle, one sequence at a time
    for b, L in enumerate(lengths):
        ks, vs = kv[b]
        want = kref.decode_attention_ref(q[b:b + 1], ks[None], vs[None], length=L)
        np.testing.assert_allclose(
            np.asarray(got[b:b + 1]), np.asarray(want), rtol=1e-5, atol=1e-5
        )


def test_ragged_decode_attention_vector_length():
    """(B,) lengths mask each row independently (ref oracle property)."""
    key = jax.random.key(4)
    B, S, H, D = 3, 16, 2, 8
    q = jax.random.normal(key, (B, 1, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, D))
    lens = jnp.asarray([4, 16, 9], jnp.int32)
    got = kref.decode_attention_ref(q, k, v, length=lens)
    for b in range(B):
        want = kref.decode_attention_ref(
            q[b:b + 1], k[b:b + 1], v[b:b + 1], length=int(lens[b])
        )
        np.testing.assert_allclose(np.asarray(got[b:b + 1]), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# placement-integrated cluster
# ---------------------------------------------------------------------------
def test_replica_profile_scales_with_arch():
    small = replica_profile("smollm-135m", max_batch=4, max_len=2048)
    big = replica_profile("deepseek-v3-671b", max_batch=4, max_len=2048)
    assert small.memory_slices < big.memory_slices
    assert big.memory_slices * TPU_V5E_POD.mem_per_slice_gb >= 1340  # > 671B bf16


@pytest.mark.parametrize("policy", ["heuristic", "mip", "first_fit", "load_balanced"])
def test_cluster_deploy_policies(policy):
    srv = ClusterServer(n_nodes=4, policy=policy)
    rep = srv.deploy("chat", "smollm-135m", n_replicas=6, max_batch=4, max_len=2048)
    assert len(rep.placed) == 6 and not rep.pending
    srv.state.validate()
    assert srv.metrics().n_gpus >= 1


def test_cluster_compaction_saves_nodes():
    srv = ClusterServer(n_nodes=6, policy="heuristic")
    # fragment the cluster: deploy then retire interleaved replicas
    srv.deploy("a", "smollm-135m", 8, profile_id=3)   # 2-row blocks
    srv.deploy("b", "smollm-135m", 4, profile_id=4)   # 1-row blocks
    srv.retire("a", 6)
    frag = srv.metrics()
    report = srv.compact()
    srv.state.validate()
    assert report.after.n_gpus <= frag.n_gpus
    assert report.plan.n_moves >= 0  # plan is executable
    # every surviving replica still placed exactly once
    for wid in srv.replicas:
        assert srv.state.gpu_of(wid) is not None


def test_cluster_reconfigure_eviction_retires_ghosts():
    """A committed reconfigure that cannot re-place a replica must retire it
    from every server-side map (no ghost in routing/engines/footprints)."""
    from repro.core.state import Workload

    srv = ClusterServer(n_nodes=4, policy="heuristic")
    srv.deploy("m", "smollm-135m", 3, profile_id=4)
    victim = sorted(srv.replicas)[0]
    srv.attach_engine(victim, object())

    real = srv.engine.reconfigure

    def evicting(state):
        res = real(state)
        gid = state.gpu_of(victim)
        state.gpus[gid].remove(victim)  # the replay "failed" to re-place it
        res.pending = [Workload(victim, 4, model="m")]
        return res

    srv.engine.reconfigure = evicting
    rep = srv.reconfigure()
    assert rep.evicted == [victim]
    assert victim not in srv.replicas
    assert victim not in srv.engines
    assert victim not in srv.state.workloads
    assert victim not in srv.replicas_of("m")
    srv.state.validate()


def test_cluster_reconfigure_and_route():
    srv = ClusterServer(n_nodes=8, policy="heuristic")
    srv.deploy("m", "smollm-135m", 5, profile_id=4)
    rep = srv.reconfigure()
    assert rep.after.n_gpus <= rep.before.n_gpus
    picks = [srv.route("m") for _ in range(10)]
    assert len(set(picks)) == len(srv.replicas_of("m"))  # round robin covers all


def test_cluster_end_to_end_serving():
    """Deploy 2 models, attach real engines, route + pump to completion."""
    srv = ClusterServer(n_nodes=2, policy="heuristic")
    mb1, p1 = _mk("smollm-135m")
    mb2, p2 = _mk("xlstm-125m")
    srv.deploy("chat", "smollm-135m", 2, profile_id=4)
    srv.deploy("draft", "xlstm-125m", 1, profile_id=4)
    for wid in srv.replicas_of("chat"):
        srv.attach_engine(wid, Engine(mb1, p1, EngineConfig(max_slots=2, max_len=32)))
    for wid in srv.replicas_of("draft"):
        srv.attach_engine(wid, Engine(mb2, p2, EngineConfig(max_slots=2, max_len=32)))
    for i in range(4):
        srv.submit("chat", Request(rid=f"c{i}", prompt=[1, 2, 3 + i], max_new_tokens=3))
    srv.submit("draft", Request(rid="d0", prompt=[9, 8], max_new_tokens=3))
    total = srv.pump()
    done = [c for e in srv.engines.values() for c in e.completed]
    assert len(done) == 5
    assert total == sum(len(c.tokens) for c in done)
    # placement metrics still coherent after serving
    srv.state.validate()
