"""Vectorized placement fabric: parity with the scalar reference.

The fabric's contract (core/fabric.py docstring) is *placement identity*:
its batched feasibility kernel must agree with ``GPUState.can_place_at`` on
every (gpu, profile, index) triple, and its policy fast paths must pick the
same (gid, index) spots as the scalar policies — tie-breaks included — on
randomized heterogeneous fleets.  Scoring is checked against scalar
recomputation of wastage/fragmentation.
"""
import numpy as np
import pytest

from repro.core import baselines, heuristic
from repro.core.engine import PlacementEngine
from repro.core.fabric import (
    FleetFabric,
    fabric_first_fit,
    fabric_frag_aware_compact,
    fabric_frag_aware_deploy,
    fabric_frag_aware_reconfigure,
    fabric_initial_deployment,
    fabric_load_balanced,
)
from repro.core.profiles import A100_80GB, H100_96GB
from repro.core.simulator import generate_test_case, random_workloads
from repro.core.state import ClusterState, GPUState, Workload
from repro.core.tpu_profiles import TPU_V5E_POD

SEEDS = (0, 1, 2, 3, 7)
KERNELS = (False, True)  # use_jax


def _random_hetero_state(seed: int) -> ClusterState:
    """A randomly-populated mixed A100 + H100 + TPU fleet."""
    rng = np.random.default_rng(seed)
    state = ClusterState()
    specs = [(A100_80GB, 5), (H100_96GB, 3), (TPU_V5E_POD, 2)]
    wi = 0
    for device, count in specs:
        for i in range(count):
            gid = f"{device.name.split('-')[0].lower()}-{i}"
            gpu = GPUState(gid, device)
            state.gpus[gid] = gpu
            pool = [p.profile_id for p in device.profiles]
            for _ in range(int(rng.integers(0, 5))):
                pid = int(rng.choice(pool))
                idx = gpu.first_feasible_index(device.profile(pid))
                if idx is None:
                    continue
                w = Workload(wid=f"w{wi}", profile_id=pid, device_kind=device.name)
                state.add_workload(w)
                gpu.place(w.wid, pid, idx)
                wi += 1
    return state


def _placements(state: ClusterState):
    return {
        (gid, p.wid, p.profile_id, p.index)
        for gid, g in state.gpus.items()
        for p in g.placements
    }


# ---------------------------------------------------------------------------
# kernel parity: feasibility over ALL triples == scalar can_place_at
# ---------------------------------------------------------------------------
class TestFeasibilityParity:
    @pytest.mark.parametrize("use_jax", KERNELS)
    @pytest.mark.parametrize("seed", SEEDS)
    def test_all_triples_heterogeneous(self, seed, use_jax):
        state = _random_hetero_state(seed)
        fab = FleetFabric(state, use_jax=use_jax)
        feas = fab.feasible_all()
        for r, gid in enumerate(fab.gids):
            gpu = state.gpus[gid]
            for p, prof in enumerate(gpu.device.profiles):
                for i in range(fab.M):
                    assert bool(feas[r, p, i]) == gpu.can_place_at(prof, i), (
                        gid, prof.name, i,
                    )
            # slots past this device's profile count are never feasible
            for p in range(len(gpu.device.profiles), fab.P_max):
                assert not feas[r, p].any()

    def test_jax_and_numpy_kernels_agree(self):
        state = _random_hetero_state(11)
        a = FleetFabric(state, use_jax=False).feasible_all()
        b = FleetFabric(state, use_jax=True).feasible_all()
        np.testing.assert_array_equal(a, b)

    @pytest.mark.parametrize("use_jax", KERNELS)
    def test_incremental_row_refresh(self, use_jax):
        """apply/unapply keep the cached all-triple slab exact."""
        tc = generate_test_case(5, n_gpus=6)
        state = tc.initial
        fab = FleetFabric(state, use_jax=use_jax)
        fab.feasible_all()  # populate the cache
        prof = A100_80GB.profile(14)
        spot = fab.pick_first_fit(14)
        assert spot is not None
        gid, idx = spot
        state.add_workload(Workload(wid="zz", profile_id=14))
        state.place("zz", gid, idx)
        fab.apply(gid, prof, idx)
        np.testing.assert_array_equal(
            fab.feasible_all(), FleetFabric(state, use_jax=use_jax).feasible_all()
        )
        state.remove("zz", gid)
        fab.unapply(gid, prof, idx)
        np.testing.assert_array_equal(
            fab.feasible_all(), FleetFabric(state, use_jax=use_jax).feasible_all()
        )


# ---------------------------------------------------------------------------
# score parity: wastage / fragmentation vs scalar recomputation
# ---------------------------------------------------------------------------
class TestScoreParity:
    @pytest.mark.parametrize("use_jax", KERNELS)
    @pytest.mark.parametrize("seed", SEEDS[:3])
    def test_waste_and_frag_vs_scalar(self, seed, use_jax):
        state = _random_hetero_state(seed)
        fab = FleetFabric(state, use_jax=use_jax)
        for gid in fab.gids:
            gpu = state.gpus[gid]
            r = fab.row_of[gid]
            for prof in gpu.device.profiles:
                feas = fab.feasible_profile(prof.profile_id, gpu.device.name)
                waste, frag = fab.scores_profile(prof.profile_id, gpu.device.name)
                for i in range(gpu.device.n_memory_slices):
                    if not feas[r, i]:
                        continue
                    trial = gpu.clone()
                    before_mw = trial.memory_waste()
                    trial.place("_t", prof.profile_id, i)
                    want_waste = (
                        prof.compute_waste_at(i, gpu.device.n_gpu_slices)
                        + trial.memory_waste() - before_mw
                    )
                    occ = trial.memory_occupancy()
                    runs = 0
                    prev_free = False
                    for pos in range(gpu.device.n_memory_slices):
                        free = occ[pos] is None
                        if free and not prev_free:
                            runs += 1
                        prev_free = free
                    assert int(waste[r, i]) == want_waste, (gid, prof.name, i)
                    assert int(frag[r, i]) == runs, (gid, prof.name, i)


# ---------------------------------------------------------------------------
# fast-path placement identity vs the scalar policies
# ---------------------------------------------------------------------------
class TestDeployParity:
    @pytest.mark.parametrize("seed", SEEDS)
    @pytest.mark.parametrize(
        "scalar_fn,fabric_fn",
        [
            (baselines.first_fit, fabric_first_fit),
            (baselines.load_balanced, fabric_load_balanced),
            (heuristic.initial_deployment, fabric_initial_deployment),
        ],
        ids=["first_fit", "load_balanced", "rule_based"],
    )
    def test_identical_placements(self, scalar_fn, fabric_fn, seed):
        tc = generate_test_case(seed, n_gpus=10)
        s1, s2 = tc.initial.clone(), tc.initial.clone()
        p1 = scalar_fn(s1, tc.new_workloads)
        p2 = fabric_fn(s2, tc.new_workloads)
        assert _placements(s1) == _placements(s2)
        assert [w.wid for w in p1] == [w.wid for w in p2]

    @pytest.mark.parametrize("policy", ["first_fit", "load_balanced", "rule_based"])
    @pytest.mark.parametrize("seed", SEEDS[:3])
    def test_engine_fabric_on_off_parity(self, policy, seed):
        tc = generate_test_case(seed, n_gpus=12)
        s_off, s_on = tc.initial.clone(), tc.initial.clone()
        PlacementEngine(policy, fabric="off").deploy(s_off, tc.new_workloads)
        PlacementEngine(policy, fabric="on").deploy(s_on, tc.new_workloads)
        assert _placements(s_off) == _placements(s_on)

    @pytest.mark.parametrize("seed", SEEDS[:3])
    def test_heterogeneous_routed_parity(self, seed):
        """Mixed fleet through the engine: fabric and scalar paths agree."""
        rng = np.random.default_rng(seed)
        spec = [(A100_80GB, 6), (H100_96GB, 4)]
        news = []
        for device, n in spec:
            news += [
                Workload(
                    wid=f"{device.name}:{w.wid}",
                    profile_id=w.profile_id,
                    device_kind=device.name,
                )
                for w in random_workloads(rng, 3 * n, device)
            ]
        for policy in ("first_fit", "rule_based"):
            states = []
            for fabric in ("off", "on"):
                st = ClusterState(
                    gpus={
                        f"{d.name.split('-')[0].lower()}{i}": GPUState(
                            f"{d.name.split('-')[0].lower()}{i}", d
                        )
                        for d, n in spec
                        for i in range(n)
                    }
                )
                PlacementEngine(policy, fabric=fabric).deploy(st, news)
                st.validate()
                states.append(st)
            assert _placements(states[0]) == _placements(states[1]), policy


# ---------------------------------------------------------------------------
# frag_aware policy semantics
# ---------------------------------------------------------------------------
class TestFragAware:
    @pytest.mark.parametrize("seed", SEEDS)
    def test_deploy_valid_and_no_worse_than_rule_based(self, seed):
        tc = generate_test_case(seed, n_gpus=8)
        s_rule, s_frag = tc.initial.clone(), tc.initial.clone()
        heuristic.initial_deployment(s_rule, tc.new_workloads)
        pend = fabric_frag_aware_deploy(s_frag, tc.new_workloads)
        s_frag.validate()
        from repro.core import metrics

        wl = list(tc.initial.workloads.values()) + list(tc.new_workloads)
        m_rule = metrics.evaluate(s_rule, tc.initial, wl)
        m_frag = metrics.evaluate(s_frag, tc.initial, wl)
        assert m_frag.n_gpus <= m_rule.n_gpus
        assert (
            m_frag.compute_wastage + m_frag.memory_wastage
            <= m_rule.compute_wastage + m_rule.memory_wastage
        )
        assert len(pend) <= m_rule.n_pending

    def test_compact_one_shot_and_valid(self):
        tc = generate_test_case(4, n_gpus=8)
        state = tc.initial.clone()
        used_before = len(state.used_gpus())
        fabric_frag_aware_compact(state)
        state.validate()
        assert len(state.used_gpus()) <= used_before
        # every workload still placed exactly once
        placed = [p.wid for g in state.gpus.values() for p in g.placements]
        assert sorted(placed) == sorted(
            p.wid for g in tc.initial.gpus.values() for p in g.placements
        )

    def test_reconfigure_places_everything(self):
        tc = generate_test_case(6, n_gpus=8)
        state = tc.initial.clone()
        pending = fabric_frag_aware_reconfigure(state)
        state.validate()
        assert pending == []
        placed = {p.wid for g in state.gpus.values() for p in g.placements}
        assert placed == {
            p.wid for g in tc.initial.gpus.values() for p in g.placements
        }

    @pytest.mark.parametrize("seed", range(8))
    def test_reconfigure_never_evicts(self, seed):
        """Dense random-index layouts the greedy re-pack can't always match:
        reconfigure must keep the current layout rather than evict (the
        Sec-4.2 heuristic's safety behavior)."""
        rng = np.random.default_rng(seed)
        state = ClusterState(
            gpus={f"g{i}": GPUState(f"g{i}", A100_80GB) for i in range(4)}
        )
        wi = 0
        for g in state.gpus.values():
            for _ in range(8):
                pid = int(rng.choice([5, 9, 14, 15, 19, 20]))
                prof = A100_80GB.profile(pid)
                feas = [i for i in prof.allowed_indexes if g.can_place_at(prof, i)]
                if not feas:
                    continue
                idx = int(rng.choice(feas))  # random, not preference order
                w = Workload(wid=f"p{wi}", profile_id=pid)
                wi += 1
                state.add_workload(w)
                g.place(w.wid, pid, idx)
        before = {p.wid for g in state.gpus.values() for p in g.placements}
        assert fabric_frag_aware_reconfigure(state) == []
        state.validate()
        after = {p.wid for g in state.gpus.values() for p in g.placements}
        assert after == before

    def test_engine_verbs(self):
        tc = generate_test_case(2, n_gpus=8)
        state = tc.initial.clone()
        eng = PlacementEngine("frag_aware")
        eng.deploy(state, tc.new_workloads)
        state.validate()
        eng.compact(state)
        state.validate()
        eng.reconfigure(state)
        state.validate()


class TestPersistentMirror:
    """fleet_fabric(): one mirror per ClusterState, row-synced across calls."""

    def test_reused_and_synced_after_external_mutation(self):
        from repro.core.fabric import fleet_fabric

        tc = generate_test_case(1, n_gpus=8)
        state = tc.initial
        fab1 = fleet_fabric(state)
        fab1.feasible_all()
        # external mutation the mirror has not seen: direct GPUState removal
        gid, pl = next(
            (g.gid, g.placements[0]) for g in state.used_gpus()
        )
        state.gpus[gid].remove(pl.wid)
        fab2 = fleet_fabric(state)
        assert fab2 is fab1  # reused, not rebuilt
        np.testing.assert_array_equal(
            fab2.feasible_all(), FleetFabric(state).feasible_all()
        )

    def test_wholesale_gpu_replacement_resyncs(self):
        from repro.core.fabric import fleet_fabric

        tc = generate_test_case(2, n_gpus=6)
        state = tc.initial
        fleet_fabric(state).feasible_all()
        snapshot = state.clone()
        # mutate, then roll back by replacing the gpus dict with the clones
        # (what OnlineSimulator's migration-budget rollback does)
        gid = state.used_gpus()[0].gid
        state.gpus[gid].remove(state.gpus[gid].placements[0].wid)
        state.gpus = snapshot.gpus
        fab = fleet_fabric(state)
        np.testing.assert_array_equal(
            fab.feasible_all(), FleetFabric(state).feasible_all()
        )

    def test_engine_deploys_share_one_mirror_across_calls(self):
        tc = generate_test_case(3, n_gpus=8)
        s_scalar, s_fab = tc.initial.clone(), tc.initial.clone()
        eng_off = PlacementEngine("rule_based", fabric="off")
        eng_on = PlacementEngine("rule_based", fabric="on")
        news = list(tc.new_workloads)
        # deploy one-by-one (the online arrival pattern), with a direct
        # departure in between that only the state sees
        for i, w in enumerate(news[:6]):
            eng_off.deploy(s_scalar, [w])
            eng_on.deploy(s_fab, [w])
            if i == 2:
                for st in (s_scalar, s_fab):
                    victim = st.used_gpus()[0].placements[0].wid
                    st.remove(victim)
        assert _placements(s_scalar) == _placements(s_fab)


def test_empty_fleet_parity():
    """0-GPU cluster: fabric paths pend everything, like the scalar paths."""
    w = Workload(wid="w0", profile_id=9)
    for fn in (fabric_first_fit, fabric_load_balanced, fabric_initial_deployment,
               fabric_frag_aware_deploy):
        state = ClusterState()
        pending = fn(state, [w])
        assert [p.wid for p in pending] == ["w0"]
        assert "w0" in state.workloads
    fabric_frag_aware_compact(ClusterState())
    assert fabric_frag_aware_reconfigure(ClusterState()) == []


# ---------------------------------------------------------------------------
# randomized property: parity under arrival/departure churn
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", SEEDS[:3])
def test_churn_parity(seed):
    """Interleaved random placements/removals keep the mirror exact."""
    rng = np.random.default_rng(seed)
    state = _random_hetero_state(seed + 100)
    fab = FleetFabric(state)
    fab.feasible_all()
    live = []
    wi = 0
    for step in range(60):
        if live and rng.random() < 0.4:
            wid, gid, pid, idx = live.pop(int(rng.integers(len(live))))
            state.remove(wid, gid)
            fab.unapply(gid, state.gpus[gid].device.profile(pid), idx)
        else:
            gid = fab.gids[int(rng.integers(len(fab.gids)))]
            device = state.gpus[gid].device
            pid = int(rng.choice([p.profile_id for p in device.profiles]))
            spot = fab.pick_first_fit(pid, device.name)
            if spot is None:
                continue
            sgid, idx = spot
            w = Workload(wid=f"c{wi}", profile_id=pid, device_kind=device.name)
            wi += 1
            state.add_workload(w)
            state.place(w.wid, sgid, idx)
            fab.apply(sgid, device.profile(pid), idx)
            live.append((w.wid, sgid, pid, idx))
    np.testing.assert_array_equal(
        fab.feasible_all(), FleetFabric(state).feasible_all()
    )
    state.validate()
