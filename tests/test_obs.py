"""Telemetry subsystem tests (repro/obs).

Covers the PR-5 observability guarantees:

- telemetry is *inert*: a seeded simulation run with telemetry enabled is
  byte-identical (TraceStats + final placements) to a telemetry-off run;
- every committed plan verb produces a complete plan/score/commit span
  tree (and rejected plans a rollback child);
- ``Histogram.percentile`` matches ``numpy.percentile`` linear
  interpolation on the raw reservoir;
- exporters: Prometheus text exposition shape, strict (NaN-free) JSONL
  round-trip, and the ``repro.obs.report`` renderer.
"""
import json
import math

import numpy as np
import pytest

from repro import obs
from repro.core.engine import CommitPolicy, PlacementEngine
from repro.core.events import OnlineSimulator, build_fleet, generate_trace
from repro.core.profiles import A100_80GB
from repro.core.state import ClusterState, Workload
from repro.core.tpu_profiles import TPU_V5E_POD
from repro.obs import report


@pytest.fixture(autouse=True)
def _isolated_telemetry():
    """Never leak an enabled Telemetry into other tests."""
    yield
    obs.disable()


def _snapshot(state: ClusterState):
    return sorted(
        (gid, p.wid, p.profile_id, p.index)
        for gid, g in state.gpus.items()
        for p in g.placements
    )


def _run_trace(seed: int = 11):
    fleet = build_fleet([(A100_80GB, 6), (TPU_V5E_POD, 1)])
    trace = generate_trace(
        seed, fleet, horizon=80.0, arrival_rate=0.5, mean_lifetime=30.0
    )
    sim = OnlineSimulator(
        fleet, PlacementEngine("rule_based"), compact_every=20.0
    )
    stats = sim.run(trace)
    return stats, _snapshot(fleet)


class TestTelemetryIsInert:
    def test_enabled_run_is_byte_identical_to_disabled(self):
        obs.disable()
        stats_off, snap_off = _run_trace()
        obs.enable()
        stats_on, snap_on = _run_trace()

        d_off, d_on = stats_off.as_dict(), stats_on.as_dict()
        # wall-clock engine time is inherently nondeterministic; everything
        # else must match to the byte.
        for d in (d_off, d_on):
            d.pop("engine_seconds")
        assert d_on == d_off
        assert snap_on == snap_off
        assert json.dumps(obs.sanitize_json(d_on), sort_keys=True) == \
            json.dumps(obs.sanitize_json(d_off), sort_keys=True)

    def test_disabled_telemetry_records_nothing(self):
        obs.disable()
        tel = obs.get_telemetry()
        with tel.tracer.span("deploy") as sp:
            sp.set(foo=1)
        tel.metrics.counter("c", "help").inc()
        assert tel.tracer.records() == []
        assert tel.metrics.families() == {}
        assert not tel.enabled


class TestSpanTrees:
    def _state(self):
        st = ClusterState.homogeneous(3)
        for wid, pid, gid, idx in [
            ("w1", 15, "gpu0", 0), ("w2", 15, "gpu1", 0), ("w3", 19, "gpu2", 0),
        ]:
            st.add_workload(Workload(wid=wid, profile_id=pid))
            st.place(wid, gid, idx)
        return st

    def test_committed_compact_has_plan_score_commit_children(self):
        tel = obs.enable()
        res = PlacementEngine("rule_based").compact(self._state())
        assert res.committed
        roots = tel.tracer.find(name="compact")
        assert len(roots) == 1
        root = roots[0]
        assert root.parent_id is None
        children = {c.name for c in tel.tracer.children_of(root)}
        assert {"plan", "score", "commit"} <= children
        for c in tel.tracer.children_of(root):
            assert c.parent_id == root.span_id
            assert c.trace_id == root.trace_id
        assert root.attrs["committed"] is True
        assert root.attrs["n_moves"] == res.plan.n_moves

    def test_rejected_plan_has_rollback_child_and_term(self):
        tel = obs.enable()
        engine = PlacementEngine(
            "rule_based", commit=CommitPolicy(move_budget=0)
        )
        res = engine.compact(self._state())
        assert not res.committed
        root = tel.tracer.find(name="compact")[0]
        children = {c.name for c in tel.tracer.children_of(root)}
        assert "rollback" in children and "commit" not in children
        assert root.attrs["term"] == res.decision.term == "moves"
        assert res.decision.shortfall >= 1.0

    def test_commit_decision_terms(self):
        res = PlacementEngine("rule_based").compact(self._state())
        gains, cost = res.gains, res.cost
        assert cost.n_moves > 0
        always = CommitPolicy(mode="always").decide(gains, cost)
        assert always.commit and always.term == "always"
        assert always.shortfall == 0.0
        moves = CommitPolicy(move_budget=0).decide(gains, cost)
        assert not moves.commit and moves.term == "moves"
        assert moves.shortfall == pytest.approx(cost.n_moves)
        byts = CommitPolicy(mode="budgeted", bytes_budget=1).decide(gains, cost)
        assert not byts.commit and byts.term == "bytes"
        assert byts.shortfall == pytest.approx(cost.total_bytes - 1)


class TestHistogram:
    def test_percentile_matches_numpy(self):
        rng = np.random.default_rng(0)
        vals = rng.exponential(0.05, size=500)
        h = obs.Histogram("h", "help", labels=())
        for v in vals:
            h.observe(float(v))
        for q in (50.0, 90.0, 95.0, 99.0, 100.0):
            assert h.percentile(q) == pytest.approx(
                float(np.percentile(vals, q)), rel=1e-9
            )

    def test_cumulative_buckets_and_count(self):
        h = obs.Histogram("h", "help", labels=(), buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 5.0):
            h.observe(v)
        cum = h.cumulative_buckets()
        assert cum == [(0.1, 1), (1.0, 2), (math.inf, 3)]
        assert h.count == 3 and h.sum == pytest.approx(5.55)


class TestExporters:
    def test_prometheus_text_shape(self):
        tel = obs.Telemetry.live()
        tel.metrics.counter(
            "plans_committed_total", "plans committed", labels={"verb": "compact"}
        ).inc(3)
        tel.metrics.gauge("gpus_used", "gpus in use").set(7)
        tel.metrics.histogram("latency_seconds", "verb latency").observe(0.2)
        text = obs.prometheus_text(tel.metrics)
        assert "# TYPE repro_plans_committed_total counter" in text
        assert 'repro_plans_committed_total{verb="compact"} 3' in text
        assert "repro_gpus_used 7" in text
        assert 'repro_latency_seconds_bucket{le="+Inf"} 1' in text
        assert "repro_latency_seconds_count 1" in text
        assert text.endswith("\n")

    def test_jsonl_round_trip_is_strict(self, tmp_path):
        tel = obs.Telemetry.live()
        with tel.tracer.span("deploy") as sp:
            sp.set(policy="rule_based", score=float("nan"))
        dest = tmp_path / "spans.jsonl"
        n = obs.write_jsonl(tel.tracer.records(), dest)
        assert n == 1

        def _reject(x):
            raise ValueError(f"non-strict JSON constant {x!r}")

        [rec] = [
            json.loads(line, parse_constant=_reject)
            for line in dest.read_text().splitlines()
        ]
        assert rec["name"] == "deploy"
        assert rec["attrs"]["score"] is None  # NaN sanitized to null
        assert list(obs.iter_jsonl(dest)) == [rec]

    def test_sanitize_json_scrubs_non_finite(self):
        out = obs.sanitize_json(
            {"a": float("inf"), "b": [float("-inf"), 1.5], "c": {"d": math.nan}}
        )
        assert out == {"a": None, "b": [None, 1.5], "c": {"d": None}}
        json.dumps(out, allow_nan=False)  # must not raise


class TestReport:
    def test_report_renders_from_generated_spans(self, tmp_path, capsys):
        tel = obs.enable()
        _run_trace(seed=3)
        dest = tmp_path / "spans.jsonl"
        obs.write_jsonl(tel.tracer.records(), dest)
        report.main([str(dest), "--width", "60"])
        out = capsys.readouterr().out
        assert "per-span latency" in out
        assert "deploy" in out
        spans, _events = report.load_records(str(dest))
        rows = report.latency_table(spans)
        deploy = next(r for r in rows if r["name"] == "deploy")
        assert deploy["count"] > 0
        assert deploy["p50_s"] <= deploy["p95_s"] <= deploy["p99_s"]

    def test_html_timeline(self, tmp_path):
        tel = obs.enable()
        _run_trace(seed=3)
        dest = tmp_path / "spans.jsonl"
        obs.write_jsonl(tel.tracer.records(), dest)
        html = tmp_path / "report.html"
        report.main([str(dest), "--html", str(html)])
        text = html.read_text()
        assert text.lstrip().lower().startswith("<!doctype html>")
        assert "deploy" in text
