"""Hypothesis property tests for placement-system invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st  # noqa: E402
from hypothesis import HealthCheck, given, settings  # noqa: E402

from repro.core import baselines, heuristic, metrics
from repro.core.indexing import assign_indexes
from repro.core.profiles import A100_80GB
from repro.core.state import ClusterState, GPUState, Workload

_POOL = [5, 9, 14, 15, 19]
# Case sizes kept small so tier-1 stays fast; the transactional-state parity
# tests in test_engine.py cover the larger seeded instances.
_SETTINGS = dict(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)

workload_lists = st.lists(
    st.sampled_from(_POOL), min_size=1, max_size=16
).map(lambda pids: [Workload(f"w{i}", p) for i, p in enumerate(pids)])


@given(workload_lists, st.integers(1, 6))
@settings(**_SETTINGS)
def test_initial_deployment_invariants(ws, n_gpus):
    st_ = ClusterState.homogeneous(n_gpus)
    pending = heuristic.initial_deployment(st_, ws)
    st_.validate()  # no overlaps, only allowed indexes
    placed = {p.wid for g in st_.gpus.values() for p in g.placements}
    assert placed | {w.wid for w in pending} == {w.wid for w in ws}
    assert placed & {w.wid for w in pending} == set()
    m = metrics.evaluate(st_, None, ws)
    assert 0.0 <= m.memory_utilization <= 1.0
    assert 0.0 <= m.compute_utilization <= 1.0
    assert m.compute_wastage >= 0 and m.memory_wastage >= 0


@given(workload_lists, st.integers(1, 6))
@settings(**_SETTINGS)
def test_baselines_feasibility(ws, n_gpus):
    for placer in (baselines.first_fit, baselines.load_balanced):
        st_ = ClusterState.homogeneous(n_gpus)
        placer(st_, ws)
        st_.validate()


@given(workload_lists)
@settings(**_SETTINGS)
def test_rule_based_never_uses_more_gpus_than_first_fit(ws):
    """Sec 4.2's sorting + max-utilization packing dominates first-fit."""
    n = len(ws)  # plenty of GPUs so nothing is pending
    a = ClusterState.homogeneous(n)
    heuristic.initial_deployment(a, ws)
    b = ClusterState.homogeneous(n)
    baselines.first_fit(b, ws)
    assert metrics.evaluate(a).n_gpus <= metrics.evaluate(b).n_gpus


@given(st.lists(st.sampled_from(_POOL + [0, 20]), min_size=1, max_size=7))
@settings(**_SETTINGS)
def test_assumption1_on_random_multisets(pids):
    """fits() == indexable for random multisets (Assumption 1)."""
    counts = {}
    for p in pids:
        counts[p] = counts.get(p, 0) + 1
    g = GPUState("probe")
    indexable = assign_indexes(g, pids, optimize=False) is not None
    assert indexable == A100_80GB.fits(counts)


@given(workload_lists, st.integers(2, 5))
@settings(max_examples=20, deadline=None)
def test_compaction_never_increases_gpus_or_breaks_state(ws, n_gpus):
    st_ = ClusterState.homogeneous(n_gpus)
    heuristic.initial_deployment(st_, ws)
    placed_before = {p.wid for g in st_.gpus.values() for p in g.placements}
    before = metrics.evaluate(st_).n_gpus
    init = st_.clone()
    heuristic.compaction(st_)
    st_.validate()
    placed_after = {p.wid for g in st_.gpus.values() for p in g.placements}
    assert placed_after == placed_before  # nothing lost
    m = metrics.evaluate(st_, init)
    assert m.n_gpus <= before
    assert m.sequential_migrations == 0  # heuristic is one-shot by design


@given(workload_lists)
@settings(max_examples=20, deadline=None)
def test_reconfiguration_meets_lower_bound_plus_slack(ws):
    n = max(2 * len(ws), 4)
    st_ = ClusterState.homogeneous(n)
    pending = heuristic.initial_deployment(st_, ws)
    if pending:
        return
    init = st_.clone()
    heuristic.reconfiguration(st_)
    st_.validate()
    lb = heuristic.min_gpus_needed(A100_80GB, ws)
    m = metrics.evaluate(st_, init)
    assert lb <= m.n_gpus <= lb + 1  # FFD on these profiles stays near-optimal
    placed = {p.wid for g in st_.gpus.values() for p in g.placements}
    assert placed == {w.wid for w in ws}
