"""Pytest path setup only — deliberately NO XLA flags here.

Smoke tests and benchmarks must see the real single CPU device; only
launch/dryrun.py forces the 512-device host platform."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "src"))
